"""Quickstart: the paper's three TNO variants on a toy sequence.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.tno import TNOConfig, tno_apply, tno_init
from repro.core.fd import FDConfig, fd_init, fd_kernel_time
from repro.nn.params import unbox


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 256, 32))      # (batch, seq, channels)

    print("== Toeplitz Neural Operator variants (paper §3) ==")
    for variant, note in [
        ("tno", "baseline TNN: MLP RPE × decay bias, FFT matvec"),
        ("ski", "sparse + low-rank: conv + W A Wᵀ via asymmetric SKI"),
        ("fd", "frequency domain: RPE models the spectrum directly"),
    ]:
        cfg = TNOConfig(d=32, variant=variant, causal=True, rank=16,
                        filter_size=8)
        params, _ = unbox(tno_init(key, cfg))
        y = jax.jit(lambda p, x: tno_apply(p, cfg, x))(params, x)
        print(f"  {variant:4s}: y{tuple(y.shape)}  |y|={float(jnp.abs(y).mean()):.4f}  ({note})")

    print("\n== Causality via the Hilbert transform (paper §3.3.1) ==")
    fcfg = FDConfig(d=4, causal=True)
    fparams, _ = unbox(fd_init(key, fcfg))
    kt = fd_kernel_time(fparams, fcfg, 64)        # (d, 2n)
    neg = float(jnp.abs(kt[:, 65:]).max())
    pos = float(jnp.abs(kt[:, :64]).max())
    print(f"  negative-lag mass {neg:.2e} vs positive-lag {pos:.2e} "
          "-> kernel is exactly causal")

    print("\n== Drop the paper's mixer into an assigned architecture ==")
    import dataclasses
    from repro.configs import get_config, reduce_for_smoke
    from repro.models.context import Ctx
    from repro.models.transformer import forward, init_model
    cfg = dataclasses.replace(
        reduce_for_smoke(get_config("phi3-medium-14b")), mixer_override="fd")
    params, _ = unbox(init_model(key, cfg))
    batch = {"tokens": jnp.ones((2, 32), jnp.int32)}
    logits, _ = forward(params, cfg, Ctx(), batch)
    print(f"  phi3(+FD-TNO mixer) logits {tuple(logits.shape)} ok")


if __name__ == "__main__":
    main()
