"""End-to-end driver: pre-train a TNN-family causal LM (~100M-param config,
reduced to CPU scale by default) for a few hundred steps on the synthetic
corpus, with checkpoints and fault-tolerant runtime — the paper's §5.1
pipeline shape, through the framework's full stack.

CPU-scale run (a few minutes):
  PYTHONPATH=src python examples/train_tnn_lm.py --variant fd --steps 200

Full-size config (TPU fleet; same entrypoint):
  PYTHONPATH=src python -m repro.launch.train --arch fd-tnn-lm-wt103 \
      --steps 50000 --production-mesh
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import StepBuilder
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="fd", choices=["tno", "ski", "fd"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full-size", action="store_true",
                    help="paper-scale 6L/512d (~45M) instead of smoke")
    ap.add_argument("--ckpt-dir", default="/tmp/tnn_lm_ckpt")
    args = ap.parse_args()

    name = {"tno": "tnn-lm-wt103", "ski": "ski-tnn-lm-wt103",
            "fd": "fd-tnn-lm-wt103"}[args.variant]
    cfg = get_config(name)
    if not args.full_size:
        cfg = reduce_for_smoke(cfg, d_model=128, vocab=1024, n_layers=2)
    cfg = dataclasses.replace(cfg, scan_layers=True)

    mesh = make_host_mesh()
    opt_cfg = adamw.OptConfig(lr=1e-3, warmup_steps=20,
                              total_steps=args.steps)
    sb = StepBuilder(cfg, mesh, opt_cfg=opt_cfg)
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                          global_batch=args.batch, kind="synthetic")

    state_sh = sb.state_shardings()
    train_step = jax.jit(sb.make_train_step(),
                         in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None))

    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=100, log_every=25)
    trainer = Trainer(tcfg, train_step, data_cfg)
    with mesh:
        state = jax.device_put(sb.init_state(jax.random.PRNGKey(0)), state_sh)
        state, start = trainer.try_restore(state, shardings=state_sh)
        state, end = trainer.run(state, start)

    nlls = [float(m["nll"]) for m in trainer.metrics_history]
    print(f"[example] {args.variant}: nll {nlls[0]:.3f} -> {nlls[-1]:.3f} "
          f"(ppl {np.exp(nlls[-1]):.1f}) over {end - start} steps")
    assert nlls[-1] < nlls[0], "training should reduce loss"


if __name__ == "__main__":
    main()
