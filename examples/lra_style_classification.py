"""Bidirectional long-range classification (the paper's LRA setting, §5.2)
on the offline ``lra_match`` task: train SKI-TNN vs FD-TNN vs TNN and
print accuracies — the Table-2 experiment shape end to end.

  PYTHONPATH=src python examples/lra_style_classification.py --steps 80
"""
import argparse

from benchmarks.bench_lra_style import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()
    results = run(steps=args.steps, seq_len=args.seq_len, batch=args.batch)
    print("\n[lra-style] accuracies (chance = 50%):")
    for variant, acc in results.items():
        print(f"  {variant:4s}: {100 * acc:.1f}%")


if __name__ == "__main__":
    main()
