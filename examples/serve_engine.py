"""Continuous-batching serving example: N ragged requests through S
decode slots (repro.serving_engine) with prefill→insert→generate
scheduling, per-request token streaming, EOS/max-len eviction and slot
recycling — then a per-request parity check against solo decode.

  PYTHONPATH=src python examples/serve_engine.py --arch fd-tnn-lm-wt103
  PYTHONPATH=src python examples/serve_engine.py --slots 4 --requests 6
  PYTHONPATH=src python examples/serve_engine.py --chaos

The parity assertion is the engine's core contract: every request's
token stream is identical to what a dedicated single-request
``launch/serve.generate`` call (same length bucket) produces — batching
is a throughput optimisation, never a quality change.

``--chaos`` runs the ISSUE 6 chaos parity gate instead: the same fleet
under a deterministic FaultInjector campaign (one poisoned request with
a persistent prefill fault, a transient decode fault, a NaN injection
that must be quarantined, a raising streaming callback) followed by a
mid-run SIGTERM + snapshot resume. Gate: every non-faulted request's
token stream is bit-exact vs the fault-free baseline, every faulted
request ends in an explicit error outcome, no slot leaks (a full second
wave serves exactly), and the resumed run is token-exact.
"""
import argparse
import os
import signal
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke


def _fleet(prompts, gens, uid_prefix="req", **req_kw):
    from repro.serving_engine import Request
    return [Request(uid=f"{uid_prefix}{i}", prompt=pr, max_new=g, **req_kw)
            for i, (pr, g) in enumerate(zip(prompts, gens))]


def run_chaos(args, cfg, params, prompts, gens, reg=None, tracer=None):
    """ISSUE 6 chaos parity gate — see module docstring. Returns the
    *final* outcome per uid (the last run that served it) so the obs
    trace's terminal span statuses can be cross-checked."""
    from repro.serving_engine import (Engine, FaultInjector, FaultSpec,
                                      Scheduler)
    obs_kw = dict(metrics=reg, tracer=tracer)

    def fresh_engine():
        return Engine(cfg, params, slots=args.slots, max_len=args.max_len)

    # ---- fault-free baseline: the token streams every later run must hit
    sched = Scheduler(fresh_engine(), **obs_kw)
    for r in _fleet(prompts, gens, "c"):
        sched.submit(r)
    baseline, _ = sched.run()
    baseline = {u: list(t) for u, t in baseline.items()}
    assert all(o.status == "ok" for o in sched.outcomes.values())
    print(f"[chaos] baseline: {len(baseline)} requests, "
          f"{sum(map(len, baseline.values()))} tokens")

    # ---- wave 1 under scripted faults
    injector = FaultInjector(specs=[
        FaultSpec(site="prefill", uid="c1", count=99),   # poisoned request
        FaultSpec(site="decode", at=2),                  # transient: retried
        FaultSpec(site="decode", at=5, poison_slot=0),   # NaN -> quarantine
        FaultSpec(site="callback", uid="c2"),            # raising callback
    ])
    eng = fresh_engine()
    streamed = {}
    sched = Scheduler(eng, injector=injector, max_retries=2,
                      backoff_base=0.0, log=print, **obs_kw)
    for r in _fleet(prompts, gens, "c",
                    on_token=lambda u, t: streamed.setdefault(u, [])
                    .append(t)):
        sched.submit(r)
    results, state = sched.run()

    out = sched.outcomes
    assert out["c1"].status == "error" and "prefill" in out["c1"].error, (
        out["c1"])
    victims = [u for u, o in out.items()
               if o.status == "error" and o.error
               and "non-finite" in o.error]
    assert len(victims) == 1, out           # exactly the poisoned slot
    victim = victims[0]
    # quarantined stream is a strict prefix of the baseline (tokens up to
    # the injection are exact; garbage after it is never emitted)
    vt = results[victim]
    assert vt == baseline[victim][:len(vt)] and len(vt) < len(
        baseline[victim]), (victim, vt)
    assert out["c2"].callback_error is not None, out["c2"]
    survivors = [u for u in baseline
                 if u not in (victim, "c1")]
    for u in survivors:
        assert out[u].status == "ok", out[u]
        assert results[u] == baseline[u], (
            f"{u}: fault spill-over — {results[u][:8]} vs "
            f"{baseline[u][:8]}")
    assert sched.retries >= 1, "transient decode fault was never retried"
    print(f"[chaos] wave 1: poisoned={['c1']}, quarantined={victim}, "
          f"callback detached=c2, {len(survivors)} survivors bit-exact, "
          f"retries={sched.retries}, injector fired={injector.fired}")

    # ---- wave 2 through the same engine state: no slot leaks
    sched.injector = None
    for r in _fleet(prompts, gens, "w"):
        sched.submit(r)
    results2, _ = sched.run(state)
    for i in range(len(prompts)):
        u = f"w{i}"
        assert sched.outcomes[u].status == "ok", sched.outcomes[u]
        assert results2[u] == baseline[f"c{i}"], (
            f"{u}: recycled-slot leak — {results2[u][:8]} vs "
            f"{baseline[f'c{i}'][:8]}")
    print(f"[chaos] wave 2: {len(prompts)} requests through recycled "
          "slots, all bit-exact — no slot leaks")

    # ---- mid-run SIGTERM + snapshot resume, token-exact continuation
    with tempfile.TemporaryDirectory() as snap_dir:
        emitted = {"n": 0}

        def kill_after(u, t):
            emitted["n"] += 1
            if emitted["n"] == 11:       # mid-generation, slots in flight
                os.kill(os.getpid(), signal.SIGTERM)

        wave2_outcomes = dict(sched.outcomes)
        sched = Scheduler(fresh_engine(), snapshot_dir=snap_dir, log=print,
                          **obs_kw)
        for r in _fleet(prompts, gens, "c", on_token=kill_after):
            sched.submit(r)
        sched.run()
        assert sched.preempted, "SIGTERM did not preempt the run"
        partial = sum(len(v) for v in sched.results.values())
        assert partial < sum(map(len, baseline.values()))

        sched2 = Scheduler(fresh_engine(), snapshot_dir=snap_dir, **obs_kw)
        assert sched2.try_restore(), "no committed snapshot to resume"
        resumed, _ = sched2.run()
        for u in baseline:
            assert sched2.outcomes[u].status == "ok", sched2.outcomes[u]
            assert resumed[u] == baseline[u], (
                f"{u}: resume drift — {resumed[u][:8]} vs "
                f"{baseline[u][:8]}")
        print(f"[chaos] kill+resume: preempted after {partial} tokens "
              f"(step {sched.steps}), resumed to step {sched2.steps}, "
              "all requests token-exact vs uninterrupted baseline")
        # final outcome per uid: w* ended in wave 2, c* in the resumed run
        final = {u: o for u, o in wave2_outcomes.items()
                 if u.startswith("w")}
        final.update(sched2.outcomes)
    print("[chaos] chaos parity gate OK")
    return final


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fd-tnn-lm-wt103")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--no-parity", action="store_true",
                    help="skip the (slow) solo-decode parity check")
    ap.add_argument("--chaos", action="store_true",
                    help="run the fault-injection + kill/resume parity "
                         "gate instead of the plain demo (ISSUE 6)")
    ap.add_argument("--metrics-file", default=None, metavar="PATH",
                    help="dump the obs metrics registry on exit (.json = "
                         "JSON, else Prometheus text exposition)")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="stream span events to PATH (JSONL) and write "
                         "PATH + '.chrome.json' (Perfetto-loadable); the "
                         "span trees are validated before exit")
    args = ap.parse_args()

    from repro.kernels import backend
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import generate
    from repro.launch.steps import StepBuilder
    from repro.models.transformer import init_model
    from repro.nn.params import unbox
    from repro.serving_engine import Engine, Request, Scheduler

    print(f"[engine] backend: {backend.describe()}")
    cfg = reduce_for_smoke(get_config(args.arch))
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))

    reg = tracer = None
    if args.metrics_file:
        from repro.obs import metrics as obs_metrics
        reg = obs_metrics.Registry()
        # process default too: engine trace_counts + kernel dispatch
        # counters land in the same dump
        obs_metrics.set_default_registry(reg)
    if args.trace_file:
        from repro.obs import tracing as obs_tracing
        tracer = obs_tracing.Tracer(args.trace_file)

    rng = np.random.default_rng(0)
    plens = [int(rng.integers(3, 17)) for _ in range(args.requests)]
    gens = [int(rng.integers(8, 33)) for _ in range(args.requests)]
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
               for p in plens]

    if args.chaos:
        final = run_chaos(args, cfg, params, prompts, gens, reg, tracer)
        _dump_obs(args, reg, tracer, final)
        return

    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len)
    sched = Scheduler(eng, metrics=reg, tracer=tracer)
    streamed = {}
    for i, (pr, g) in enumerate(zip(prompts, gens)):
        sched.submit(Request(
            uid=f"req{i}", prompt=pr, max_new=g,
            on_token=lambda uid, tok: streamed.setdefault(uid, []).append(tok)))
    t0 = time.time()
    results, _ = sched.run()
    dt = time.time() - t0
    n_new = sum(len(v) for v in results.values())
    print(f"[engine] {args.requests} ragged requests over {eng.slots} slots: "
          f"{n_new} tokens in {dt:.2f}s ({n_new / dt:.1f} tok/s aggregate); "
          f"decode steps={sched.steps} prefills={sched.prefills}")

    # eviction/recycle actually happened: more requests than slots means
    # every extra request rode a recycled slot, and the jitted step never
    # retraced across inserts/evictions
    assert args.requests > args.slots, "demo wants recycling: requests > slots"
    assert sched.prefills == args.requests
    assert sched.packed_prefills >= 1, "admission never packed a batch"
    assert eng.trace_counts["generate"] == 1, eng.trace_counts
    # admission traces are bounded by shapes, never by request count:
    # one insert trace (sequential fallback), one insert_from trace per
    # distinct packed batch size, one prefill executable per
    # (batch, bucket) pair
    assert eng.trace_counts["insert"] <= 1, eng.trace_counts
    assert 1 <= eng.trace_counts["insert_from"] <= args.slots, (
        eng.trace_counts)
    assert eng.trace_counts["prefill_bucket"] <= args.slots * len(
        eng.buckets), eng.trace_counts
    for i, g in enumerate(gens):
        assert len(results[f"req{i}"]) == g, (i, len(results[f"req{i}"]), g)
        assert results[f"req{i}"] == streamed[f"req{i}"]  # cb saw every token
    print("[engine] eviction/recycle + jit-stability assertions OK")

    if not args.no_parity:
        mesh = make_host_mesh()
        sb = StepBuilder(cfg, mesh)
        with mesh:
            for i, (pr, g) in enumerate(zip(prompts, gens)):
                toks = generate(sb, params, jnp.asarray(pr)[None], g,
                                max_len=args.max_len)
                want = np.asarray(toks)[0, len(pr):]
                got = np.asarray(results[f"req{i}"])
                assert np.array_equal(got, want), (
                    f"req{i}: engine {got[:8]} != solo {want[:8]}")
        print(f"[engine] per-request token-exact parity vs solo decode OK "
              f"({args.requests} requests)")
    _dump_obs(args, reg, tracer, sched.outcomes)


def _dump_obs(args, reg, tracer, outcomes=None):
    """Write the --metrics-file/--trace-file artifacts: Prometheus (or
    JSON) metrics dump, raw JSONL spans, a Perfetto-loadable Chrome
    trace — and hard-validate that every request left a complete span
    tree whose terminal status matches its Outcome (the ISSUE 9 chaos
    acceptance check)."""
    if tracer is not None:
        from repro.obs import tracing as obs_tracing
        tracer.close()
        chrome = args.trace_file + ".chrome.json"
        obs_tracing.write_chrome(tracer.events, chrome)
        spans = obs_tracing.validate_spans(tracer.events)
        if outcomes:
            for uid, o in outcomes.items():
                got = spans[uid][-1]["status"]
                assert got == o.status, (
                    f"{uid}: trace terminus {got!r} != outcome "
                    f"{o.status!r}")
        print(f"[obs] trace: {args.trace_file} (JSONL), {chrome} "
              f"(Perfetto); {len(spans)} request span trees validated")
    if reg is not None:
        if args.metrics_file.endswith(".json"):
            reg.dump_json(args.metrics_file)
        else:
            reg.dump_prometheus(args.metrics_file)
        print(f"[obs] metrics: {args.metrics_file}")


if __name__ == "__main__":
    main()
