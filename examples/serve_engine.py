"""Continuous-batching serving example: N ragged requests through S
decode slots (repro.serving_engine) with prefill→insert→generate
scheduling, per-request token streaming, EOS/max-len eviction and slot
recycling — then a per-request parity check against solo decode.

  PYTHONPATH=src python examples/serve_engine.py --arch fd-tnn-lm-wt103
  PYTHONPATH=src python examples/serve_engine.py --slots 4 --requests 6

The parity assertion is the engine's core contract: every request's
token stream is identical to what a dedicated single-request
``launch/serve.generate`` call (same length bucket) produces — batching
is a throughput optimisation, never a quality change.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fd-tnn-lm-wt103")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--no-parity", action="store_true",
                    help="skip the (slow) solo-decode parity check")
    args = ap.parse_args()

    from repro.kernels import backend
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import generate
    from repro.launch.steps import StepBuilder
    from repro.models.transformer import init_model
    from repro.nn.params import unbox
    from repro.serving_engine import Engine, Request, Scheduler

    print(f"[engine] backend: {backend.describe()}")
    cfg = reduce_for_smoke(get_config(args.arch))
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))

    rng = np.random.default_rng(0)
    plens = [int(rng.integers(3, 17)) for _ in range(args.requests)]
    gens = [int(rng.integers(8, 33)) for _ in range(args.requests)]
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
               for p in plens]

    eng = Engine(cfg, params, slots=args.slots, max_len=args.max_len)
    sched = Scheduler(eng)
    streamed = {}
    for i, (pr, g) in enumerate(zip(prompts, gens)):
        sched.submit(Request(
            uid=f"req{i}", prompt=pr, max_new=g,
            on_token=lambda uid, tok: streamed.setdefault(uid, []).append(tok)))
    t0 = time.time()
    results, _ = sched.run()
    dt = time.time() - t0
    n_new = sum(len(v) for v in results.values())
    print(f"[engine] {args.requests} ragged requests over {eng.slots} slots: "
          f"{n_new} tokens in {dt:.2f}s ({n_new / dt:.1f} tok/s aggregate); "
          f"decode steps={sched.steps} prefills={sched.prefills}")

    # eviction/recycle actually happened: more requests than slots means
    # every extra request rode a recycled slot, and the jitted step never
    # retraced across inserts/evictions
    assert args.requests > args.slots, "demo wants recycling: requests > slots"
    assert sched.prefills == args.requests
    assert eng.trace_counts["generate"] == 1, eng.trace_counts
    assert eng.trace_counts["insert"] == 1, eng.trace_counts
    for i, g in enumerate(gens):
        assert len(results[f"req{i}"]) == g, (i, len(results[f"req{i}"]), g)
        assert results[f"req{i}"] == streamed[f"req{i}"]  # cb saw every token
    print("[engine] eviction/recycle + jit-stability assertions OK")

    if not args.no_parity:
        mesh = make_host_mesh()
        sb = StepBuilder(cfg, mesh)
        with mesh:
            for i, (pr, g) in enumerate(zip(prompts, gens)):
                toks = generate(sb, params, jnp.asarray(pr)[None], g,
                                max_len=args.max_len)
                want = np.asarray(toks)[0, len(pr):]
                got = np.asarray(results[f"req{i}"])
                assert np.array_equal(got, want), (
                    f"req{i}: engine {got[:8]} != solo {want[:8]}")
        print(f"[engine] per-request token-exact parity vs solo decode OK "
              f"({args.requests} requests)")


if __name__ == "__main__":
    main()
