"""Batched serving example: prefill + autoregressive decode with per-layer
caches (attention KV / SSD state / TNO history / FD overlap-save stream),
through the same serve_step the multi-pod dry-run compiles.

FD archs decode through the streaming cache by default (ring of the last
C tokens + precomputed kernel-tail contributions, O(d) per token — see
kernels/fd_stream.py) with the prompt entering in C-token blocks
(chunked prefill). ``--stream off`` pins the legacy O(n·d) hist-replay
decode for A/B comparison.

  PYTHONPATH=src python examples/serve_decode.py --arch fd-tnn-lm-wt103
  PYTHONPATH=src python examples/serve_decode.py --arch fd-tnn-lm-wt103 --stream off
  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fd-tnn-lm-wt103")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--stream", choices=["auto", "off"], default="auto",
                    help="off: force the legacy hist-replay TNO/FD cache")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="token-by-token prefill even for streaming archs")
    args = ap.parse_args()

    if args.stream == "off":
        os.environ["REPRO_FD_STREAM"] = "0"
    # env must be set before the serving/backend modules are imported
    from repro.kernels import backend
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import generate
    from repro.launch.steps import StepBuilder
    from repro.models.transformer import init_model
    from repro.nn.params import unbox

    print(f"[serve] backend: {backend.describe()}")
    cfg = reduce_for_smoke(get_config(args.arch))
    mesh = make_host_mesh()
    sb = StepBuilder(cfg, mesh)
    with mesh:
        params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
        rng = np.random.default_rng(0)
        prompt = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)
        t0 = time.time()
        toks = generate(sb, params, prompt, args.gen_len,
                        temperature=args.temperature,
                        chunked_prefill=False if args.no_chunked_prefill
                        else None)
        toks.block_until_ready()
        dt = time.time() - t0
    n_new = args.batch * args.gen_len
    print(f"[serve] {args.arch}: {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s)")
    for row in np.asarray(toks)[:2]:
        print("  ", row[: args.prompt_len + 8], "...")


if __name__ == "__main__":
    main()
