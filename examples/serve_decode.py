"""Batched serving example: prefill + autoregressive decode with per-layer
caches (attention KV / SSD state / TNO history), through the same
serve_step the multi-pod dry-run compiles.

  PYTHONPATH=src python examples/serve_decode.py --arch fd-tnn-lm-wt103
  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.launch.steps import StepBuilder
from repro.models.transformer import init_model
from repro.nn.params import unbox


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fd-tnn-lm-wt103")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    mesh = make_host_mesh()
    sb = StepBuilder(cfg, mesh)
    with mesh:
        params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
        rng = np.random.default_rng(0)
        prompt = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)
        t0 = time.time()
        toks = generate(sb, params, prompt, args.gen_len,
                        temperature=args.temperature)
        toks.block_until_ready()
        dt = time.time() - t0
    n_new = args.batch * args.gen_len
    print(f"[serve] {args.arch}: {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s)")
    for row in np.asarray(toks)[:2]:
        print("  ", row[: args.prompt_len + 8], "...")


if __name__ == "__main__":
    main()
