"""Gemma-3-4B [hf:google/gemma-3]: 5:1 local:global interleave, 128k ctx.
head_dim=256 per the official model (spec line leaves it free)."""
from repro.configs.base import register
from repro.models.config import ArchConfig

_PATTERN = tuple(
    ("local" if i < 5 else "attention", "dense") for i in range(6))

CONFIG = register(ArchConfig(
    name="gemma3-4b",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144, window=1024,
    pattern=_PATTERN,
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="5:1 local:global; long_500k RUNS (decode O(n), mostly windowed)",
))
