"""Phi-3-medium 14B [arXiv:2404.14219]: dense, RoPE, SwiGLU, GQA kv=10."""
from repro.configs.base import register
from repro.models.config import ArchConfig

CONFIG = register(ArchConfig(
    name="phi3-medium-14b",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab=100352,
    pattern=(("attention", "dense"),),
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="pure full attention; long_500k SKIPPED",
))
