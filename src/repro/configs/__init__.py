from repro.configs.base import get_config, list_archs, reduce_for_smoke
