"""Grok-1 314B [hf:xai-org/grok-1]: 64L GQA MoE 8e top-2 on every layer."""
from repro.configs.base import register
from repro.models.config import ArchConfig

CONFIG = register(ArchConfig(
    name="grok-1-314b",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab=131072,
    pattern=(("attention", "moe"),),
    n_experts=8, top_k=2,
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="pure full attention; long_500k SKIPPED",
))
