"""Whisper-medium [arXiv:2212.04356]: enc-dec; conv audio frontend is a
STUB (input_specs provides precomputed frame embeddings). SwiGLU FFN in
place of the original 2-proj MLP (framework default; ~+30% FFN params)."""
from repro.configs.base import register
from repro.models.config import ArchConfig

CONFIG = register(ArchConfig(
    name="whisper-medium",
    n_layers=24, enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=51865,
    pattern=(("attention", "dense"),),
    kind="encdec",
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="enc-dec; decode shapes RUN (decoder side); long_500k SKIPPED",
))
