"""The paper's own architecture: TNN causal LM (Qin et al. 2023 config:
6 decoder layers, d=512, ~45M params) with the token mixer selectable
between baseline TNO / SKI-TNO / FD-TNO. GTU+GLU realised as mixer+ffn."""
import dataclasses

from repro.configs.base import register
from repro.models.config import ArchConfig

CONFIG = register(ArchConfig(
    name="tnn-lm-wt103",
    n_layers=6, d_model=512, d_ff=1024, vocab=50265,
    pattern=(("tno", "dense"),),
    tno_rpe_layers=3, tno_rpe_hidden=64, tno_lam=0.99,
    dtype="float32", param_dtype="float32",
    notes="paper's arch; variants: mixer_override('', tno->ski/fd)",
))

FD = register(dataclasses.replace(CONFIG, name="fd-tnn-lm-wt103",
                                  pattern=(("fd", "dense"),)))
SKI = register(dataclasses.replace(CONFIG, name="ski-tnn-lm-wt103",
                                   pattern=(("ski", "dense"),)))
