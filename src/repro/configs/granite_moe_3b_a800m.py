"""Granite-3.0-3b-a800m [hf:ibm-granite]: 40 experts top-8, d_ff=512."""
from repro.configs.base import register
from repro.models.config import ArchConfig

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    pattern=(("attention", "moe"),),
    n_experts=40, top_k=8,
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="pure full attention; long_500k SKIPPED; vocab padded to /256",
))
