"""Config registry + smoke-reduction helper."""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig

_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    key = name.replace("_", "-")
    if key in _REGISTRY:
        return _REGISTRY[key]
    raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")


def list_archs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    import importlib
    for mod in [
        "jamba_1_5_large_398b", "grok_1_314b", "granite_moe_3b_a800m",
        "phi3_medium_14b", "qwen2_72b", "gemma3_4b", "stablelm_3b",
        "paligemma_3b", "whisper_medium", "mamba2_2p7b", "tnn_lm",
    ]:
        importlib.import_module(f"repro.configs.{mod}")


def reduce_for_smoke(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Shrink a full config to CPU-smoke size, preserving the layer pattern
    and family (GQA ratios, MoE top-k, SSM structure)."""
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=min(cfg.n_layers, 2 * cfg.period),
        d_model=128,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32 if cfg.n_heads else 0,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512 if cfg.vocab else 0,
        enc_layers=min(cfg.enc_layers, 2),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_groups=min(cfg.ssm_groups, 2) if cfg.ssm_state else 1,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssd_chunk=16,
        window=min(cfg.window, 8) if cfg.window else 0,
        n_prefix=min(cfg.n_prefix, 8) if cfg.n_prefix else 0,
        attn_chunk=32,
        tno_rank=8,
        tno_filter=4,
        tno_rpe_hidden=16,
        vocab_pad_multiple=16,
        remat="none",
    )
    if cfg.n_kv_heads == cfg.n_heads:   # MHA family (stablelm, whisper)
        kw["n_kv_heads"] = kw["n_heads"]
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)
