"""StableLM-3B [hf:stabilityai]: dense MHA (kv=heads=32), head_dim 80."""
from repro.configs.base import register
from repro.models.config import ArchConfig

CONFIG = register(ArchConfig(
    name="stablelm-3b",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab=50304,
    pattern=(("attention", "dense"),),
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="pure full attention; long_500k SKIPPED",
))
