"""Jamba-1.5-Large 398B [arXiv:2403.19887]: Mamba+attention 1:7 interleave,
MoE 16e top-2 every other layer. Our SSD-based Mamba sublayer (Mamba-2
chunked scan) replaces the original Mamba-1 selective scan — the TPU-native
choice (DESIGN §3/§4); d_state/groups chosen for MXU alignment."""
from repro.configs.base import register
from repro.models.config import ArchConfig

# 8-layer period: attention at index 4, mamba elsewhere; MoE on odd layers.
_PATTERN = tuple(
    ("attention" if i == 4 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8))

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536,
    pattern=_PATTERN,
    n_experts=16, top_k=2,
    # moe_impl="ep" is available (E == TP extent; exact vs dropless,
    # tested) but the capacity default measures better on the CPU
    # artifact - see EXPERIMENTS par.Perf J2/J3
    ssm_state=128, ssm_groups=8, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="hybrid; long_500k RUNS (sub-quadratic)",
))
