"""PaliGemma-3B [arXiv:2407.07726]: SigLIP patch frontend (STUB per the
assignment: input_specs provides precomputed patch embeddings) + gemma text
tower as a prefix-LM (bidirectional over 256 patches, causal over text)."""
from repro.configs.base import register
from repro.models.config import ArchConfig

CONFIG = register(ArchConfig(
    name="paligemma-3b",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216,
    pattern=(("attention", "dense"),),
    kind="prefix_vlm", n_prefix=256,
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="pure full attention; long_500k SKIPPED; MQA (kv=1)",
))
