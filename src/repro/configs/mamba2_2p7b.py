"""Mamba2-2.7B [arXiv:2405.21060]: attention-free SSD, 64L, d_state=128."""
from repro.configs.base import register
from repro.models.config import ArchConfig

CONFIG = register(ArchConfig(
    name="mamba2-2.7b",
    n_layers=64, d_model=2560, vocab=50280,
    pattern=(("mamba", "none"),),
    ssm_state=128, ssm_groups=1, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="SSM; long_500k RUNS",
))
