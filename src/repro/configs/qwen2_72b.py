"""Qwen2-72B [arXiv:2407.10671]: dense GQA kv=8 with QKV bias."""
from repro.configs.base import register
from repro.models.config import ArchConfig

CONFIG = register(ArchConfig(
    name="qwen2-72b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064, qkv_bias=True,
    pattern=(("attention", "dense"),),
    dtype="bfloat16", param_dtype="bfloat16", remat="full",
    notes="pure full attention; long_500k SKIPPED",
))
