"""Mamba-2 SSD via the chunked (state-space dual) algorithm — pure JAX.

Within a chunk of length Q the recurrence is computed as a masked,
decay-weighted quadratic form (MXU-friendly); across chunks a short
``lax.scan`` carries the (h, p, s) state. Work: O(n Q (p + s)) + O(n p s)
vs O(n p s) sequential — but with Q-sized matmuls instead of a length-n
scan, which is the whole point on a systolic machine.

This is the XLA execution path; ``ssd_scan.py`` holds the Pallas TPU kernel
for the intra-chunk part and ``ref.ssd_scan_ref`` the sequential oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_chunked(x, dt, a, b, c, d_skip, *, chunk=64, hshard=None):
    """Shapes as ref.ssd_scan_ref: x (bt,n,h,p), dt (bt,n,h), a (h,),
    b/c (bt,n,g,s), d_skip (h,) -> y (bt,n,h,p).

    ``hshard(arr, h_axis)`` (optional) re-asserts the head-axis TP
    sharding on the chunk-state tensors: GSPMD loses it through the
    inter-chunk scan carry and silently replicates h=256 states —
    30 × 4.3 GiB/device at jamba train_4k (dry-run buffer dump,
    EXPERIMENTS §Perf)."""
    if hshard is None:
        hshard = lambda arr, ax: arr
    bt, n, h, p = x.shape
    g, s = b.shape[2], b.shape[3]
    q = min(chunk, n)
    pad = (-n) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // q

    f32 = jnp.float32
    xq = x.reshape(bt, nc, q, h, p).astype(f32)
    dtq = dt.reshape(bt, nc, q, h).astype(f32)
    bq = b.reshape(bt, nc, q, g, s).astype(f32)
    cq = c.reshape(bt, nc, q, g, s).astype(f32)
    hpg = h // g

    loga = dtq * a[None, None, None, :]            # (bt,nc,q,h)  <= 0
    cum = jnp.cumsum(loga, axis=2)                 # inclusive cumsum
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (bt,nc,q_i,q_j,h)
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: scores[i,j] = (C_i . B_j) * L[i,j] * dt[j]
    cb = jnp.einsum("bnigs,bnjgs->bnijg", cq, bq)          # (bt,nc,q,q,g)
    cb = jnp.repeat(cb, hpg, axis=4)                        # -> h
    scores = cb * l_mat * dtq[:, :, None, :, :]
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", scores, xq)

    # chunk-final states: S_k = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (bt,nc,q,h)
    bj = jnp.repeat(bq, hpg, axis=3)                        # (bt,nc,q,h,s)
    w = decay_to_end * dtq                                  # (bt,nc,q,h)
    s_chunk = jnp.einsum("bnjh,bnjhs,bnjhp->bnhps", w, bj, xq)
    s_chunk = hshard(s_chunk, 2)

    # inter-chunk recurrence over nc chunk states
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (bt,nc,h)

    def step(carry, inp):
        s_k, dec = inp                                      # (bt,h,p,s),(bt,h)
        new = carry * dec[:, :, None, None] + s_k
        return new, carry                                   # emit state BEFORE chunk

    s_seq = jnp.moveaxis(s_chunk, 1, 0)                     # (nc,bt,h,p,s)
    d_seq = jnp.moveaxis(chunk_decay, 1, 0)
    init = hshard(jnp.zeros((bt, h, p, s), f32), 1)
    _, prev_states = jax.lax.scan(step, init, (s_seq, d_seq))
    prev = hshard(jnp.moveaxis(prev_states, 0, 1), 2)       # (bt,nc,h,p,s)

    # inter contribution: C_i . (prev_state * exp(cum_i))
    cj = jnp.repeat(cq, hpg, axis=3)                        # (bt,nc,q,h,s)
    y_inter = jnp.einsum("bnihs,bnhps,bnih->bnihp", cj, prev, jnp.exp(cum))

    y = (y_intra + y_inter).reshape(bt, nc * q, h, p)[:, :n]
    y = y + x.reshape(bt, nc * q, h, p)[:, :n] * d_skip[None, None, :, None]
    return y.astype(jnp.result_type(x.dtype))


def ssd_decode_step(state, x_t, dt_t, a, b_t, c_t, d_skip):
    """Single-token recurrent update for serving.

    state: (bt, h, p, s); x_t (bt,h,p); dt_t (bt,h); b_t/c_t (bt,g,s).
    Returns (new_state, y_t (bt,h,p)).
    """
    h = x_t.shape[1]
    g = b_t.shape[1]
    hpg = h // g
    bx = jnp.repeat(b_t, hpg, axis=1)   # (bt,h,s)
    cx = jnp.repeat(c_t, hpg, axis=1)
    da = jnp.exp(dt_t * a[None, :])     # (bt,h)
    new = state * da[..., None, None] + (
        (dt_t[..., None] * x_t)[..., :, None] * bx[..., None, :])
    y = jnp.einsum("bhps,bhs->bhp", new, cx) + x_t * d_skip[None, :, None]
    return new, y
