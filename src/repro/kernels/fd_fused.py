"""Fused causal FD-TNO Pallas pipeline (paper §3.3, Algorithm 2).

The causal frequency-domain TNO models only the *real* part ``khat`` of the
kernel's frequency response on the rfft grid and recovers the imaginary
part with a discrete Hilbert transform (``khat - i·H{khat}``, realised as
the analytic-signal window in the lag variable — the numerically-exact
form pinned by tests/test_hilbert.py). The jnp path in core/fd.py runs
this as five separate XLA ops with the (b, n+1, d) complex spectrum
crossing HBM between each. This module is the FD sibling of the fused SKI
stack (kernels/ski_fused.py + ski_vjp.py): the (i)rfft stages remain XLA
FFTs (Pallas has no FFT primitive; the FFTs are the only super-linear
work), and everything *between* them is fused into blocked Pallas kernels:

* ``hilbert_window_pallas`` — the analytic-signal lag window (1, 2, …, 2,
  1, 0, …, 0) applied to the kernel's time response, blocked over
  (d-tile, lag-tile), the window regenerated in-kernel from iota. The
  window is diagonal ⇒ self-adjoint: its custom VJP is the same kernel.
* ``fd_spectral_multiply_pallas`` — the per-channel complex spectral
  multiply ŷ = x̂ ⊙ k̂ on re/im planes (Pallas TPU has no complex dtype),
  blocked over (batch, freq-tile, d-tile): both output planes produced by
  one kernel / one read of x̂ — the (b, n+1, d) round-trips between
  ``real·real``/``imag·imag`` element-wise ops collapse into one pass.
* ``fd_khat_grad_pallas`` — the backward's per-tile reduction kernel:
  Σ_b ĝ ⊙ conj(x̂) accumulated over the innermost batch grid axis
  (consecutive output revisits — the safe Pallas accumulation pattern,
  same as ski_grad).

The differentiable op is :func:`fd_tno_pallas` (dispatched by
``ops.fd_tno``): a ``jax.custom_vjp`` whose backward *reuses the forward
multiply kernel with the spectrum conjugated* — the adjoint of a causal
circular convolution is the anticausal correlation, i.e. the identical
pipeline with k̂ → conj(k̂) — plus the reduction kernel for the k̂
cotangent. All cotangents are exact linear-operator adjoints (circular
correlation theorem), not FFT-adjoint approximations:

    y   = slice_n( irfft( rfft(pad x) ⊙ k̂ ) )       k̂ = rfft(w ⊙ irfft(khat))
    dx  = slice_n( irfft( rfft(pad g) ⊙ conj k̂ ) )   forward kernel, conj spectrum
    dk̂_time = irfft( Σ_b rfft(pad g) ⊙ conj(rfft(pad x)) )   reduction kernel
    dkhat   = irfftᵀ( w ⊙ dk̂_time )                 window kernel again (wᵀ = w)

Residual policy matches the SKI ops: inputs only (x, khat_real); the
spectra are recomputed in the backward. ``REPRO_PALLAS_GRAD=0`` swaps the
backward to the jnp reference cotangents (counters record which path ran —
no silent fallback, the ski_vjp contract).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend

# trace-time instrumentation, same contract as kernels/ski_vjp.py: tests
# (and the trainer banner) assert training never silently falls back to
# the jnp reference path
counters = {"fwd": 0, "bwd_kernel": 0, "bwd_ref": 0}


def reset_counters() -> None:
    for k in counters:
        counters[k] = 0


# ------------------------------------------------------ hilbert lag window
def _window_kernel(k_ref, o_ref, *, n, bt):
    ti = pl.program_id(1)
    t = jax.lax.broadcasted_iota(jnp.int32, k_ref.shape, 1) + ti * bt
    w = jnp.where((t == 0) | (t == n), 1.0,
                  jnp.where(t < n, 2.0, 0.0))
    o_ref[...] = (k_ref[...].astype(jnp.float32) * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "interpret", "bd", "bt"))
def _window_call(kt, n: int, *, interpret, bd, bt):
    d, tt = kt.shape
    grid = (d // bd, tt // bt)
    return pl.pallas_call(
        functools.partial(_window_kernel, n=n, bt=bt),
        grid=grid,
        in_specs=[pl.BlockSpec((bd, bt), lambda di, ti: (di, ti))],
        out_specs=pl.BlockSpec((bd, bt), lambda di, ti: (di, ti)),
        out_shape=jax.ShapeDtypeStruct((d, tt), kt.dtype),
        interpret=interpret,
    )(kt)


def _window_padded(kt, n, interpret, bd, bt):
    d, tt = kt.shape
    dp, tp = backend.round_up(d, bd), backend.round_up(tt, bt)
    if dp != d or tp != tt:
        out = _window_call(jnp.pad(kt, ((0, dp - d), (0, tp - tt))), n,
                           interpret=interpret, bd=bd, bt=bt)
        return out[:d, :tt]
    return _window_call(kt, n, interpret=interpret, bd=bd, bt=bt)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4))
def _window_core(kt, n, interpret, bd, bt):
    return _window_padded(kt, n, interpret, bd, bt)


def _window_core_fwd(kt, n, interpret, bd, bt):
    return _window_core(kt, n, interpret, bd, bt), None


def _window_core_bwd(n, interpret, bd, bt, res, g):
    del res                                   # diagonal window: residual-free
    if not backend.resolve_pallas_grad():
        from repro.kernels import ref
        return (ref.hilbert_window_ref(g, n),)
    return (_window_padded(g, n, interpret, bd, bt),)


_window_core.defvjp(_window_core_fwd, _window_core_bwd)


def hilbert_window_pallas(kt, n: int, *, interpret=None, bd=None, bt=None):
    """Analytic-signal lag window of :func:`repro.core.hilbert.causal_spectrum`:
    keep lag 0 and lag n, double lags 1..n-1, zero lags n+1..  (causal ⇒
    the irfft of the windowed response vanishes on negative lags exactly).

    kt: (d, 2n) time response (``irfft(khat_real)``). The window is
    diagonal, hence self-adjoint — differentiable via a custom VJP that is
    this same kernel. Matches ref.hilbert_window_ref.
    """
    d, tt = kt.shape
    interpret = backend.resolve_interpret(interpret)
    if bd is None or bt is None:
        tune = None
        if backend.is_concrete(kt):
            tune = lambda BD, BT: _window_padded(kt, n, interpret, BD, BT)
        # get_blocks keys on (sublane-dim, lane-dim): here (d, lag)
        hbd, hbt = backend.get_blocks("hilbert_window", d, tt, kt.dtype,
                                      interpret,
                                      tune_call=tune, extra=f"n={n}")
        bd = bd or hbd
        bt = bt or hbt
    bd, bt = backend.clamp_blocks(bd, bt, d, tt, interpret)
    return _window_core(kt, int(n), interpret, bd, bt)


# ------------------------------------------------- complex spectral multiply
def _mul_kernel(xr_ref, xi_ref, kr_ref, ki_ref, yr_ref, yi_ref):
    xr = xr_ref[0].astype(jnp.float32)
    xi = xi_ref[0].astype(jnp.float32)
    kr = kr_ref[...].astype(jnp.float32)
    ki = ki_ref[...].astype(jnp.float32)
    yr_ref[0] = (xr * kr - xi * ki).astype(yr_ref.dtype)
    yi_ref[0] = (xr * ki + xi * kr).astype(yi_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "bf", "bd"))
def _mul_call(xr, xi, kr, ki, *, interpret, bf, bd):
    b, f, d = xr.shape
    grid = (b, d // bd, f // bf)
    xspec = pl.BlockSpec((1, bf, bd), lambda bi, di, fi: (bi, fi, di))
    kspec = pl.BlockSpec((bf, bd), lambda bi, di, fi: (fi, di))
    out = jax.ShapeDtypeStruct((b, f, d), jnp.float32)
    return pl.pallas_call(
        _mul_kernel,
        grid=grid,
        in_specs=[xspec, xspec, kspec, kspec],
        out_specs=[pl.BlockSpec((1, bf, bd), lambda bi, di, fi: (bi, fi, di))] * 2,
        out_shape=[out, out],
        interpret=interpret,
    )(xr, xi, kr, ki)


def _mul_padded(xr, xi, kr, ki, interpret, bf, bd):
    b, f, d = xr.shape
    fp, dp = backend.round_up(f, bf), backend.round_up(d, bd)
    if fp != f or dp != d:
        padx = ((0, 0), (0, fp - f), (0, dp - d))
        padk = ((0, fp - f), (0, dp - d))
        yr, yi = _mul_call(jnp.pad(xr, padx), jnp.pad(xi, padx),
                           jnp.pad(kr, padk), jnp.pad(ki, padk),
                           interpret=interpret, bf=bf, bd=bd)
        return yr[:, :f, :d], yi[:, :f, :d]
    return _mul_call(xr, xi, kr, ki, interpret=interpret, bf=bf, bd=bd)


def fd_spectral_multiply_pallas(xr, xi, kr, ki, *, interpret=None, bf=None,
                                bd=None):
    """Per-channel complex spectral multiply on re/im planes, one kernel.

    xr, xi: (b, F, d) signal-spectrum planes (F = n+1 rfft bins);
    kr, ki: (F, d) kernel-spectrum planes. Returns (yr, yi), fp32.
    Matches ref.fd_spectral_multiply_ref. The backward sibling is this
    same kernel with the kernel spectrum conjugated (ki → -ki) — see
    :func:`fd_tno_pallas`.
    """
    b, f, d = xr.shape
    interpret = backend.resolve_interpret(interpret)
    if bf is None or bd is None:
        tune = None
        if backend.is_concrete(xr, xi, kr, ki):
            tune = lambda BF, BD: _mul_padded(xr, xi, kr, ki, interpret,
                                              BF, BD)
        hbf, hbd = backend.get_blocks("fd_mul", f, d, xr.dtype, interpret,
                                      tune_call=tune)
        bf = bf or hbf
        bd = bd or hbd
    bf, bd = backend.clamp_blocks(bf, bd, f, d, interpret)
    return _mul_padded(xr, xi, kr, ki, interpret, bf, bd)


# --------------------------------------------------- khat cotangent reduce
def _khat_grad_kernel(gr_ref, gi_ref, xr_ref, xi_ref, dr_ref, di_ref):
    bi = pl.program_id(2)
    gr = gr_ref[0].astype(jnp.float32)
    gi = gi_ref[0].astype(jnp.float32)
    xr = xr_ref[0].astype(jnp.float32)
    xi = xi_ref[0].astype(jnp.float32)
    pr = gr * xr + gi * xi                    # Re(ĝ conj(x̂))
    pi = gi * xr - gr * xi                    # Im(ĝ conj(x̂))

    @pl.when(bi == 0)
    def _init():
        dr_ref[...] = pr
        di_ref[...] = pi

    @pl.when(bi > 0)
    def _acc():
        dr_ref[...] = dr_ref[...] + pr
        di_ref[...] = di_ref[...] + pi


@functools.partial(jax.jit, static_argnames=("interpret", "bf", "bd"))
def _khat_grad_call(gr, gi, xr, xi, *, interpret, bf, bd):
    b, f, d = xr.shape
    grid = (d // bd, f // bf, b)              # batch innermost: consecutive
    xspec = pl.BlockSpec((1, bf, bd), lambda di, fi, bi: (bi, fi, di))
    ospec = pl.BlockSpec((bf, bd), lambda di, fi, bi: (fi, di))
    out = jax.ShapeDtypeStruct((f, d), jnp.float32)
    return pl.pallas_call(
        _khat_grad_kernel,
        grid=grid,
        in_specs=[xspec] * 4,
        out_specs=[ospec] * 2,
        out_shape=[out, out],
        interpret=interpret,
    )(gr, gi, xr, xi)


def fd_khat_grad_pallas(gr, gi, xr, xi, *, interpret=None, bf=None, bd=None):
    """Per-tile batch-reduction of the kernel-spectrum cotangent:
    (dkr, dki) = planes of Σ_b ĝ ⊙ conj(x̂) → (F, d) fp32 each.

    The irfft of this is *exactly* the time-domain cotangent of the causal
    kernel (circular correlation theorem) — no FFT-adjoint scaling enters.
    Matches ref.fd_khat_grad_ref.
    """
    b, f, d = xr.shape
    interpret = backend.resolve_interpret(interpret)
    if bf is None or bd is None:
        bf, bd = backend.get_blocks("fd_khat_grad", f, d, xr.dtype, interpret)
    bf, bd = backend.clamp_blocks(bf, bd, f, d, interpret)
    fp, dp = backend.round_up(f, bf), backend.round_up(d, bd)
    if fp != f or dp != d:
        pad = ((0, 0), (0, fp - f), (0, dp - d))
        dr, di = _khat_grad_call(jnp.pad(gr, pad), jnp.pad(gi, pad),
                                 jnp.pad(xr, pad), jnp.pad(xi, pad),
                                 interpret=interpret, bf=bf, bd=bd)
        return dr[:f, :d], di[:f, :d]
    return _khat_grad_call(gr, gi, xr, xi, interpret=interpret, bf=bf, bd=bd)


# --------------------------------------------------------- the fused op
def causal_khat_planes(khat_real, interpret=None):
    """(d, n+1) real response → ((n+1), d) re/im planes of the causal
    spectrum ``khat - i·H{khat}``, the Hilbert step realised as the
    analytic lag window (Pallas) between the two staging FFTs.

    Differentiable: the window kernel carries its own custom VJP and the
    FFT stages use XLA's exact adjoints, so ``jax.vjp`` through this is
    exact (used by the op backward for the parameter-side pullback).
    """
    n = khat_real.shape[-1] - 1
    kt = jnp.fft.irfft(khat_real.astype(jnp.float32), n=2 * n, axis=-1)
    kc = hilbert_window_pallas(kt, n, interpret=interpret)
    khat = jnp.fft.rfft(kc, n=2 * n, axis=-1)                # (d, n+1)
    return jnp.real(khat).T, jnp.imag(khat).T                # (n+1, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fd_tno_pallas(x, khat_real, interpret: bool):
    """Causal FD-TNO as one differentiable op: y = irfft(rfft(x) ⊙ k̂)[:n]
    with k̂ the Hilbert-completed causal spectrum of ``khat_real``.

    x: (b, n, d); khat_real: (d, n+1) real response on the rfft grid
    (the raw RPE output — no decay bias, paper §3.3). Matches
    ref.fd_tno_ref. ``interpret`` must be resolved by the caller (static
    nondiff argument).
    """
    b, n, d = x.shape
    kr, ki = causal_khat_planes(khat_real, interpret)
    xhat = jnp.fft.rfft(x.astype(jnp.float32), n=2 * n, axis=1)  # (b,n+1,d)
    yr, yi = fd_spectral_multiply_pallas(jnp.real(xhat), jnp.imag(xhat),
                                         kr, ki, interpret=interpret)
    y = jnp.fft.irfft(yr + 1j * yi, n=2 * n, axis=1)[:, :n]
    return y.astype(x.dtype)


def _fd_fwd(x, khat_real, interpret):
    counters["fwd"] += 1
    return fd_tno_pallas(x, khat_real, interpret), (x, khat_real)


def _fd_bwd_ref_formulas(x, khat_real, g):
    from repro.kernels import ref
    _, vjp = jax.vjp(ref.fd_tno_ref, x, khat_real)
    return vjp(g)


def _fd_bwd(interpret, res, g):
    x, khat_real = res
    if not backend.resolve_pallas_grad():
        counters["bwd_ref"] += 1
        return _fd_bwd_ref_formulas(x, khat_real, g)
    counters["bwd_kernel"] += 1
    b, n, d = x.shape
    # recompute both spectra from the saved inputs (residuals = inputs only)
    kr, ki = causal_khat_planes(khat_real, interpret)
    xhat = jnp.fft.rfft(x.astype(jnp.float32), n=2 * n, axis=1)
    ghat = jnp.fft.rfft(g.astype(jnp.float32), n=2 * n, axis=1)
    gr, gi = jnp.real(ghat), jnp.imag(ghat)
    # signal cotangent: the forward multiply kernel with the spectrum
    # conjugated — adjoint of causal conv = anticausal correlation
    dxr, dxi = fd_spectral_multiply_pallas(gr, gi, kr, -ki,
                                           interpret=interpret)
    dx = jnp.fft.irfft(dxr + 1j * dxi, n=2 * n, axis=1)[:, :n]
    # kernel cotangent: per-tile reduction Σ_b ĝ ⊙ conj(x̂); its irfft is
    # exactly the time cotangent of the causal kernel, then the (self-
    # adjoint) lag window and the exact irfft adjoint pull it back to
    # khat_real
    dkr, dki = fd_khat_grad_pallas(gr, gi, jnp.real(xhat), jnp.imag(xhat),
                                   interpret=interpret)
    dkc = jnp.fft.irfft((dkr + 1j * dki).T, n=2 * n, axis=-1)    # (d, 2n)
    dkt = hilbert_window_pallas(dkc, n, interpret=interpret)
    _, irfft_vjp = jax.vjp(
        lambda k: jnp.fft.irfft(k.astype(jnp.float32), n=2 * n, axis=-1),
        khat_real)
    (dkhat_real,) = irfft_vjp(dkt)
    return dx.astype(x.dtype), dkhat_real.astype(khat_real.dtype)


fd_tno_pallas.defvjp(_fd_fwd, _fd_bwd)
