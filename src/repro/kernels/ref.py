"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the semantics contract: each Pallas kernel must match its oracle
to float tolerance across shape/dtype sweeps (tests/test_kernels.py), and
they double as the CPU/dry-run execution path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- short conv
def short_conv_ref(x: jax.Array, filt: jax.Array, causal: bool) -> jax.Array:
    """Depthwise short 1-D convolution — the sparse Toeplitz component.

    x: (b, n, d); filt: (d, m) per-channel taps.
    causal: taps cover lags 0..m-1 (y_i = sum_k f[k] x_{i-k}).
    bidirectional: taps cover lags -(m//2) .. m-1-m//2 (centered).
    Returns (b, n, d). (Shift-add and custom-VJP variants were benchmarked
    on XLA:CPU and lose to the grouped conv once backward is included —
    EXPERIMENTS §Perf; the TPU path is the Pallas kernel.)
    """
    b, n, d = x.shape
    m = filt.shape[-1]
    left = 0 if causal else m // 2
    dn = jax.lax.conv_dimension_numbers(
        (b, n + m - 1, d), (m, 1, d), ("NHC", "HIO", "NHC"))
    # depthwise: feature_group_count = d, kernel (m, 1, d)
    k = jnp.flip(filt, axis=-1).T[:, None, :]  # (m, 1, d): cross-corr->conv
    # pad so output index i reads lags (i - k + left) for k = 0..m-1
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (m - 1 - left, left), (0, 0)))
    y = jax.lax.conv_general_dilated(
        xp, k.astype(jnp.float32), (1,), "VALID",
        dimension_numbers=dn, feature_group_count=d)
    return y.astype(x.dtype)


# -------------------------------------------------- banded interp (SKI W)
def interp_reduce_ref(x: jax.Array, idx_lo: jax.Array, w_lo: jax.Array,
                      r: int) -> jax.Array:
    """z = W^T x for banded linear-interpolation W (paper §3.2.1).

    x: (b, n, d) -> (b, r, d). Implemented as the DENSE hat-weight matmul
    (W is (n, r), < 1 MB): the paper's own §3.2.1 observation — on
    accelerators (and XLA:CPU) the batched dense contraction beats
    sparse scatter/gather up to large n. The O(n) banded form lives in
    the Pallas kernel; a scatter oracle remains below for tests.
    """
    w = dense_interp_matrix(idx_lo, w_lo, r)          # (n, r)
    z = jnp.einsum("nr,bnd->brd", w, x.astype(jnp.float32))
    return z.astype(x.dtype)


def interp_reduce_scatter_oracle(x, idx_lo, w_lo, r):
    """Two-scatter-add O(n) oracle (tests only)."""
    xl = x.astype(jnp.float32) * w_lo[None, :, None]
    xh = x.astype(jnp.float32) * (1.0 - w_lo)[None, :, None]
    z = jnp.zeros((x.shape[0], r, x.shape[2]), jnp.float32)
    z = z.at[:, idx_lo, :].add(xl)
    z = z.at[:, idx_lo + 1, :].add(xh)
    return z.astype(x.dtype)


def interp_expand_ref(z: jax.Array, idx_lo: jax.Array,
                      w_lo: jax.Array) -> jax.Array:
    """y = W z, dense hat-weight form. z: (b, r, d) -> (b, n, d)."""
    r = z.shape[1]
    w = dense_interp_matrix(idx_lo, w_lo, r)          # (n, r)
    y = jnp.einsum("nr,brd->bnd", w, z.astype(jnp.float32))
    return y.astype(z.dtype)


def dense_interp_matrix(idx_lo: jax.Array, w_lo: jax.Array, r: int):
    """Materialised (n, r) W for oracle comparisons in tests."""
    n = idx_lo.shape[0]
    w = jnp.zeros((n, r), jnp.float32)
    w = w.at[jnp.arange(n), idx_lo].add(w_lo)
    w = w.at[jnp.arange(n), idx_lo + 1].add(1.0 - w_lo)
    return w


# ------------------------------------------------------------- mamba2 SSD
def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, d_skip: jax.Array) -> jax.Array:
    """Mamba-2 SSD sequential oracle (state-space recurrence).

    x: (bt, n, h, p)   inputs per head (p = head dim)
    dt: (bt, n, h)     softplus'd step sizes (>0)
    a: (h,)            negative state decay rates (A = -exp(a_log))
    b: (bt, n, g, s)   input projections  (g groups, s = state dim)
    c: (bt, n, g, s)   output projections
    d_skip: (h,)       skip connection
    Returns y: (bt, n, h, p).
    """
    bt, n, h, p = x.shape
    g = b.shape[2]
    heads_per_group = h // g
    bx = jnp.repeat(b, heads_per_group, axis=2)  # (bt, n, h, s)
    cx = jnp.repeat(c, heads_per_group, axis=2)

    da = jnp.exp(dt * a[None, None, :])  # (bt, n, h) decay per step

    def step(carry, inp):
        xt, dtt, dat, bt_, ct_ = inp
        # state: (bt, h, p, s)
        new = carry * dat[..., None, None] + (
            (dtt[..., None] * xt)[..., :, None] * bt_[..., None, :])
        y = jnp.einsum("bhps,bhs->bhp", new, ct_)
        return new, y

    x_ = jnp.moveaxis(x.astype(jnp.float32), 1, 0)
    dt_ = jnp.moveaxis(dt.astype(jnp.float32), 1, 0)
    da_ = jnp.moveaxis(da.astype(jnp.float32), 1, 0)
    b_ = jnp.moveaxis(bx.astype(jnp.float32), 1, 0)
    c_ = jnp.moveaxis(cx.astype(jnp.float32), 1, 0)
    init = jnp.zeros((bt, h, p, b.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(step, init, (x_, dt_, da_, b_, c_))
    y = jnp.moveaxis(ys, 0, 1)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype)
