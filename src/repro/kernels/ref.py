"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the semantics contract: each Pallas kernel must match its oracle
to float tolerance across shape/dtype sweeps (tests/test_kernels.py), and
they double as the CPU/dry-run execution path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


# ------------------------------------------------------------- short conv
def _shift_conv(x, filt, left):
    """y[:, j] = sum_k f_k x[:, j-k+left] via m shifted multiply-adds over a
    zero-padded copy — 3-4x faster than conv_general_dilated's depthwise
    lowering on XLA:CPU (memory-bound slices vs grouped conv)."""
    n = x.shape[1]
    m = filt.shape[-1]
    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, 0), (m - 1 - left, left), (0, 0)))
    f = filt.astype(jnp.float32)
    acc = jnp.zeros(x.shape, jnp.float32)
    for k in range(m):
        acc = acc + xp[:, m - 1 - k:m - 1 - k + n, :] * f[:, k][None, None, :]
    return acc


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def short_conv_ref(x: jax.Array, filt: jax.Array, causal: bool) -> jax.Array:
    """Depthwise short 1-D convolution — the sparse Toeplitz component.

    x: (b, n, d); filt: (d, m) per-channel taps.
    causal: taps cover lags 0..m-1 (y_i = sum_k f[k] x_{i-k}).
    bidirectional: taps cover lags -(m//2) .. m-1-m//2 (centered).
    Returns (b, n, d).

    Forward is the shift-add form (beats the grouped-conv lowering ~3.4x
    on XLA:CPU at bench shapes). Plain autodiff of shift-add transposes to
    32 scatter-adds (~3x slower than the conv backward — EXPERIMENTS
    §Perf), so the VJP is supplied analytically: both cotangents are
    themselves shift-convs. Being a custom_vjp, forward-mode AD
    (jvp/jacfwd) is unsupported through this op; the repo trains with
    reverse mode only. The TPU path is the Pallas kernel.
    """
    m = filt.shape[-1]
    left = 0 if causal else m // 2
    return _shift_conv(x, filt, left).astype(x.dtype)


def _short_conv_fwd(x, filt, causal):
    return short_conv_ref(x, filt, causal), (x, filt)


def conv_tap_grad_ref(g, x, m: int, left: int) -> jax.Array:
    """Filter cotangent: df[c,k] = Σ_{b,j} g[b,j,c] x[b,j-k+left,c] → (d, m).

    Oracle for kernels/ski_grad.conv_tap_grad_pallas. fp32 output."""
    n = x.shape[1]
    gf = g.astype(jnp.float32)
    xp = jnp.pad(x.astype(jnp.float32),
                 ((0, 0), (m - 1 - left, left), (0, 0)))
    return jnp.stack(
        [jnp.einsum("bnc,bnc->c", gf, xp[:, m - 1 - k:m - 1 - k + n, :])
         for k in range(m)], axis=-1)                       # (d, m)


def _short_conv_bwd(causal, res, g):
    x, filt = res
    m = filt.shape[-1]
    left = 0 if causal else m // 2
    # dx: correlation = conv with flipped taps and mirrored offset
    dx = _shift_conv(g, jnp.flip(filt, axis=-1), m - 1 - left)
    df = conv_tap_grad_ref(g, x, m, left)
    return dx.astype(x.dtype), df.astype(filt.dtype)


short_conv_ref.defvjp(_short_conv_fwd, _short_conv_bwd)


def short_conv_left_ref(x, filt, left: int) -> jax.Array:
    """Generalised-offset shift conv (differentiable via plain autodiff);
    used by the Pallas wrappers' tiny-n fallback for backward-sibling
    launches whose ``left`` is not causal-derived."""
    return _shift_conv(x, filt, left).astype(x.dtype)


# -------------------------------------------------- banded interp (SKI W)
def interp_reduce_ref(x: jax.Array, idx_lo: jax.Array, w_lo: jax.Array,
                      r: int) -> jax.Array:
    """z = W^T x for banded linear-interpolation W (paper §3.2.1).

    x: (b, n, d) -> (b, r, d). Implemented as the DENSE hat-weight matmul
    (W is (n, r), < 1 MB): the paper's own §3.2.1 observation — on
    accelerators (and XLA:CPU) the batched dense contraction beats
    sparse scatter/gather up to large n. The O(n) banded form lives in
    the Pallas kernel; a scatter oracle remains below for tests.
    """
    w = dense_interp_matrix(idx_lo, w_lo, r)          # (n, r)
    z = jnp.einsum("nr,bnd->brd", w, x.astype(jnp.float32))
    return z.astype(x.dtype)


def interp_reduce_scatter_oracle(x, idx_lo, w_lo, r):
    """Two-scatter-add O(n) oracle (tests only)."""
    xl = x.astype(jnp.float32) * w_lo[None, :, None]
    xh = x.astype(jnp.float32) * (1.0 - w_lo)[None, :, None]
    z = jnp.zeros((x.shape[0], r, x.shape[2]), jnp.float32)
    z = z.at[:, idx_lo, :].add(xl)
    z = z.at[:, idx_lo + 1, :].add(xh)
    return z.astype(x.dtype)


def interp_expand_ref(z: jax.Array, idx_lo: jax.Array,
                      w_lo: jax.Array) -> jax.Array:
    """y = W z, dense hat-weight form. z: (b, r, d) -> (b, n, d)."""
    r = z.shape[1]
    w = dense_interp_matrix(idx_lo, w_lo, r)          # (n, r)
    y = jnp.einsum("nr,brd->bnd", w, z.astype(jnp.float32))
    return y.astype(z.dtype)


def dense_interp_matrix(idx_lo: jax.Array, w_lo: jax.Array, r: int):
    """Materialised (n, r) W for oracle comparisons in tests."""
    n = idx_lo.shape[0]
    w = jnp.zeros((n, r), jnp.float32)
    w = w.at[jnp.arange(n), idx_lo].add(w_lo)
    w = w.at[jnp.arange(n), idx_lo + 1].add(1.0 - w_lo)
    return w


def hat_interp_matrix(n: int, r: int):
    """(n, r) W regenerated from the uniform grid alone — identical
    construction to core.ski.make_inducing, but importable from the kernel
    layer (used by the reference cotangent formulas)."""
    h = (n - 1) / (r - 1)
    f = jnp.arange(n, dtype=jnp.float32) / h
    lo = jnp.clip(jnp.floor(f).astype(jnp.int32), 0, r - 2)
    w_lo = jnp.clip(1.0 - (f - lo.astype(jnp.float32)), 0.0, 1.0)
    return dense_interp_matrix(lo, w_lo, r)


def gram_grad_ref(gz: jax.Array, z: jax.Array) -> jax.Array:
    """Gram cotangent: dA[c,s,t] = Σ_b gz[b,s,c] z[b,t,c] → (d, r, r).

    Oracle for kernels/ski_grad.gram_grad_pallas. fp32 output."""
    return jnp.einsum("bsc,btc->cst", gz.astype(jnp.float32),
                      z.astype(jnp.float32))


# ----------------------------------------------------- fused SKI pass 2
def ski_expand_pass2_ref(x: jax.Array, z2: jax.Array, filt: jax.Array,
                         causal: bool, left: int | None = None) -> jax.Array:
    """Gram-free half of pass 2: y = W z2 + T_sparse x.

    x: (b, n, d); z2 = A (Wᵀx): (b, r, d); filt: (d, m). This is the
    oracle for kernels/ski_fused.ski_expand_pass2_pallas (the FFT-Gram
    variant's second pass — the Gram matvec already happened) and the
    shared tail of :func:`ski_fused_pass2_ref`.

    The expansion uses W's banded structure (≤2 non-zeros/row → two row
    gathers + blend, the paper's O(n) action) instead of the dense (n, r)
    matmul: O(n d) memory-bound vs O(n r d) MACs — the big CPU win of the
    fused pipeline at bench shapes. The Pallas kernel keeps the dense-hat
    MXU form (TPU crossover, kernels/interp_matvec.py docstring).
    """
    n = x.shape[1]
    r = z2.shape[1]
    m = filt.shape[-1]
    # banded W row weights, identical construction to ski.make_inducing
    h = (n - 1) / (r - 1)
    f = jnp.arange(n, dtype=jnp.float32) / h
    lo = jnp.clip(jnp.floor(f).astype(jnp.int32), 0, r - 2)
    w_lo = jnp.clip(1.0 - (f - lo.astype(jnp.float32)), 0.0, 1.0)[None, :, None]
    z2 = z2.astype(jnp.float32)
    y = w_lo * z2[:, lo, :] + (1.0 - w_lo) * z2[:, lo + 1, :]
    if left is None or left == (0 if causal else m // 2):
        y_sp = short_conv_ref(x, filt, causal)    # analytic custom-VJP form
    else:
        y_sp = short_conv_left_ref(x, filt, left)
    y = y + y_sp.astype(jnp.float32)
    return y.astype(x.dtype)


def ski_fused_pass2_ref(x: jax.Array, z: jax.Array, a_dense: jax.Array,
                        filt: jax.Array, causal: bool,
                        left: int | None = None) -> jax.Array:
    """Oracle for kernels/ski_fused.py: y = W (A z) + T_sparse x.

    x: (b, n, d); z = Wᵀx: (b, r, d); a_dense: (d, r, r); filt: (d, m).
    fp32 accumulation throughout, cast back to x.dtype at the end.
    ``left`` overrides the causal-derived tap offset (backward siblings).
    """
    z2 = jnp.einsum("dst,btd->bsd", a_dense.astype(jnp.float32),
                    z.astype(jnp.float32))
    return ski_expand_pass2_ref(x, z2, filt, causal, left=left)


def ski_fused_tno_ref(x: jax.Array, a_dense: jax.Array, filt: jax.Array,
                      idx_lo: jax.Array, w_lo: jax.Array, r: int,
                      causal: bool) -> jax.Array:
    """Reference two-pass fused SKI-TNO: y = W (A (Wᵀ x)) + T_sparse x.

    Semantics contract for kernels/ski_vjp.ski_fused_tno_pallas; fully
    differentiable in (x, a_dense, filt) via plain autodiff (+ the
    short-conv analytic VJP)."""
    z = interp_reduce_ref(x, idx_lo, w_lo, r)
    return ski_fused_pass2_ref(x, z, a_dense, filt, causal)


def toeplitz_gram_matvec_ref(a_coef: jax.Array, z: jax.Array) -> jax.Array:
    """z2 = A z for the COEFFICIENT-form Gram: a_coef (d, 2r-1) Toeplitz
    lags -(r-1)..(r-1); z (b, r, d) -> (b, r, d). O(r log r) circulant
    rfft/irfft — the only Gram action that exists at large rank, where
    the dense (d, r, r) materialisation does not fit (r=8192, d=64 →
    16 GB)."""
    from repro.core import toeplitz
    zt = jnp.swapaxes(z, 1, 2)                              # (b, d, r)
    z2t = toeplitz.toeplitz_matvec(a_coef[None], zt)
    return jnp.swapaxes(z2t, 1, 2)                          # (b, r, d)


def ski_fused_tno_coef_ref(x: jax.Array, a_coef: jax.Array, filt: jax.Array,
                           idx_lo: jax.Array, w_lo: jax.Array, r: int,
                           causal: bool) -> jax.Array:
    """Large-rank fused SKI-TNO, coefficient form: the semantics contract
    for BOTH kernels/ski_vjp.ski_fused_tno_coef_pallas variants (windowed
    banded-W and FFT-Gram — they are two execution strategies for the same
    operator). a_coef: (d, 2r-1). Differentiable via plain autodiff."""
    z = interp_reduce_ref(x, idx_lo, w_lo, r)
    z2 = toeplitz_gram_matvec_ref(a_coef, z)
    return ski_expand_pass2_ref(x, z2, filt, causal)


def gram_coef_grad_ref(gz: jax.Array, z: jax.Array) -> jax.Array:
    """Coefficient-Gram cotangent oracle (small r, O(r²) — tests only):
    dcoef[c, k] = Σ_{b, s-t = k-(r-1)} gz[b,s,c] z[b,t,c] → (d, 2r-1),
    i.e. the diagonal sums of the dense Gram cotangent gz zᵀ. The
    production form is kernels/ski_grad.gram_coef_grad_fft."""
    r = z.shape[1]
    da = gram_grad_ref(gz, z)                               # (d, r, r)
    i = jnp.arange(r)
    lag = i[:, None] - i[None, :] + (r - 1)                 # (r, r) in [0, 2r-2]
    out = jnp.zeros((z.shape[2], 2 * r - 1), jnp.float32)
    return out.at[:, lag].add(da)


# ------------------------------------------------------- causal FD-TNO
def hilbert_window_ref(kt: jax.Array, n: int) -> jax.Array:
    """Analytic-signal lag window (paper §3.3.1 Hilbert step in the lag
    variable): keep lag 0 and lag n, double lags 1..n-1, zero the rest.
    kt: (d, T) with T >= n+1 (normally T = 2n). Oracle for
    kernels/fd_fused.hilbert_window_pallas; diagonal ⇒ self-adjoint."""
    t = jnp.arange(kt.shape[-1])
    w = jnp.where((t == 0) | (t == n), 1.0,
                  jnp.where(t < n, 2.0, 0.0))
    return (kt.astype(jnp.float32) * w[None]).astype(kt.dtype)


def fd_spectral_multiply_ref(xr, xi, kr, ki):
    """Complex spectral multiply on planes: ŷ = x̂ ⊙ k̂ per channel.
    xr, xi: (b, F, d); kr, ki: (F, d). Oracle for
    kernels/fd_fused.fd_spectral_multiply_pallas. fp32 outputs."""
    xr = xr.astype(jnp.float32)
    xi = xi.astype(jnp.float32)
    kr = kr.astype(jnp.float32)[None]
    ki = ki.astype(jnp.float32)[None]
    return xr * kr - xi * ki, xr * ki + xi * kr


def fd_khat_grad_ref(gr, gi, xr, xi):
    """Kernel-spectrum cotangent planes: Σ_b ĝ ⊙ conj(x̂) → (F, d) each.
    Oracle for kernels/fd_fused.fd_khat_grad_pallas. fp32 outputs."""
    gr = gr.astype(jnp.float32)
    gi = gi.astype(jnp.float32)
    xr = xr.astype(jnp.float32)
    xi = xi.astype(jnp.float32)
    return (jnp.sum(gr * xr + gi * xi, axis=0),
            jnp.sum(gi * xr - gr * xi, axis=0))


def fd_tno_ref(x: jax.Array, khat_real: jax.Array) -> jax.Array:
    """Causal FD-TNO oracle: y = irfft(rfft(x, 2n) ⊙ k̂, 2n)[:n] with
    k̂ = causal_spectrum(khat_real) (the Hilbert-completed response).

    x: (b, n, d); khat_real: (d, n+1). Semantics contract for
    kernels/fd_fused.fd_tno_pallas; differentiable via plain autodiff
    (pure jnp). Identical numerics to core.fd.fd_tno_apply on the causal
    path."""
    from repro.core.hilbert import causal_spectrum
    b, n, d = x.shape
    khat = causal_spectrum(khat_real.astype(jnp.float32))     # (d, n+1)
    xhat = jnp.fft.rfft(x.astype(jnp.float32), n=2 * n, axis=1)
    y = jnp.fft.irfft(xhat * khat.T[None], n=2 * n, axis=1)[:, :n]
    return y.astype(x.dtype)


# ------------------------------------------------------------- mamba2 SSD
def ssd_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, d_skip: jax.Array) -> jax.Array:
    """Mamba-2 SSD sequential oracle (state-space recurrence).

    x: (bt, n, h, p)   inputs per head (p = head dim)
    dt: (bt, n, h)     softplus'd step sizes (>0)
    a: (h,)            negative state decay rates (A = -exp(a_log))
    b: (bt, n, g, s)   input projections  (g groups, s = state dim)
    c: (bt, n, g, s)   output projections
    d_skip: (h,)       skip connection
    Returns y: (bt, n, h, p).
    """
    bt, n, h, p = x.shape
    g = b.shape[2]
    heads_per_group = h // g
    bx = jnp.repeat(b, heads_per_group, axis=2)  # (bt, n, h, s)
    cx = jnp.repeat(c, heads_per_group, axis=2)

    da = jnp.exp(dt * a[None, None, :])  # (bt, n, h) decay per step

    def step(carry, inp):
        xt, dtt, dat, bt_, ct_ = inp
        # state: (bt, h, p, s)
        new = carry * dat[..., None, None] + (
            (dtt[..., None] * xt)[..., :, None] * bt_[..., None, :])
        y = jnp.einsum("bhps,bhs->bhp", new, ct_)
        return new, y

    x_ = jnp.moveaxis(x.astype(jnp.float32), 1, 0)
    dt_ = jnp.moveaxis(dt.astype(jnp.float32), 1, 0)
    da_ = jnp.moveaxis(da.astype(jnp.float32), 1, 0)
    b_ = jnp.moveaxis(bx.astype(jnp.float32), 1, 0)
    c_ = jnp.moveaxis(cx.astype(jnp.float32), 1, 0)
    init = jnp.zeros((bt, h, p, b.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(step, init, (x_, dt_, da_, b_, c_))
    y = jnp.moveaxis(ys, 0, 1)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y.astype(x.dtype)
