"""Pallas TPU kernels for the SKI backward pass: parameter cotangents.

The fused SKI pipeline is linear in the signal, so the signal cotangent is
served by the *forward* kernels with transposed operands (see
kernels/ski_vjp.py). What the forwards cannot produce are the parameter
cotangents — both are correlation reductions accumulated per tile:

* ``conv_tap_grad``: df[c, k] = Σ_{b,j} g[b, j, c] · x[b, j-k+left, c]
  — the m-tap filter cotangent. Same halo'd prev/cur/next BlockSpec trick
  as the forward conv; each (d-tile, batch, n-tile) grid step reduces its
  window into the (bd, m) output block. The d-tile dimension is the
  *outermost* grid axis so every revisit of an output block is consecutive
  (the safe Pallas accumulation pattern; cf. interp_reduce's k-loop).

* ``gram_grad``: dA[c, s, t] = Σ_b gz[b, s, c] · z[b, t, c]
  — the inducing-Gram cotangent, a per-channel outer product of the two
  rank-r reductions (gz = Wᵀg, z = Wᵀx), accumulated over the batch grid
  axis. Output mirrors the (d, r, r) a_dense layout of the fused forward.

Both accumulate in fp32 regardless of input dtype and emit fp32 (callers
cast to the parameter dtype). Ragged n/d/r follow the backend zero-pad
policy — padded rows multiply zero cotangents, so the sums are exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend
from repro.kernels.ski_fused import _halo_window


# ----------------------------------------------------------- conv tap grad
def _tap_grad_kernel(prev_ref, cur_ref, nxt_ref, g_ref, o_ref, *,
                     m, left, bn, nb_total):
    bi = pl.program_id(1)
    ni = pl.program_id(2)
    # identical halo semantics to the forward conv this kernel transposes
    xwin = _halo_window(prev_ref, cur_ref, nxt_ref, m=m, left=left, bn=bn,
                        nb_total=nb_total, ni=ni)
    g = g_ref[0].astype(jnp.float32)                     # (bn, bd)
    parts = []
    for k in range(m):
        sl = xwin[(m - 1 - k):(m - 1 - k) + bn].astype(jnp.float32)
        parts.append(jnp.sum(sl * g, axis=0))            # (bd,)
    part = jnp.stack(parts, axis=1)                      # (bd, m)

    @pl.when((bi == 0) & (ni == 0))
    def _init():
        o_ref[...] = part

    @pl.when((bi > 0) | (ni > 0))
    def _acc():
        o_ref[...] = o_ref[...] + part


def _tap_grad_call_impl(g, x, m: int, left: int, *, interpret, bn, bd):
    """Requires n % bn == 0, d % bd == 0, bn >= m (padded by the wrapper)."""
    b, n, d = x.shape
    nb, db = n // bn, d // bd
    grid = (db, b, nb)

    def xmap(shift):
        def f(di, bi, ni):
            return (bi, jnp.clip(ni + shift, 0, nb - 1), di)
        return f

    return pl.pallas_call(
        functools.partial(_tap_grad_kernel, m=m, left=left, bn=bn,
                          nb_total=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bd), xmap(-1)),
            pl.BlockSpec((1, bn, bd), xmap(0)),
            pl.BlockSpec((1, bn, bd), xmap(+1)),
            pl.BlockSpec((1, bn, bd), xmap(0)),
        ],
        out_specs=pl.BlockSpec((bd, m), lambda di, bi, ni: (di, 0)),
        out_shape=jax.ShapeDtypeStruct((d, m), jnp.float32),
        interpret=interpret,
    )(x, x, x, g)


def _tap_grad_padded(g, x, m, left, interpret, bn, bd):
    b, n, d = x.shape
    np_, dp = backend.round_up(n, bn), backend.round_up(d, bd)
    if np_ != n or dp != d:
        pad = ((0, 0), (0, np_ - n), (0, dp - d))
        return _tap_grad_padded_call(jnp.pad(g, pad), jnp.pad(x, pad), m,
                                     left, interpret, bn, bd)[:d]
    return _tap_grad_padded_call(g, x, m, left, interpret, bn, bd)


@functools.partial(jax.jit,
                   static_argnames=("m", "left", "interpret", "bn", "bd"))
def _tap_grad_padded_call(g, x, m, left, interpret, bn, bd):
    return _tap_grad_call_impl(g, x, m, left, interpret=interpret,
                               bn=bn, bd=bd)


def conv_tap_grad_pallas(g, x, m: int, left: int, *, interpret=None,
                         bn=None, bd=None):
    """df[c, k] = Σ_{b,j} g[b,j,c] x[b,j-k+left,c]; g, x: (b, n, d) → (d, m).

    Matches ref.conv_tap_grad_ref. Returns fp32 (accumulator dtype).
    """
    b, n, d = x.shape
    interpret = backend.resolve_interpret(interpret)
    if bn is None or bd is None:
        tune = None
        if backend.is_concrete(g, x):
            tune = lambda BN, BD: _tap_grad_padded(g, x, m, left, interpret,
                                                   BN, BD)
        hbn, hbd = backend.get_blocks("conv_tap_grad", n, d, x.dtype,
                                      interpret, tune_call=tune,
                                      extra=f"m={m}")
        bn = bn or hbn
        bd = bd or hbd
    bn, bd = backend.clamp_blocks(bn, bd, n, d, interpret)
    if bn < m:
        from repro.kernels import ref
        return ref.conv_tap_grad_ref(g, x, m, left)
    return _tap_grad_padded(g, x, m, left, interpret, bn, bd)


# --------------------------------------------------------------- gram grad
def _gram_grad_kernel(gz_ref, z_ref, o_ref):
    bi = pl.program_id(1)
    gz = gz_ref[0].astype(jnp.float32).T                 # (bd, r)
    zz = z_ref[0].astype(jnp.float32).T                  # (bd, r)
    part = gz[:, :, None] * zz[:, None, :]               # (bd, r, r)

    @pl.when(bi == 0)
    def _init():
        o_ref[...] = part

    @pl.when(bi > 0)
    def _acc():
        o_ref[...] = o_ref[...] + part


@functools.partial(jax.jit, static_argnames=("interpret", "bd"))
def _gram_grad_call(gz, z, *, interpret, bd):
    b, r, d = z.shape
    grid = (d // bd, b)
    return pl.pallas_call(
        _gram_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r, bd), lambda di, bi: (bi, 0, di)),
            pl.BlockSpec((1, r, bd), lambda di, bi: (bi, 0, di)),
        ],
        out_specs=pl.BlockSpec((bd, r, r), lambda di, bi: (di, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, r, r), jnp.float32),
        interpret=interpret,
    )(gz, z)


# -------------------------------------------------------- gram coef grad
def gram_coef_grad_fft(gz, z):
    """Coefficient-Gram cotangent: dcoef[c, k] = Σ_{b,t} gz[b, t+lag, c] ·
    z[b, t, c] with lag = k - (r-1); gz, z: (b, r, d) → (d, 2r-1) fp32.

    The large-rank siblings of :func:`gram_grad_pallas` cannot exist as a
    per-tile (r, r) reduction — the dense cotangent they would accumulate
    is exactly the (d, r, r) panel the forward variants avoid (16 GB at
    r = 8192, d = 64). The Toeplitz structure collapses it to diagonal
    sums, i.e. a cross-correlation of the two rank-r reductions, served
    here by a length-2r rfft/irfft (O(r log r); the FFT *is* the kernel —
    XLA's, not Pallas). Matches ref.gram_coef_grad_ref.
    """
    b, r, d = z.shape
    two_r = 2 * r
    gs = jnp.fft.rfft(gz.astype(jnp.float32), n=two_r, axis=1)
    zs = jnp.fft.rfft(z.astype(jnp.float32), n=two_r, axis=1)
    spec = jnp.sum(gs * jnp.conj(zs), axis=0)               # (r+1, d)
    c = jnp.fft.irfft(spec, n=two_r, axis=0)                # (2r, d) circular
    # circular correlation: lag k at c[k] (k ≥ 0), lag -k at c[2r - k]
    out = jnp.concatenate([c[r + 1:], c[:r]], axis=0)       # lags -(r-1)..r-1
    return out.T                                            # (d, 2r-1)


def gram_grad_pallas(gz, z, *, interpret=None, bd=None):
    """dA[c,s,t] = Σ_b gz[b,s,c] z[b,t,c]; gz, z: (b, r, d) → (d, r, r).

    Matches ref.gram_grad_ref. Returns fp32 (accumulator dtype). r is
    padded to the sublane unit; padded rows/cols are exactly zero and are
    sliced away.
    """
    b, r, d = z.shape
    interpret = backend.resolve_interpret(interpret)
    if bd is None:
        bd = backend.fit_block(d, 128, backend.lane_unit(interpret))
    bd = min(bd, backend.round_up(d, backend.lane_unit(interpret)))
    rp = backend.round_up(r, 8)
    dp = backend.round_up(d, bd)
    if rp != r or dp != d:
        pad = ((0, 0), (0, rp - r), (0, dp - d))
        out = _gram_grad_call(jnp.pad(gz, pad), jnp.pad(z, pad),
                              interpret=interpret, bd=bd)
        return out[:d, :r, :r]
    return _gram_grad_call(gz, z, interpret=interpret, bd=bd)
