"""Fused SKI-TNO pass-2 Pallas kernel (paper §3.2, DESIGN §3 item 1).

The unfused SKI-TNO pipeline launches four kernels

    y = short_conv(x) + W · (A · (Wᵀ x))
        └── k1 ──┘       └k4┘ └k3┘ └k2┘

streaming the full (b, n, d) activation through HBM between each. This
module implements the *two-pass* fused form:

* **pass 1** — ``interp_reduce`` (kernels/interp_matvec.py): z = Wᵀ x with
  tiles VMEM-resident, output only (b, r, d).
* **pass 2** — THIS kernel: for each (batch, d-tile) the r×r inducing-Gram
  contraction z₂ = A z runs **once** on the MXU into VMEM scratch
  (``pl.when(ni == 0)``; r ≤ 512 → direct matmul, no FFT — the paper's
  observation that dense beats sparse/FFT at this size), then every
  sequence tile regenerates its hat-weight block of W, contracts W z₂ on
  the MXU, adds the m-tap short conv over the same VMEM-resident x tiles
  (halo via prev/cur/next BlockSpecs), and performs a **single** output
  write.

Net: four HBM round-trips of (b, n, d) collapse into two (read x, write y).

Ragged n, d follow the backend zero-pad policy; the hat spacing h comes
from the true n. When bn < m (tiny n) the jnp reference path is used.

Training path (PR 2): the tap offset is generalised from the causal flag
to an arbitrary ``left`` so that this same kernel serves as its own
backward sibling — dx = W (Aᵀ (Wᵀ g)) + T_sparseᵀ g is exactly this
kernel launched on the cotangent with A transposed, the taps flipped and
left mirrored to m-1-left (see kernels/ski_vjp.py for the custom VJP).

Large-rank variants (PR 3)
--------------------------
The dense-Gram kernel above pins the whole (bd, r, r) Gram per d-tile in
VMEM — a hard r ≤ 512 ceiling (and at r = 8192 the (d, r, r) HBM
materialisation itself is ~16 GB, so the dense form cannot even be built).
Two variants remove the ceiling; both consume the Gram in *Toeplitz
coefficient* form a_coef (d, 2r-1) and share the jnp oracle
``ref.ski_fused_tno_coef_ref``:

* ``ski_windowed_pass2_pallas`` — the windowed O(n) banded-W form. Each
  row of W has ≤ 2 interpolation taps, so a length-bn sequence tile only
  ever reads a window of ``bw ≈ bn/h + O(1)`` rows of z₂ = A z. The
  kernel computes exactly that window per tile, streaming the Gram as
  kb = rp/bw Toeplitz **(bw, bw) band blocks** regenerated in VMEM from a
  (2bw-1) coefficient slice (static shifted slices — no gather), each
  contracted on the MXU against the matching z chunk. Per-tile VMEM is
  O(bd·bw²) + the (bd, 2rp-1) coefficient line + the (rp, bd) z tile —
  never an (r, r) panel. Total Gram MACs are b·d·r² across the grid, the
  same as the dense kernel's once-per-d-tile contraction (windows of
  adjacent tiles overlap by ≤ 2 rows).
* ``ski_expand_pass2_pallas`` — the Gram-free second pass for the
  FFT-Gram variant: z₂ = A z is applied *outside* (rfft/irfft circulant
  matvec, O(r log r) — see ski_vjp) and this kernel fuses the windowed
  hat-weight expansion of z₂ with the short conv and the single output
  write. Used when r is beyond the windowed band budget, where the
  O(r²/n) per-row band work loses to O(r log r / r) FFT work.

The backward of both is the same kernel with the coefficients flipped
(Aᵀ of a Toeplitz matrix = lag-reversed coefficients), the taps flipped
and left mirrored — the "transposed band" of ISSUE 3.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend
from repro.kernels.interp_matvec import _hat_weights


def _halo_window(prev_ref, cur_ref, nxt_ref, *, m, left, bn, nb_total, ni):
    """(bn + m - 1, bd) sequence window assembled from halo'd
    prev/cur/next VMEM tiles, boundary tiles zero-masked. The single
    definition of the conv halo semantics — used by the forward conv of
    every pass-2 kernel here AND by its transposed sibling
    ``ski_grad._tap_grad_kernel`` (which must window identically)."""
    hl = m - 1 - left
    hr = left
    prev = jnp.where(ni > 0, prev_ref[0], jnp.zeros_like(prev_ref[0]))
    nxt = jnp.where(ni < nb_total - 1, nxt_ref[0], jnp.zeros_like(nxt_ref[0]))
    cur = cur_ref[0]
    return jnp.concatenate([prev[bn - hl:], cur] + ([nxt[:hr]] if hr else []),
                           axis=0) if hl else jnp.concatenate(
                               [cur] + ([nxt[:hr]] if hr else []), axis=0)


def _conv_halo_acc(prev_ref, cur_ref, nxt_ref, filt_ref, acc, *,
                   m, left, bn, nb_total, ni):
    """Add the m-tap short conv over halo'd prev/cur/next VMEM tiles (VPU)
    into ``acc`` (bn, bd) — the sparse half shared by every pass-2 kernel."""
    xwin = _halo_window(prev_ref, cur_ref, nxt_ref, m=m, left=left, bn=bn,
                        nb_total=nb_total, ni=ni)
    f = filt_ref[...].astype(jnp.float32)                # (bd, m)
    for k in range(m):
        sl = xwin[(m - 1 - k):(m - 1 - k) + bn].astype(jnp.float32)
        acc = acc + sl * f[:, k][None, :]
    return acc


def _fused_kernel(prev_ref, cur_ref, nxt_ref, z_ref, a_ref, filt_ref, o_ref,
                  z2_ref, *, m, left, bn, r, h, nb_total):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _gram():
        # z2 = A z once per (batch, d-tile): batched (bd) r x r MXU matvec
        zt = z_ref[0].astype(jnp.float32).T              # (bd, r)
        a = a_ref[...].astype(jnp.float32)               # (bd, r, r)
        z2 = jax.lax.dot_general(a, zt, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
        z2_ref[...] = z2.T                               # (r, bd)

    # low-rank half: y_low = W_tile z2 (MXU)
    w = _hat_weights(ni * bn, bn, r, h)                  # (bn, r)
    acc = jnp.dot(w, z2_ref[...], preferred_element_type=jnp.float32)
    acc = _conv_halo_acc(prev_ref, cur_ref, nxt_ref, filt_ref, acc,
                         m=m, left=left, bn=bn, nb_total=nb_total, ni=ni)
    o_ref[0] = acc.astype(o_ref.dtype)                   # single write


@functools.partial(jax.jit,
                   static_argnames=("left", "h", "interpret", "bn", "bd"))
def _fused_call(x, z, a_dense, filt, left: int, h: float, *,
                interpret, bn, bd):
    """Requires n % bn == 0, d % bd == 0, bn >= m (padded by the wrapper)."""
    b, n, d = x.shape
    r = z.shape[1]
    m = filt.shape[-1]
    nb, db = n // bn, d // bd
    grid = (b, db, nb)

    def xmap(shift):
        def f(bi, di, ni):
            return (bi, jnp.clip(ni + shift, 0, nb - 1), di)
        return f

    return pl.pallas_call(
        functools.partial(_fused_kernel, m=m, left=left, bn=bn, r=r, h=h,
                          nb_total=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bd), xmap(-1)),
            pl.BlockSpec((1, bn, bd), xmap(0)),
            pl.BlockSpec((1, bn, bd), xmap(+1)),
            pl.BlockSpec((1, r, bd), lambda bi, di, ni: (bi, 0, di)),
            pl.BlockSpec((bd, r, r), lambda bi, di, ni: (di, 0, 0)),
            pl.BlockSpec((bd, m), lambda bi, di, ni: (di, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, bd), lambda bi, di, ni: (bi, ni, di)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((r, bd), jnp.float32)],
        interpret=interpret,
    )(x, x, x, z, a_dense, filt)


def _padded_call(x, z, a_dense, filt, left, h, interpret, bn, bd):
    b, n, d = x.shape
    np_, dp = backend.round_up(n, bn), backend.round_up(d, bd)
    if np_ != n or dp != d:
        pd = dp - d
        xp = jnp.pad(x, ((0, 0), (0, np_ - n), (0, pd)))
        zp = jnp.pad(z, ((0, 0), (0, 0), (0, pd)))
        ap = jnp.pad(a_dense, ((0, pd), (0, 0), (0, 0)))
        fp = jnp.pad(filt, ((0, pd), (0, 0)))
        return _fused_call(xp, zp, ap, fp, left, h, interpret=interpret,
                           bn=bn, bd=bd)[:, :n, :d]
    return _fused_call(x, z, a_dense, filt, left, h, interpret=interpret,
                       bn=bn, bd=bd)


def ski_fused_pass2_pallas(x, z, a_dense, filt, causal: bool, *,
                           interpret=None, bn=None, bd=None, left=None):
    """y = W (A z) + T_sparse x, one kernel, one output write.

    x: (b, n, d); z = Wᵀx: (b, r, d); a_dense: (d, r, r) per-channel Gram;
    filt: (d, m). Matches ref.ski_fused_pass2_ref. ``left`` overrides the
    causal-derived tap offset (backward-sibling launches only).
    """
    b, n, d = x.shape
    m = filt.shape[-1]
    if left is None:
        left = 0 if causal else m // 2
    interpret = backend.resolve_interpret(interpret)
    h = (n - 1) / (z.shape[1] - 1)
    if bn is None or bd is None:
        tune = None
        if backend.is_concrete(x, z, a_dense, filt):
            tune = lambda BN, BD: _padded_call(x, z, a_dense, filt, left,
                                               h, interpret, BN, BD)
        hbn, hbd = backend.get_blocks("ski_fused", n, d, x.dtype, interpret,
                                      tune_call=tune,
                                      extra=f"r={z.shape[1]}|m={m}")
        bn = bn or hbn
        bd = bd or hbd
    bn, bd = backend.clamp_blocks(bn, bd, n, d, interpret)
    if bn < m:
        from repro.kernels import ref
        return ref.ski_fused_pass2_ref(x, z, a_dense, filt, causal, left=left)
    return _padded_call(x, z, a_dense, filt, left, h, interpret, bn, bd)


# ---------------------------------------------------- large-rank variants
def _windowed_kernel(prev_ref, cur_ref, nxt_ref, z_ref, *rest, m, left, bn,
                     w0_max, bw, h, nb_total, banded):
    if banded:
        fc_ref, filt_ref, o_ref = rest
    else:
        filt_ref, o_ref = rest
    ni = pl.program_id(2)
    s = ni * bn
    sf = s.astype(jnp.float32)
    # first inducing column touched by this tile's hat rows, clamped so the
    # static-width window stays inside the (padded) inducing grid
    w0 = jnp.clip(jnp.floor(sf / h).astype(jnp.int32), 0, w0_max)

    if banded:
        # z2 window = A[w0:w0+bw, :] z, streamed as kb Toeplitz (bw, bw)
        # band blocks regenerated from the flipped coefficient line:
        # A[w0+j, t] = fc[(rp-1-w0) + t - j]  (fc = lag-reversed, padded)
        fc = fc_ref[...].astype(jnp.float32)             # (bd, 2rp-1)
        z = z_ref[0].astype(jnp.float32)                 # (rp, bd)
        bd = fc.shape[0]
        rp = z.shape[0]
        s0 = (rp - 1) - w0
        kb = rp // bw

        def body(k, acc):
            cs = s0 - (bw - 1) + k * bw
            csl = jax.lax.dynamic_slice(fc, (0, cs), (bd, 2 * bw - 1))
            # block[c, j, u] = fc[c, s0 + k*bw + u - j]: bw static shifted
            # slices of the (2bw-1) line — no gather
            block = jnp.stack(
                [csl[:, bw - 1 - j:2 * bw - 1 - j] for j in range(bw)],
                axis=1)                                  # (bd, bw, bw)
            zc = jax.lax.dynamic_slice(z, (k * bw, 0), (bw, bd)).T
            return acc + jax.lax.dot_general(
                block, zc, (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)      # (bd, bw)

        z2w = jax.lax.fori_loop(
            0, kb, body, jnp.zeros((bd, bw), jnp.float32)).T   # (bw, bd)
    else:
        # FFT-Gram variant: z_ref already holds z2 = A z; just window it
        bd = z_ref.shape[2]
        z2w = jax.lax.dynamic_slice(z_ref[0].astype(jnp.float32),
                                    (w0, 0), (bw, bd))   # (bw, bd)

    # windowed hat-weight expansion: w[i, j] = hat((s+i)/h - (w0+j)) (MXU)
    i = jax.lax.broadcasted_iota(jnp.float32, (bn, bw), 0) + sf
    j = jax.lax.broadcasted_iota(jnp.float32, (bn, bw), 1) + \
        w0.astype(jnp.float32)
    wwin = jnp.maximum(0.0, 1.0 - jnp.abs(i / h - j))
    acc = jnp.dot(wwin, z2w, preferred_element_type=jnp.float32)
    acc = _conv_halo_acc(prev_ref, cur_ref, nxt_ref, filt_ref, acc,
                         m=m, left=left, bn=bn, nb_total=nb_total, ni=ni)
    o_ref[0] = acc.astype(o_ref.dtype)                   # single write


@functools.partial(jax.jit, static_argnames=(
    "left", "h", "w0_max", "banded", "interpret", "bn", "bd", "bw"))
def _windowed_call(x, z, fc, filt, left: int, h: float, w0_max: int, *,
                   banded, interpret, bn, bd, bw):
    """Requires n % bn == 0, d % bd == 0, bn >= m, z rows padded to rp
    (a multiple of bw when banded) — all arranged by _windowed_padded."""
    b, n, d = x.shape
    rp = z.shape[1]
    m = filt.shape[-1]
    nb, db = n // bn, d // bd
    grid = (b, db, nb)

    def xmap(shift):
        def f(bi, di, ni):
            return (bi, jnp.clip(ni + shift, 0, nb - 1), di)
        return f

    in_specs = [
        pl.BlockSpec((1, bn, bd), xmap(-1)),
        pl.BlockSpec((1, bn, bd), xmap(0)),
        pl.BlockSpec((1, bn, bd), xmap(+1)),
        pl.BlockSpec((1, rp, bd), lambda bi, di, ni: (bi, 0, di)),
    ]
    args = [x, x, x, z]
    if banded:
        in_specs.append(pl.BlockSpec((bd, 2 * rp - 1),
                                     lambda bi, di, ni: (di, 0)))
        args.append(fc)
    in_specs.append(pl.BlockSpec((bd, m), lambda bi, di, ni: (di, 0)))
    args.append(filt)

    return pl.pallas_call(
        functools.partial(_windowed_kernel, m=m, left=left, bn=bn,
                          w0_max=w0_max, bw=bw, h=h, nb_total=nb,
                          banded=banded),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bn, bd), lambda bi, di, ni: (bi, ni, di)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), x.dtype),
        interpret=interpret,
    )(*args)


def _windowed_padded(x, z, a_coef, filt, left, h, r, banded, interpret,
                     bn, bd, bw):
    b, n, d = x.shape
    # rp: multiple of bw (banded chunk loop) or of the sublane unit
    rp = backend.round_up(r, bw) if banded else max(backend.round_up(r, 8), bw)
    np_, dp = backend.round_up(n, bn), backend.round_up(d, bd)
    w0_max = max(0, r - bw)
    if np_ != n or dp != d:
        x = jnp.pad(x, ((0, 0), (0, np_ - n), (0, dp - d)))
        filt = jnp.pad(filt, ((0, dp - d), (0, 0)))
    if rp != r or dp != d:
        z = jnp.pad(z, ((0, 0), (0, rp - r), (0, dp - d)))
    fc = None
    if banded:
        # lag-reversed coefficients (A[s,t] lookup becomes a forward slice),
        # symmetric-padded to rank rp: extra |lag| >= r coefficients are
        # zero, so padded z rows / window rows contribute exactly nothing
        fc = jnp.flip(a_coef, axis=-1)
        fc = jnp.pad(fc, ((0, dp - d), (rp - r, rp - r)))
    out = _windowed_call(x, z, fc, filt, left, h, w0_max, banded=banded,
                         interpret=interpret, bn=bn, bd=bd, bw=bw)
    return out[:, :n, :d]


def _coef_ref_fallback(x, z2_or_z, a_coef, filt, causal, left):
    from repro.kernels import ref
    if a_coef is not None:
        z2 = ref.toeplitz_gram_matvec_ref(a_coef, z2_or_z)
    else:
        z2 = z2_or_z
    return ref.ski_expand_pass2_ref(x, z2, filt, causal, left=left)


def _windowed_wrapper(x, z, a_coef, filt, causal, banded, interpret,
                      bn, bd, bw, left):
    """Shared block/band resolution + tiny-shape fallback for the two
    large-rank pass-2 wrappers."""
    b, n, d = x.shape
    r = z.shape[1]
    m = filt.shape[-1]
    if left is None:
        left = 0 if causal else m // 2
    interpret = backend.resolve_interpret(interpret)
    if r < 2:
        return _coef_ref_fallback(x, z, a_coef, filt, causal, left)
    h = (n - 1) / (r - 1)
    kern = "ski_windowed" if banded else "ski_expand2"
    if bn is None or bd is None:
        tune = None
        if backend.is_concrete(x, z, filt) and (
                a_coef is None or backend.is_concrete(a_coef)):
            def tune(BN, BD):
                BN, BW = backend.band_fit(BN, n, r)
                return _windowed_padded(x, z, a_coef, filt, left, h, r,
                                        banded, interpret, BN, BD, BW)
        hbn, hbd = backend.get_blocks(kern, n, d, x.dtype, interpret,
                                      tune_call=tune, extra=f"r={r}|m={m}")
        bn = bn or hbn
        bd = bd or hbd
    bn, bd = backend.clamp_blocks(bn, bd, n, d, interpret)
    if bw is None:
        bn, bw = backend.band_fit(bn, n, r)
    if bn < m:
        return _coef_ref_fallback(x, z, a_coef, filt, causal, left)
    return _windowed_padded(x, z, a_coef, filt, left, h, r, banded,
                            interpret, bn, bd, bw)


def ski_windowed_pass2_pallas(x, z, a_coef, filt, causal: bool, *,
                              interpret=None, bn=None, bd=None, bw=None,
                              left=None):
    """Windowed O(n) banded-W pass 2: y = W (A z) + T_sparse x, with the
    Gram consumed in Toeplitz-coefficient form and streamed as (bw, bw)
    band blocks per sequence tile — no (r, r) panel ever exists, in VMEM
    or HBM.

    x: (b, n, d); z = Wᵀx: (b, r, d); a_coef: (d, 2r-1) lags -(r-1)..r-1;
    filt: (d, m). Matches ref.ski_fused_tno_coef_ref's pass 2 (i.e.
    toeplitz_gram_matvec_ref + ski_expand_pass2_ref). ``left`` overrides
    the causal-derived tap offset; the backward sibling is this same
    kernel with ``a_coef`` lag-flipped (transposed band), taps flipped
    and left mirrored.
    """
    return _windowed_wrapper(x, z, a_coef, filt, causal, True, interpret,
                             bn, bd, bw, left)


def ski_expand_pass2_pallas(x, z2, filt, causal: bool, *, interpret=None,
                            bn=None, bd=None, bw=None, left=None):
    """Gram-free windowed pass 2 for the FFT-Gram variant: y = W z2 +
    T_sparse x where z2 = A z was applied outside via rfft/irfft.

    x: (b, n, d); z2: (b, r, d); filt: (d, m). Matches
    ref.ski_expand_pass2_ref. Same windowed hat-weight expansion as the
    banded kernel — each tile reads only its (bw, bd) window of z2.
    """
    return _windowed_wrapper(x, z2, None, filt, causal, False, interpret,
                             bn, bd, bw, left)
