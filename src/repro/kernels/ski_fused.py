"""Fused SKI-TNO pass-2 Pallas kernel (paper §3.2, DESIGN §3 item 1).

The unfused SKI-TNO pipeline launches four kernels

    y = short_conv(x) + W · (A · (Wᵀ x))
        └── k1 ──┘       └k4┘ └k3┘ └k2┘

streaming the full (b, n, d) activation through HBM between each. This
module implements the *two-pass* fused form:

* **pass 1** — ``interp_reduce`` (kernels/interp_matvec.py): z = Wᵀ x with
  tiles VMEM-resident, output only (b, r, d).
* **pass 2** — THIS kernel: for each (batch, d-tile) the r×r inducing-Gram
  contraction z₂ = A z runs **once** on the MXU into VMEM scratch
  (``pl.when(ni == 0)``; r ≤ 512 → direct matmul, no FFT — the paper's
  observation that dense beats sparse/FFT at this size), then every
  sequence tile regenerates its hat-weight block of W, contracts W z₂ on
  the MXU, adds the m-tap short conv over the same VMEM-resident x tiles
  (halo via prev/cur/next BlockSpecs), and performs a **single** output
  write.

Net: four HBM round-trips of (b, n, d) collapse into two (read x, write y).

Ragged n, d follow the backend zero-pad policy; the hat spacing h comes
from the true n. When bn < m (tiny n) the jnp reference path is used.

Training path (PR 2): the tap offset is generalised from the causal flag
to an arbitrary ``left`` so that this same kernel serves as its own
backward sibling — dx = W (Aᵀ (Wᵀ g)) + T_sparseᵀ g is exactly this
kernel launched on the cotangent with A transposed, the taps flipped and
left mirrored to m-1-left (see kernels/ski_vjp.py for the custom VJP).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend
from repro.kernels.interp_matvec import _hat_weights


def _fused_kernel(prev_ref, cur_ref, nxt_ref, z_ref, a_ref, filt_ref, o_ref,
                  z2_ref, *, m, left, bn, r, h, nb_total):
    ni = pl.program_id(2)

    @pl.when(ni == 0)
    def _gram():
        # z2 = A z once per (batch, d-tile): batched (bd) r x r MXU matvec
        zt = z_ref[0].astype(jnp.float32).T              # (bd, r)
        a = a_ref[...].astype(jnp.float32)               # (bd, r, r)
        z2 = jax.lax.dot_general(a, zt, (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)
        z2_ref[...] = z2.T                               # (r, bd)

    # low-rank half: y_low = W_tile z2 (MXU)
    w = _hat_weights(ni * bn, bn, r, h)                  # (bn, r)
    acc = jnp.dot(w, z2_ref[...], preferred_element_type=jnp.float32)

    # sparse half: m-tap short conv over halo'd VMEM tiles (VPU)
    hl = m - 1 - left
    hr = left
    prev = jnp.where(ni > 0, prev_ref[0], jnp.zeros_like(prev_ref[0]))
    nxt = jnp.where(ni < nb_total - 1, nxt_ref[0], jnp.zeros_like(nxt_ref[0]))
    cur = cur_ref[0]
    xwin = jnp.concatenate([prev[bn - hl:], cur] + ([nxt[:hr]] if hr else []),
                           axis=0) if hl else jnp.concatenate(
                               [cur] + ([nxt[:hr]] if hr else []), axis=0)
    f = filt_ref[...].astype(jnp.float32)                # (bd, m)
    for k in range(m):
        sl = xwin[(m - 1 - k):(m - 1 - k) + bn].astype(jnp.float32)
        acc = acc + sl * f[:, k][None, :]

    o_ref[0] = acc.astype(o_ref.dtype)                   # single write


@functools.partial(jax.jit,
                   static_argnames=("left", "h", "interpret", "bn", "bd"))
def _fused_call(x, z, a_dense, filt, left: int, h: float, *,
                interpret, bn, bd):
    """Requires n % bn == 0, d % bd == 0, bn >= m (padded by the wrapper)."""
    b, n, d = x.shape
    r = z.shape[1]
    m = filt.shape[-1]
    nb, db = n // bn, d // bd
    grid = (b, db, nb)

    def xmap(shift):
        def f(bi, di, ni):
            return (bi, jnp.clip(ni + shift, 0, nb - 1), di)
        return f

    return pl.pallas_call(
        functools.partial(_fused_kernel, m=m, left=left, bn=bn, r=r, h=h,
                          nb_total=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bd), xmap(-1)),
            pl.BlockSpec((1, bn, bd), xmap(0)),
            pl.BlockSpec((1, bn, bd), xmap(+1)),
            pl.BlockSpec((1, r, bd), lambda bi, di, ni: (bi, 0, di)),
            pl.BlockSpec((bd, r, r), lambda bi, di, ni: (di, 0, 0)),
            pl.BlockSpec((bd, m), lambda bi, di, ni: (di, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, bd), lambda bi, di, ni: (bi, ni, di)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((r, bd), jnp.float32)],
        interpret=interpret,
    )(x, x, x, z, a_dense, filt)


def _padded_call(x, z, a_dense, filt, left, h, interpret, bn, bd):
    b, n, d = x.shape
    np_, dp = backend.round_up(n, bn), backend.round_up(d, bd)
    if np_ != n or dp != d:
        pd = dp - d
        xp = jnp.pad(x, ((0, 0), (0, np_ - n), (0, pd)))
        zp = jnp.pad(z, ((0, 0), (0, 0), (0, pd)))
        ap = jnp.pad(a_dense, ((0, pd), (0, 0), (0, 0)))
        fp = jnp.pad(filt, ((0, pd), (0, 0)))
        return _fused_call(xp, zp, ap, fp, left, h, interpret=interpret,
                           bn=bn, bd=bd)[:, :n, :d]
    return _fused_call(x, z, a_dense, filt, left, h, interpret=interpret,
                       bn=bn, bd=bd)


def ski_fused_pass2_pallas(x, z, a_dense, filt, causal: bool, *,
                           interpret=None, bn=None, bd=None, left=None):
    """y = W (A z) + T_sparse x, one kernel, one output write.

    x: (b, n, d); z = Wᵀx: (b, r, d); a_dense: (d, r, r) per-channel Gram;
    filt: (d, m). Matches ref.ski_fused_pass2_ref. ``left`` overrides the
    causal-derived tap offset (backward-sibling launches only).
    """
    b, n, d = x.shape
    m = filt.shape[-1]
    if left is None:
        left = 0 if causal else m // 2
    interpret = backend.resolve_interpret(interpret)
    h = (n - 1) / (z.shape[1] - 1)
    if bn is None or bd is None:
        tune = None
        if backend.is_concrete(x, z, a_dense, filt):
            tune = lambda BN, BD: _padded_call(x, z, a_dense, filt, left,
                                               h, interpret, BN, BD)
        hbn, hbd = backend.get_blocks("ski_fused", n, d, x.dtype, interpret,
                                      tune_call=tune,
                                      extra=f"r={z.shape[1]}|m={m}")
        bn = bn or hbn
        bd = bd or hbd
    bn, bd = backend.clamp_blocks(bn, bd, n, d, interpret)
    if bn < m:
        from repro.kernels import ref
        return ref.ski_fused_pass2_ref(x, z, a_dense, filt, causal, left=left)
    return _padded_call(x, z, a_dense, filt, left, h, interpret, bn, bd)
