"""Trainable fused SKI-TNO: custom VJP with Pallas backward kernels (PR 2).

``pallas_call`` has no autodiff in this JAX version, so before this module
``jax.grad`` through the fused two-pass pipeline silently required the jnp
reference path. Every factor of the pipeline is *linear in the signal*,
so the backward is the transposed pipeline and reuses the forward
machinery (Qin et al. 2023's TNN training at kernel speed):

Forward (kernels/interp_matvec.py pass 1 + kernels/ski_fused.py pass 2)::

    z = Wᵀ x                       (b, r, d)
    y = W (A z) + T_sparse x       (b, n, d), single output write

Backward, given cotangent g = ∂L/∂y::

    gz = Wᵀ g                      pass-1 kernel on the cotangent
    dx = W (Aᵀ gz) + T_sparseᵀ g   pass-2 kernel with A → Aᵀ, taps
                                   flipped, offset mirrored (left → m-1-left)
    dA[c]   = Σ_b gz[b,:,c] z[b,:,c]ᵀ          gram_grad kernel
    df[c,k] = Σ_{b,j} g[b,j,c] x[b,j-k+left,c] conv_tap_grad kernel

Residual/recompute policy (backend.py docstring): residuals are the op
inputs (x, a_dense, filt) only — no O(n·r) activation is saved; the pass-1
reduction z is recomputed in the backward by one extra kernel launch.

``REPRO_PALLAS_GRAD=0`` (backend.resolve_pallas_grad) swaps the backward
to the jnp reference cotangents while keeping the Pallas forward — a
numerical-bisection escape hatch. The ``counters`` dict records which
path executed at trace time so tests (and the trainer banner) can assert
there is no silent reference fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import toeplitz
from repro.kernels import backend, ref
from repro.kernels.interp_matvec import interp_reduce_pallas
from repro.kernels.ski_fused import (ski_expand_pass2_pallas,
                                     ski_fused_pass2_pallas,
                                     ski_windowed_pass2_pallas)
from repro.kernels.ski_grad import (conv_tap_grad_pallas, gram_coef_grad_fft,
                                    gram_grad_pallas)

# trace-time instrumentation: which fwd/bwd path actually ran (tests +
# trainer banner assert on this — the whole point of PR 2 is that training
# does NOT silently fall back to the reference)
counters = {"fwd": 0, "bwd_kernel": 0, "bwd_ref": 0}


def reset_counters() -> None:
    for k in counters:
        counters[k] = 0


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def ski_fused_tno_pallas(x, a_dense, filt, r: int, causal: bool,
                         interpret: bool):
    """y = W (A (Wᵀ x)) + T_sparse x — two kernel passes, differentiable.

    x: (b, n, d); a_dense: (d, r, r); filt: (d, m). Matches
    ref.ski_fused_tno_ref. ``interpret`` must be resolved by the caller
    (static nondiff argument).
    """
    z = interp_reduce_pallas(x, None, None, r, interpret=interpret)
    return ski_fused_pass2_pallas(x, z, a_dense, filt, causal,
                                  interpret=interpret)


def _fwd(x, a_dense, filt, r, causal, interpret):
    counters["fwd"] += 1
    y = ski_fused_tno_pallas(x, a_dense, filt, r, causal, interpret)
    return y, (x, a_dense, filt)


def _bwd_ref_formulas(x, a_dense, filt, r, causal, g):
    """jnp reference cotangents (REPRO_PALLAS_GRAD=0 escape hatch)."""
    n = x.shape[1]
    w = ref.hat_interp_matrix(n, r)                      # (n, r) constants

    def f(x_, a_, f_):
        z = jnp.einsum("nr,bnd->brd", w, x_.astype(jnp.float32)).astype(
            x_.dtype)
        return ref.ski_fused_pass2_ref(x_, z, a_, f_, causal)

    _, vjp = jax.vjp(f, x, a_dense, filt)
    return vjp(g)


def _bwd(r, causal, interpret, res, g):
    x, a_dense, filt = res
    if not backend.resolve_pallas_grad():
        counters["bwd_ref"] += 1
        return _bwd_ref_formulas(x, a_dense, filt, r, causal, g)
    counters["bwd_kernel"] += 1
    m = filt.shape[-1]
    left = 0 if causal else m // 2
    # pass 1 on the cotangent, and recomputed on the saved input
    gz = interp_reduce_pallas(g, None, None, r, interpret=interpret)
    z = interp_reduce_pallas(x, None, None, r, interpret=interpret)
    # signal cotangent: the fused pass-2 kernel as its own transposed
    # sibling — Gram transposed, taps flipped, offset mirrored
    dx = ski_fused_pass2_pallas(g, gz, jnp.swapaxes(a_dense, 1, 2),
                                jnp.flip(filt, axis=-1), causal,
                                interpret=interpret, left=m - 1 - left)
    da = gram_grad_pallas(gz, z, interpret=interpret)
    df = conv_tap_grad_pallas(g, x, m, left, interpret=interpret)
    return (dx.astype(x.dtype), da.astype(a_dense.dtype),
            df.astype(filt.dtype))


ski_fused_tno_pallas.defvjp(_fwd, _bwd)


# ------------------------------------------------ large-rank coef variants
def _gram_fft(a_coef, z):
    """z2 = A z via the length-2r circulant rfft/irfft (the FFT-Gram step
    'inside the pipeline'); z: (b, r, d)."""
    zt = jnp.swapaxes(z, 1, 2)                           # (b, d, r)
    z2t = toeplitz.toeplitz_matvec(a_coef[None], zt)
    return jnp.swapaxes(z2t, 1, 2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def ski_fused_tno_coef_pallas(x, a_coef, filt, r: int, causal: bool,
                              variant: str, interpret: bool):
    """Large-rank differentiable fused SKI-TNO, Toeplitz-coefficient form.

    y = W (A (Wᵀ x)) + T_sparse x with A given as a_coef (d, 2r-1) —
    never materialised dense. ``variant``:

    * "windowed" — pass 2 is the banded-W kernel streaming (bw, bw) Gram
      band blocks (ski_fused.ski_windowed_pass2_pallas).
    * "fft"      — the Gram is applied between the passes by a length-2r
      rfft/irfft circulant matvec; pass 2 is the Gram-free windowed
      expand+conv kernel (ski_fused.ski_expand_pass2_pallas).

    Matches ref.ski_fused_tno_coef_ref. ``interpret`` and ``variant``
    must be resolved by the caller (static nondiff arguments).
    """
    z = interp_reduce_pallas(x, None, None, r, interpret=interpret)
    if variant == "windowed":
        return ski_windowed_pass2_pallas(x, z, a_coef, filt, causal,
                                         interpret=interpret)
    return ski_expand_pass2_pallas(x, _gram_fft(a_coef, z), filt, causal,
                                   interpret=interpret)


def _coef_fwd(x, a_coef, filt, r, causal, variant, interpret):
    counters["fwd"] += 1
    y = ski_fused_tno_coef_pallas(x, a_coef, filt, r, causal, variant,
                                  interpret)
    return y, (x, a_coef, filt)


def _coef_bwd_ref_formulas(x, a_coef, filt, r, causal, g):
    """jnp reference cotangents (REPRO_PALLAS_GRAD=0 escape hatch)."""
    n = x.shape[1]
    w = ref.hat_interp_matrix(n, r)                      # (n, r) constants

    def f(x_, a_, f_):
        z = jnp.einsum("nr,bnd->brd", w, x_.astype(jnp.float32)).astype(
            x_.dtype)
        z2 = _gram_fft(a_, z)
        return ref.ski_expand_pass2_ref(x_, z2, f_, causal)

    _, vjp = jax.vjp(f, x, a_coef, filt)
    return vjp(g)


def _coef_bwd(r, causal, variant, interpret, res, g):
    x, a_coef, filt = res
    if not backend.resolve_pallas_grad():
        counters["bwd_ref"] += 1
        return _coef_bwd_ref_formulas(x, a_coef, filt, r, causal, g)
    counters["bwd_kernel"] += 1
    m = filt.shape[-1]
    left = 0 if causal else m // 2
    gz = interp_reduce_pallas(g, None, None, r, interpret=interpret)
    z = interp_reduce_pallas(x, None, None, r, interpret=interpret)
    # signal cotangent: transposed band — Aᵀ of a Toeplitz matrix is the
    # lag-reversed coefficient line; taps flipped, offset mirrored
    coef_t = jnp.flip(a_coef, axis=-1)
    filt_t = jnp.flip(filt, axis=-1)
    if variant == "windowed":
        dx = ski_windowed_pass2_pallas(g, gz, coef_t, filt_t, causal,
                                       interpret=interpret,
                                       left=m - 1 - left)
    else:
        dx = ski_expand_pass2_pallas(g, _gram_fft(coef_t, gz), filt_t,
                                     causal, interpret=interpret,
                                     left=m - 1 - left)
    # parameter cotangents: FFT diagonal-sum correlation (coefficient
    # form of gram_grad — the dense (d, r, r) panel must never exist)
    dcoef = gram_coef_grad_fft(gz, z)
    df = conv_tap_grad_pallas(g, x, m, left, interpret=interpret)
    return (dx.astype(x.dtype), dcoef.astype(a_coef.dtype),
            df.astype(filt.dtype))


ski_fused_tno_coef_pallas.defvjp(_coef_fwd, _coef_bwd)
