"""Pallas TPU kernels: banded interpolation-matrix actions for SKI (§3.2.1).

Because inducing points are *uniform*, the linear-interp weight of position
i on grid node j is the hat function ``max(0, 1 - |i/h - j|)`` — so W never
needs to be materialised or gathered. Each kernel regenerates its block of
W from ``broadcasted_iota`` in VMEM and contracts it on the MXU:

* ``interp_reduce``:  z = Wᵀ x  — grid (b, d-tiles, n-tiles), accumulating
  the (r, BD) output across the sequence tiles (k-loop pattern).
* ``interp_expand``:  y = W z  — z (r ≤ 512) lives whole in VMEM.

For r ≤ 512 the dense-hat contraction (O(n r) MXU MACs) beats the O(n)
two-tap band on TPU for the same reason the paper's dense GPU path beat
sparse tensors; the asymptotic O(n) form is a windowed variant of the same
kernel (see DESIGN §3 / EXPERIMENTS §Perf for the crossover analysis).

Shape policy (repro.kernels.backend): tile sizes come from the autotune
cache / heuristic; ragged n, d are zero-padded to the tile multiple and
sliced back. The hat spacing ``h`` is always computed from the *true* n,
so padded rows get weights applied to zero inputs (reduce) or are sliced
away (expand) — both exact under linearity.

Training path (PR 2): both kernels carry ``jax.custom_vjp`` rules. W has
no trainable parameters (the hat weights are regenerated from the uniform
grid), so each backward is a single launch of the *other* kernel:
d(Wᵀx)/dx ⊢ expand, d(Wz)/dz ⊢ reduce. Residual-free — nothing is saved.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend


def _hat_weights(n_start, bn, r, h, dtype=jnp.float32):
    """(bn, r) linear-interp weights for global positions n_start..+bn."""
    i = jax.lax.broadcasted_iota(jnp.float32, (bn, r), 0) + n_start
    j = jax.lax.broadcasted_iota(jnp.float32, (bn, r), 1)
    return jnp.maximum(0.0, 1.0 - jnp.abs(i / h - j)).astype(dtype)


def _reduce_kernel(x_ref, o_ref, *, bn, r, h):
    ni = pl.program_id(2)
    w = _hat_weights(ni * bn, bn, r, h)               # (bn, r)
    part = jnp.dot(w.T, x_ref[0].astype(jnp.float32),
                   preferred_element_type=jnp.float32)  # (r, bd)

    @pl.when(ni == 0)
    def _init():
        o_ref[0] = part.astype(o_ref.dtype)

    @pl.when(ni > 0)
    def _acc():
        o_ref[0] = (o_ref[0].astype(jnp.float32) + part).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("r", "h", "interpret", "bn", "bd"))
def _reduce_call(x, r: int, h: float, *, interpret, bn, bd):
    b, n, d = x.shape
    grid = (b, d // bd, n // bn)
    return pl.pallas_call(
        functools.partial(_reduce_kernel, bn=bn, r=r, h=h),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bn, bd), lambda bi, di, ni: (bi, ni, di))],
        out_specs=pl.BlockSpec((1, r, bd), lambda bi, di, ni: (bi, 0, di)),
        out_shape=jax.ShapeDtypeStruct((b, r, d), x.dtype),
        interpret=interpret,
    )(x)


def _expand_blocks(n, d, dtype, interpret):
    """(bn, bd) for an expand-shaped launch (cache-or-heuristic only — the
    backward rules run under tracers, so no timing sweep)."""
    bn, bd = backend.get_blocks("interp_expand", n, d, dtype, interpret)
    return backend.clamp_blocks(bn, bd, n, d, interpret)


def _reduce_blocks(n, d, dtype, interpret):
    bn, bd = backend.get_blocks("interp_reduce", n, d, dtype, interpret)
    return backend.clamp_blocks(bn, bd, n, d, interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _reduce_core(x, n, r, h, interpret, bn, bd):
    return _reduce_padded(x, r, h, interpret, bn, bd)


def _reduce_core_fwd(x, n, r, h, interpret, bn, bd):
    return _reduce_core(x, n, r, h, interpret, bn, bd), None


def _reduce_core_bwd(n, r, h, interpret, bn, bd, res, g):
    del res                                           # residual-free
    if not backend.resolve_pallas_grad():
        from repro.kernels import ref
        w = ref.hat_interp_matrix(n, r)
        dx = jnp.einsum("nr,brd->bnd", w, g.astype(jnp.float32))
        return (dx.astype(g.dtype),)
    ebn, ebd = _expand_blocks(n, g.shape[2], g.dtype, interpret)
    return (_expand_padded(g, n, h, interpret, ebn, ebd),)


_reduce_core.defvjp(_reduce_core_fwd, _reduce_core_bwd)


def interp_reduce_pallas(x, idx_lo, w_lo, r: int, *, interpret=None,
                         bn=None, bd=None):
    """z = Wᵀ x. x: (b, n, d) -> (b, r, d). idx_lo/w_lo unused (weights are
    regenerated from the uniform grid); kept for oracle-parity signature.
    Differentiable in x (custom VJP: the backward is one expand launch)."""
    del idx_lo, w_lo
    b, n, d = x.shape
    interpret = backend.resolve_interpret(interpret)
    h = (n - 1) / (r - 1)                             # spacing from TRUE n
    if bn is None or bd is None:
        tune = None
        if backend.is_concrete(x):
            tune = lambda BN, BD: _reduce_padded(x, r, h, interpret, BN, BD)
        hbn, hbd = backend.get_blocks("interp_reduce", n, d, x.dtype,
                                      interpret, tune_call=tune,
                                      extra=f"r={r}")
        bn = bn or hbn
        bd = bd or hbd
    bn, bd = backend.clamp_blocks(bn, bd, n, d, interpret)
    return _reduce_core(x, n, r, h, interpret, bn, bd)


def _reduce_padded(x, r, h, interpret, bn, bd):
    b, n, d = x.shape
    np_, dp = backend.round_up(n, bn), backend.round_up(d, bd)
    if np_ != n or dp != d:
        x = jnp.pad(x, ((0, 0), (0, np_ - n), (0, dp - d)))
        return _reduce_call(x, r, h, interpret=interpret, bn=bn,
                            bd=bd)[:, :, :d]
    return _reduce_call(x, r, h, interpret=interpret, bn=bn, bd=bd)


def _expand_kernel(z_ref, o_ref, *, bn, r, h):
    ni = pl.program_id(2)
    w = _hat_weights(ni * bn, bn, r, h)               # (bn, r)
    y = jnp.dot(w, z_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)   # (bn, bd)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "h", "interpret", "bn", "bd"))
def _expand_call(z, n: int, h: float, *, interpret, bn, bd):
    b, r, d = z.shape
    grid = (b, d // bd, n // bn)
    return pl.pallas_call(
        functools.partial(_expand_kernel, bn=bn, r=r, h=h),
        grid=grid,
        in_specs=[pl.BlockSpec((1, r, bd), lambda bi, di, ni: (bi, 0, di))],
        out_specs=pl.BlockSpec((1, bn, bd), lambda bi, di, ni: (bi, ni, di)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), z.dtype),
        interpret=interpret,
    )(z)


def _expand_padded(z, n, h, interpret, bn, bd):
    b, r, d = z.shape
    np_, dp = backend.round_up(n, bn), backend.round_up(d, bd)
    if dp != d:
        z = jnp.pad(z, ((0, 0), (0, 0), (0, dp - d)))
    out = _expand_call(z, np_, h, interpret=interpret, bn=bn, bd=bd)
    return out[:, :n, :d] if (np_ != n or dp != d) else out


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3, 4, 5, 6))
def _expand_core(z, n, r, h, interpret, bn, bd):
    return _expand_padded(z, n, h, interpret, bn, bd)


def _expand_core_fwd(z, n, r, h, interpret, bn, bd):
    return _expand_core(z, n, r, h, interpret, bn, bd), None


def _expand_core_bwd(n, r, h, interpret, bn, bd, res, g):
    del res                                           # residual-free
    if not backend.resolve_pallas_grad():
        from repro.kernels import ref
        w = ref.hat_interp_matrix(n, r)
        dz = jnp.einsum("nr,bnd->brd", w, g.astype(jnp.float32))
        return (dz.astype(g.dtype),)
    rbn, rbd = _reduce_blocks(n, g.shape[2], g.dtype, interpret)
    return (_reduce_padded(g, r, h, interpret, rbn, rbd),)


_expand_core.defvjp(_expand_core_fwd, _expand_core_bwd)


def interp_expand_pallas(z, idx_lo, w_lo, *, interpret=None, bn=None, bd=None):
    """y = W z. z: (b, r, d) -> (b, n, d) with n = idx_lo.shape[0].
    Differentiable in z (custom VJP: the backward is one reduce launch)."""
    del w_lo
    n = int(idx_lo.shape[0])
    b, r, d = z.shape
    interpret = backend.resolve_interpret(interpret)
    h = (n - 1) / (r - 1)
    if bn is None or bd is None:
        tune = None
        if backend.is_concrete(z):
            tune = lambda BN, BD: _expand_padded(z, n, h, interpret, BN, BD)
        hbn, hbd = backend.get_blocks("interp_expand", n, d, z.dtype,
                                      interpret, tune_call=tune,
                                      extra=f"r={r}")
        bn = bn or hbn
        bd = bd or hbd
    bn, bd = backend.clamp_blocks(bn, bd, n, d, interpret)
    return _expand_core(z, n, r, h, interpret, bn, bd)
