"""Pallas TPU kernel: depthwise short 1-D convolution (sparse Toeplitz part).

TPU adaptation of the paper's ``T_sparse`` action (§3.2): the m-diagonal
band is applied as m shifted VPU multiply-adds over VMEM-resident tiles.
Halo exchange is done by passing the same HBM array under three BlockSpecs
(prev / cur / next block), masked at the sequence edges — no gather, no
sparse tensors (the paper's PyTorch pain point, DESIGN §3).

Layout: x (b, n, d) tiled (1, BN, BD); filter (d, m) tiled (BD, m).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(prev_ref, cur_ref, nxt_ref, filt_ref, o_ref, *, m, left, bn, nb_total):
    nb = pl.program_id(2)
    hl = m - 1 - left          # left halo
    hr = left                  # right halo
    prev = prev_ref[0]         # (bn, bd)
    cur = cur_ref[0]
    nxt = nxt_ref[0]
    # mask halos at the sequence boundary (zero padding semantics)
    prev = jnp.where(nb > 0, prev, jnp.zeros_like(prev))
    nxt = jnp.where(nb < nb_total - 1, nxt, jnp.zeros_like(nxt))
    xwin = jnp.concatenate([prev[bn - hl:], cur] + ([nxt[:hr]] if hr else []),
                           axis=0) if hl else jnp.concatenate(
                               [cur] + ([nxt[:hr]] if hr else []), axis=0)
    acc = jnp.zeros(cur.shape, jnp.float32)
    f = filt_ref[...].astype(jnp.float32)          # (bd, m)
    for k in range(m):
        sl = xwin[(m - 1 - k):(m - 1 - k) + bn].astype(jnp.float32)
        acc = acc + sl * f[:, k][None, :]
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "interpret", "bn", "bd"))
def short_conv_pallas(x, filt, causal: bool, *, interpret=True, bn=256, bd=128):
    """x: (b, n, d); filt: (d, m). Matches ref.short_conv_ref."""
    b, n, d = x.shape
    m = filt.shape[-1]
    left = 0 if causal else m // 2
    bn = min(bn, n)
    bd = min(bd, d)
    assert n % bn == 0 and d % bd == 0, (n, bn, d, bd)
    assert bn >= m, "block must cover the filter halo"
    nb, db = n // bn, d // bd
    grid = (b, db, nb)

    def xmap(shift):
        def f(bi, di, ni):
            return (bi, jnp.clip(ni + shift, 0, nb - 1), di)
        return f

    out = pl.pallas_call(
        functools.partial(_kernel, m=m, left=left, bn=bn, nb_total=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bd), xmap(-1)),
            pl.BlockSpec((1, bn, bd), xmap(0)),
            pl.BlockSpec((1, bn, bd), xmap(+1)),
            pl.BlockSpec((bd, m), lambda bi, di, ni: (di, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, bd), lambda bi, di, ni: (bi, ni, di)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), x.dtype),
        interpret=interpret,
    )(x, x, x, filt)
    return out
