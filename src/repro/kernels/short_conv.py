"""Pallas TPU kernel: depthwise short 1-D convolution (sparse Toeplitz part).

TPU adaptation of the paper's ``T_sparse`` action (§3.2): the m-diagonal
band is applied as m shifted VPU multiply-adds over VMEM-resident tiles.
Halo exchange is done by passing the same HBM array under three BlockSpecs
(prev / cur / next block), masked at the sequence edges — no gather, no
sparse tensors (the paper's PyTorch pain point, DESIGN §3).

Layout: x (b, n, d) tiled (1, BN, BD); filter (d, m) tiled (BD, m).

Shape policy (repro.kernels.backend): block sizes come from the autotune
cache / heuristic; n and d that do not divide the tiles are zero-padded up
to the tile multiple and sliced back (zero padding matches the conv's
boundary semantics). When no legal tile covers the filter halo (bn < m,
i.e. tiny n) the jnp reference path is used instead of crashing.

Training path (PR 2): the kernel carries a ``jax.custom_vjp``. Both
cotangents are themselves kernel launches — dx is this same conv with the
taps flipped and the offset mirrored (left → m-1-left), dfilt is the
per-tile correlation reduction of :mod:`repro.kernels.ski_grad`. The
tap offset is therefore generalised from the causal flag to an arbitrary
``left`` ∈ [0, m-1] so the transposed sibling reuses one kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend


def _kernel(prev_ref, cur_ref, nxt_ref, filt_ref, o_ref, *, m, left, bn, nb_total):
    nb = pl.program_id(2)
    hl = m - 1 - left          # left halo
    hr = left                  # right halo
    prev = prev_ref[0]         # (bn, bd)
    cur = cur_ref[0]
    nxt = nxt_ref[0]
    # mask halos at the sequence boundary (zero padding semantics)
    prev = jnp.where(nb > 0, prev, jnp.zeros_like(prev))
    nxt = jnp.where(nb < nb_total - 1, nxt, jnp.zeros_like(nxt))
    xwin = jnp.concatenate([prev[bn - hl:], cur] + ([nxt[:hr]] if hr else []),
                           axis=0) if hl else jnp.concatenate(
                               [cur] + ([nxt[:hr]] if hr else []), axis=0)
    acc = jnp.zeros(cur.shape, jnp.float32)
    f = filt_ref[...].astype(jnp.float32)          # (bd, m)
    for k in range(m):
        sl = xwin[(m - 1 - k):(m - 1 - k) + bn].astype(jnp.float32)
        acc = acc + sl * f[:, k][None, :]
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("left", "interpret", "bn", "bd"))
def _short_conv_call(x, filt, left: int, *, interpret, bn, bd):
    """Tiled pallas_call; requires n % bn == 0, d % bd == 0, bn >= m."""
    b, n, d = x.shape
    m = filt.shape[-1]
    nb, db = n // bn, d // bd
    grid = (b, db, nb)

    def xmap(shift):
        def f(bi, di, ni):
            return (bi, jnp.clip(ni + shift, 0, nb - 1), di)
        return f

    return pl.pallas_call(
        functools.partial(_kernel, m=m, left=left, bn=bn, nb_total=nb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, bd), xmap(-1)),
            pl.BlockSpec((1, bn, bd), xmap(0)),
            pl.BlockSpec((1, bn, bd), xmap(+1)),
            pl.BlockSpec((bd, m), lambda bi, di, ni: (di, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, bd), lambda bi, di, ni: (bi, ni, di)),
        out_shape=jax.ShapeDtypeStruct((b, n, d), x.dtype),
        interpret=interpret,
    )(x, x, x, filt)


def _padded_call(x, filt, left, interpret, bn, bd):
    b, n, d = x.shape
    np_, dp = backend.round_up(n, bn), backend.round_up(d, bd)
    if np_ != n or dp != d:
        xp = jnp.pad(x, ((0, 0), (0, np_ - n), (0, dp - d)))
        fp = jnp.pad(filt, ((0, dp - d), (0, 0)))
        return _short_conv_call(xp, fp, left, interpret=interpret,
                                bn=bn, bd=bd)[:, :n, :d]
    return _short_conv_call(x, filt, left, interpret=interpret, bn=bn, bd=bd)


# --------------------------------------------------------------- custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _short_conv_core(x, filt, left, interpret, bn, bd):
    """Differentiable kernel core: y_j = Σ_k f_k x_{j-k+left}."""
    return _padded_call(x, filt, left, interpret, bn, bd)


def _short_conv_core_fwd(x, filt, left, interpret, bn, bd):
    # residuals: the inputs only (recompute policy — backend.py docstring)
    return _short_conv_core(x, filt, left, interpret, bn, bd), (x, filt)


def _short_conv_core_bwd(left, interpret, bn, bd, res, g):
    x, filt = res
    m = filt.shape[-1]
    if not backend.resolve_pallas_grad():
        from repro.kernels import ref
        dx = ref._shift_conv(g, jnp.flip(filt, axis=-1), m - 1 - left)
        return (dx.astype(x.dtype),
                ref.conv_tap_grad_ref(g, x, m, left).astype(filt.dtype))
    # dx: correlation = same kernel, flipped taps, mirrored offset
    dx = _padded_call(g, jnp.flip(filt, axis=-1), m - 1 - left, interpret,
                      bn, bd)
    from repro.kernels.ski_grad import conv_tap_grad_pallas
    df = conv_tap_grad_pallas(g, x, m, left, interpret=interpret)
    return dx.astype(x.dtype), df.astype(filt.dtype)


_short_conv_core.defvjp(_short_conv_core_fwd, _short_conv_core_bwd)


def short_conv_pallas(x, filt, causal: bool, *, interpret=None,
                      bn=None, bd=None, left=None):
    """x: (b, n, d); filt: (d, m). Matches ref.short_conv_ref for any n, d.

    Differentiable in (x, filt) via the custom VJP above. ``left``
    overrides the causal-derived tap offset (used by the backward-sibling
    launches; ``None`` keeps the public causal/bidirectional semantics).
    """
    b, n, d = x.shape
    m = filt.shape[-1]
    if left is None:
        left = 0 if causal else m // 2
    interpret = backend.resolve_interpret(interpret)
    if bn is None or bd is None:
        tune = None
        if backend.is_concrete(x, filt):
            tune = lambda BN, BD: _padded_call(x, filt, left, interpret, BN, BD)
        hbn, hbd = backend.get_blocks("short_conv", n, d, x.dtype, interpret,
                                      tune_call=tune, extra=f"m={m}")
        bn = bn or hbn
        bd = bd or hbd
    bn, bd = backend.clamp_blocks(bn, bd, n, d, interpret)
    if bn < m:
        # no tile covers the filter halo (n < m): reference path, not a crash
        from repro.kernels import ref
        return ref.short_conv_left_ref(x, filt, left)
    return _short_conv_core(x, filt, left, interpret, bn, bd)
