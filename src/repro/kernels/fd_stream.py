"""Streaming overlap-save decode for causal TNO/FD mixers.

Hist-replay decode (models/serving.py, PR 0-3) answers every token by
re-running the full Toeplitz action against the whole input history:
O(n·d) multiply-adds per token, O(n²·d) per sequence — exactly the
deployment gap "Accelerating Toeplitz Neural Network with Constant-time
Inference Complexity" (Qin & Zhong, 2023) identifies. This module replaces
the ``{"hist": (b, n, d)}`` cache with an **overlap-save block scheme**:

* a **ring buffer** of the last C tokens — the causal contribution of the
  current (partial) block is a masked (d, C) head matmul, O(C·d) = O(d)
  per token for fixed C;
* **precomputed kernel-tail contributions**: when a block of C tokens
  retires (every C steps), one length-2C rfft turns it into a cached
  block spectrum, and the tail contributions of *all* retired blocks to
  the next C positions are refreshed by summing cached block spectra
  against precomputed kernel-segment spectra and one length-2C irfft —
  O(d log C) FFT work amortised per token plus an O(n·d/C) spectral
  accumulation per boundary (vs O(n·d) *every token* for hist-replay).

Exactness: the kernel segment for a block of age m covers lags
(m-1)C+1 .. (m+1)C-1; a length-2C circular convolution of the C-sample
block with that segment is wraparound-free on the C output samples used
(both factors fit in 2C), so the decode is the *exact* causal Toeplitz
action — streaming output ≡ hist-replay output to fp accumulation order.

``stream_push_block`` feeds C tokens at once through the same machinery
(intra-block causal conv via the head spectrum + the identical boundary
refresh), which is what chunked prefill is: the prompt enters block-wise
at FFT speed instead of token-by-token (models/serving.decode_chunk).

Everything here is jnp (decode shapes are tiny and latency-bound; the
FFTs are the kernels). Policy knobs live in kernels/backend.py:
``REPRO_FD_STREAM`` (enable), ``REPRO_FD_STREAM_C`` (block size C).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stream_block_size(cache: dict) -> int:
    """C of a streaming cache (static: ring is (b, C, d))."""
    return cache["ring"].shape[1]


def is_stream_cache(cache) -> bool:
    return isinstance(cache, dict) and "ring" in cache


def fd_stream_cache(k_causal: jax.Array, batch: int, max_len: int,
                    C: int) -> dict:
    """Build the overlap-save cache for one causal-TNO layer.

    k_causal: (d, L) time-domain causal kernel, lags 0..L-1, L >= max_len
    (``fd_kernel_time(...)[:, :max_len]`` for the FD mixer). All spectra
    are fp32 re/im planes (complex leaves would break dtype-uniform cache
    pytrees). Layout:

    * ring (b, C, d) — slot i holds the token at position T+i of the
      current block [T, T+C)
    * tail (b, C, d) — tail[i] = Σ_{s < T} k[T+i-s]·u_s, precomputed
    * uspec_re/im (b, NB, F, d) — rfft(2C) of each retired block (F=C+1)
    * khead (d, C), khs_re/im (F, d), kseg_re/im (NB, F, d) — kernel
      constants: head taps, head spectrum (chunked prefill), and the
      per-age tail-segment spectra
    """
    d, ll = k_causal.shape
    if ll < max_len:
        raise ValueError(f"kernel covers {ll} lags < max_len={max_len}")
    nb = -(-max_len // C)                                  # retired blocks
    k = k_causal.astype(jnp.float32)
    khead = k[:, :C]                                       # lags 0..C-1
    khs = jnp.fft.rfft(khead, n=2 * C, axis=-1)            # (d, F)
    # age-m segment: lags (m-1)C+1 .. (m+1)C-1 (2C-1 taps, zero past L)
    kp = jnp.pad(k, ((0, 0), (0, (nb + 1) * C)))
    segs = jnp.stack(
        [jax.lax.dynamic_slice(kp, (0, (m - 1) * C + 1), (d, 2 * C - 1))
         for m in range(1, nb + 1)], axis=0)               # (nb, d, 2C-1)
    ks = jnp.fft.rfft(segs, n=2 * C, axis=-1)              # (nb, d, F)
    f = C + 1
    return {
        "ring": jnp.zeros((batch, C, d), jnp.float32),
        "tail": jnp.zeros((batch, C, d), jnp.float32),
        "uspec_re": jnp.zeros((batch, nb, f, d), jnp.float32),
        "uspec_im": jnp.zeros((batch, nb, f, d), jnp.float32),
        "khead": khead,
        "khs_re": jnp.real(khs).T, "khs_im": jnp.imag(khs).T,      # (F, d)
        "kseg_re": jnp.swapaxes(jnp.real(ks), 1, 2),               # (nb,F,d)
        "kseg_im": jnp.swapaxes(jnp.imag(ks), 1, 2),
    }


def _tail_from_specs(usr, usi, ksr_all, ksi_all, j):
    """Tail contributions for the block after block j retires: sum the
    cached block spectra against the kernel segment of their age
    (block j' has age m = j+1-j' → segment index j-j'), one irfft."""
    b, nb, f, d = usr.shape
    two_c = 2 * (f - 1)
    jp = jnp.arange(nb)
    m_idx = j - jp
    ksr = jnp.take(ksr_all, jnp.clip(m_idx, 0, nb - 1), axis=0)
    ksi = jnp.take(ksi_all, jnp.clip(m_idx, 0, nb - 1), axis=0)
    # blocks not yet retired (jp > j) hold zero spectra; the mask also
    # guards the clipped (wrong-age) segment lookup for them
    valid = (m_idx >= 0).astype(jnp.float32)[None, :, None, None]
    accr = jnp.sum(valid * (usr * ksr[None] - usi * ksi[None]), axis=1)
    acci = jnp.sum(valid * (usr * ksi[None] + usi * ksr[None]), axis=1)
    full = jnp.fft.irfft(accr + 1j * acci, n=two_c, axis=1)  # (b, 2C, d)
    c = f - 1
    return full[:, c - 1:2 * c - 1, :]


def _retire(ring, usr, usi, ksr, ksi, j):
    """Cache the retiring block's spectrum (the one new length-2C rfft of
    the boundary) and refresh the tail for the next block."""
    u_spec = jnp.fft.rfft(ring.astype(jnp.float32), n=2 * ring.shape[1],
                          axis=1)                          # (b, F, d)
    usr = jax.lax.dynamic_update_slice(
        usr, jnp.real(u_spec)[:, None], (0, j, 0, 0))
    usi = jax.lax.dynamic_update_slice(
        usi, jnp.imag(u_spec)[:, None], (0, j, 0, 0))
    return _tail_from_specs(usr, usi, ksr, ksi, j), usr, usi


def stream_step(cache: dict, u: jax.Array, t) -> tuple[jax.Array, dict]:
    """One decode step: u (b, d) is the mixer input at position ``t``
    (traced int32). Returns (y (b, d) fp32, new cache).

    y_t = tail[t mod C] + Σ_{q=0..t mod C} khead[q]·u_{t-q}; when the
    step completes a block, the boundary refresh runs under ``lax.cond``
    so the O(n·d/C + d·C log C) work executes every C steps only.
    """
    ring, tail = cache["ring"], cache["tail"]
    b, c, d = ring.shape
    p = jnp.mod(t, c)
    ring = jax.lax.dynamic_update_slice(
        ring, u.astype(ring.dtype)[:, None, :], (0, p, 0))
    # direct head: ring slot i holds position T+i → lag p-i, masked to the
    # tokens of the current block seen so far
    idx = jnp.arange(c)
    tau = p - idx
    kmat = jnp.where(tau >= 0,
                     jnp.take(cache["khead"], jnp.clip(tau, 0, c - 1),
                              axis=1), 0.0)                # (d, C)
    y = jnp.einsum("bcd,dc->bd", ring.astype(jnp.float32), kmat)
    y = y + jax.lax.dynamic_slice(tail, (0, p, 0), (b, 1, d))[:, 0]

    j = t // c

    def _boundary(args):
        ring_, usr, usi = args
        return _retire(ring_, usr, usi, cache["kseg_re"], cache["kseg_im"],
                       j)

    def _keep(args):
        del args
        return tail, cache["uspec_re"], cache["uspec_im"]

    tail2, usr2, usi2 = jax.lax.cond(
        jnp.mod(t + 1, c) == 0, _boundary, _keep,
        (ring, cache["uspec_re"], cache["uspec_im"]))
    new = dict(cache, ring=ring, tail=tail2, uspec_re=usr2, uspec_im=usi2)
    return y, new


def stream_push_block(cache: dict, u_block: jax.Array,
                      t0) -> tuple[jax.Array, dict]:
    """Chunked prefill: feed a FULL block of C tokens at positions
    [t0, t0+C), t0 ≡ 0 (mod C). Returns (y (b, C, d) fp32, new cache).

    The intra-block causal conv runs through the head spectrum (the
    length-2C circular conv is wraparound-free on its first C samples),
    reusing the rfft that retires the block — equivalent to C
    :func:`stream_step` calls, at FFT speed.
    """
    b, c, d = cache["ring"].shape
    uf = u_block.astype(jnp.float32)
    u_spec = jnp.fft.rfft(uf, n=2 * c, axis=1)             # (b, F, d)
    ur, ui = jnp.real(u_spec), jnp.imag(u_spec)
    khr, khi = cache["khs_re"][None], cache["khs_im"][None]
    yr = ur * khr - ui * khi
    yi = ur * khi + ui * khr
    y = jnp.fft.irfft(yr + 1j * yi, n=2 * c, axis=1)[:, :c] + cache["tail"]

    j = t0 // c
    usr = jax.lax.dynamic_update_slice(
        cache["uspec_re"], ur[:, None], (0, j, 0, 0))
    usi = jax.lax.dynamic_update_slice(
        cache["uspec_im"], ui[:, None], (0, j, 0, 0))
    tail = _tail_from_specs(usr, usi, cache["kseg_re"], cache["kseg_im"], j)
    new = dict(cache, ring=uf, tail=tail, uspec_re=usr, uspec_im=usi)
    return y, new
