"""Streaming overlap-save decode for causal TNO/FD mixers.

Hist-replay decode (models/serving.py, PR 0-3) answers every token by
re-running the full Toeplitz action against the whole input history:
O(n·d) multiply-adds per token, O(n²·d) per sequence — exactly the
deployment gap "Accelerating Toeplitz Neural Network with Constant-time
Inference Complexity" (Qin & Zhong, 2023) identifies. This module replaces
the ``{"hist": (b, n, d)}`` cache with an **overlap-save block scheme**:

* a **ring buffer** of the last C tokens — the causal contribution of the
  current (partial) block is a masked (d, C) head matmul, O(C·d) = O(d)
  per token for fixed C;
* **precomputed kernel-tail contributions**: when a block of C tokens
  retires (every C steps), one length-2C rfft turns it into a cached
  block spectrum, and the tail contributions of *all* retired blocks to
  the next C positions are refreshed by summing cached block spectra
  against precomputed kernel-segment spectra and one length-2C irfft —
  O(d log C) FFT work amortised per token plus an O(n·d/C) spectral
  accumulation per boundary (vs O(n·d) *every token* for hist-replay).

Exactness: the kernel segment for a block of age m covers lags
(m-1)C+1 .. (m+1)C-1; a length-2C circular convolution of the C-sample
block with that segment is wraparound-free on the C output samples used
(both factors fit in 2C), so the decode is the *exact* causal Toeplitz
action — streaming output ≡ hist-replay output to fp accumulation order.

``stream_push_block`` feeds C tokens at once through the same machinery
(intra-block causal conv via the head spectrum + the identical boundary
refresh), which is what chunked prefill is: the prompt enters block-wise
at FFT speed instead of token-by-token (models/serving.decode_chunk).

**Ragged slots (PR 5):** ``stream_step`` accepts either one scalar
position (all batch rows in lockstep — the single-request decode loop) or
a ``(b,)`` per-slot position vector (continuous batching — each slot of
the serving engine sits at its own ring phase and block index). The
vector path is the same arithmetic applied row-wise: per-slot ring write,
per-slot masked head taps, per-slot tail gather, and a boundary refresh
that fires under one ``lax.cond`` whenever *any* slot completes a block,
applied only to the slots at a boundary. The scalar path is the vector
path with the position broadcast, so lockstep and ragged decode are
bit-identical per row.

Everything here is jnp (decode shapes are tiny and latency-bound; the
FFTs are the kernels). Policy knobs live in kernels/backend.py:
``REPRO_FD_STREAM`` (enable), ``REPRO_FD_STREAM_C`` (block size C).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stream_block_size(cache: dict) -> int:
    """C of a streaming cache (static: ring is (b, C, d))."""
    return cache["ring"].shape[1]


def is_stream_cache(cache) -> bool:
    return isinstance(cache, dict) and "ring" in cache


def stream_capacity(cache: dict) -> int:
    """Slot capacity (max positions) of a streaming cache. Encoded in the
    SHAPE of the zero-element ``cap`` leaf so it is static under jit and
    costs no memory (an int leaf would trace; shapes never do)."""
    return cache["cap"].shape[0]


def fd_stream_cache(k_causal: jax.Array, batch: int, max_len: int,
                    C: int) -> dict:
    """Build the overlap-save cache for one causal-TNO layer.

    k_causal: (d, L) time-domain causal kernel, lags 0..L-1, L >= max_len
    (``fd_kernel_time(...)[:, :max_len]`` for the FD mixer). All spectra
    are fp32 re/im planes (complex leaves would break dtype-uniform cache
    pytrees). Layout:

    * ring (b, C, d) — slot i holds the token at position T+i of the
      current block [T, T+C)
    * tail (b, C, d) — tail[i] = Σ_{s < T} k[T+i-s]·u_s, precomputed
    * uspec_re/im (b, NB, F, d) — rfft(2C) of each retired block (F=C+1)
    * khead (d, C), khs_re/im (F, d), kseg_re/im (NB, F, d) — kernel
      constants: head taps, head spectrum (chunked prefill), and the
      per-age tail-segment spectra
    * cap (max_len, 0) — zero-element capacity marker: the slot capacity
      is its leading SHAPE dim (static under jit; see stream_capacity).
      Feeding a position >= capacity would write past the uspec block
      table and silently corrupt the decode — callers (the serving
      engine's insert/admission) gate on stream_capacity instead.
    """
    d, ll = k_causal.shape
    if ll < max_len:
        raise ValueError(f"kernel covers {ll} lags < max_len={max_len}")
    nb = -(-max_len // C)                                  # retired blocks
    k = k_causal.astype(jnp.float32)
    khead = k[:, :C]                                       # lags 0..C-1
    khs = jnp.fft.rfft(khead, n=2 * C, axis=-1)            # (d, F)
    # age-m segment: lags (m-1)C+1 .. (m+1)C-1 (2C-1 taps, zero past L)
    kp = jnp.pad(k, ((0, 0), (0, (nb + 1) * C)))
    segs = jnp.stack(
        [jax.lax.dynamic_slice(kp, (0, (m - 1) * C + 1), (d, 2 * C - 1))
         for m in range(1, nb + 1)], axis=0)               # (nb, d, 2C-1)
    ks = jnp.fft.rfft(segs, n=2 * C, axis=-1)              # (nb, d, F)
    f = C + 1
    return {
        "ring": jnp.zeros((batch, C, d), jnp.float32),
        "tail": jnp.zeros((batch, C, d), jnp.float32),
        "uspec_re": jnp.zeros((batch, nb, f, d), jnp.float32),
        "uspec_im": jnp.zeros((batch, nb, f, d), jnp.float32),
        "khead": khead,
        "khs_re": jnp.real(khs).T, "khs_im": jnp.imag(khs).T,      # (F, d)
        "kseg_re": jnp.swapaxes(jnp.real(ks), 1, 2),               # (nb,F,d)
        "kseg_im": jnp.swapaxes(jnp.imag(ks), 1, 2),
        "cap": jnp.zeros((max_len, 0), jnp.float32),
    }


def _tail_from_specs(usr, usi, ksr_all, ksi_all, j):
    """Tail contributions for the block after block j retires: sum the
    cached block spectra against the kernel segment of their age
    (block j' has age m = j+1-j' → segment index j-j'), one irfft.

    ``j`` — scalar block index (lockstep) or (b,) per-slot indices
    (ragged); the scalar case is the vector case broadcast."""
    b, nb, f, d = usr.shape
    two_c = 2 * (f - 1)
    jp = jnp.arange(nb)
    jv = jnp.broadcast_to(jnp.asarray(j, jnp.int32), (b,))
    m_idx = jv[:, None] - jp[None, :]                      # (b, nb)
    ksr = jnp.take(ksr_all, jnp.clip(m_idx, 0, nb - 1), axis=0)  # (b,nb,F,d)
    ksi = jnp.take(ksi_all, jnp.clip(m_idx, 0, nb - 1), axis=0)
    # blocks not yet retired (jp > j) hold zero spectra; the mask also
    # guards the clipped (wrong-age) segment lookup for them
    valid = (m_idx >= 0).astype(jnp.float32)[:, :, None, None]
    accr = jnp.sum(valid * (usr * ksr - usi * ksi), axis=1)
    acci = jnp.sum(valid * (usr * ksi + usi * ksr), axis=1)
    full = jnp.fft.irfft(accr + 1j * acci, n=two_c, axis=1)  # (b, 2C, d)
    c = f - 1
    return full[:, c - 1:2 * c - 1, :]


def stream_step(cache: dict, u: jax.Array, t) -> tuple[jax.Array, dict]:
    """One decode step: u (b, d) is the mixer input at position ``t`` —
    a traced int32 scalar (every row at the same position) or a (b,)
    vector of per-slot positions (ragged continuous batching). Returns
    (y (b, d) fp32, new cache).

    y_t = tail[t mod C] + Σ_{q=0..t mod C} khead[q]·u_{t-q}; when a step
    completes a block, the boundary refresh runs under ``lax.cond`` —
    lockstep: every C steps; ragged: whenever *any* slot finishes its
    block, applied (masked) only to the slots at a boundary, so slots
    mid-block keep their tail/spectra bit-for-bit.
    """
    ring, tail = cache["ring"], cache["tail"]
    b, c, d = ring.shape
    nb = cache["uspec_re"].shape[1]
    tv = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))  # (b,) positions
    p = jnp.mod(tv, c)
    sel = jnp.arange(c)[None, :] == p[:, None]             # (b, C) ring slot
    ring = jnp.where(sel[..., None], u.astype(ring.dtype)[:, None, :], ring)
    # direct head: ring slot i holds position T+i → lag p-i, masked to the
    # tokens of the current block seen so far
    idx = jnp.arange(c)
    tau = p[:, None] - idx[None, :]                        # (b, C)
    kmat = jnp.where(tau[None] >= 0,
                     jnp.take(cache["khead"], jnp.clip(tau, 0, c - 1),
                              axis=1), 0.0)                # (d, b, C)
    y = jnp.einsum("bcd,dbc->bd", ring.astype(jnp.float32), kmat)
    y = y + jnp.take_along_axis(tail, p[:, None, None], axis=1)[:, 0]

    boundary = jnp.mod(tv + 1, c) == 0                     # (b,)
    j = tv // c                                            # (b,) block index

    def _boundary(args):
        ring_, usr, usi, tail_ = args
        u_spec = jnp.fft.rfft(ring_.astype(jnp.float32), n=2 * c, axis=1)
        # write each *boundary* row's block spectrum at that row's index j
        wsel = ((jnp.arange(nb)[None, :] == jnp.clip(j, 0, nb - 1)[:, None])
                & boundary[:, None])                       # (b, nb)
        usr2 = jnp.where(wsel[:, :, None, None], jnp.real(u_spec)[:, None],
                         usr)
        usi2 = jnp.where(wsel[:, :, None, None], jnp.imag(u_spec)[:, None],
                         usi)
        fresh = _tail_from_specs(usr2, usi2, cache["kseg_re"],
                                 cache["kseg_im"], j)
        return (jnp.where(boundary[:, None, None], fresh, tail_),
                usr2, usi2)

    def _keep(args):
        _, usr, usi, tail_ = args
        return tail_, usr, usi

    tail2, usr2, usi2 = jax.lax.cond(
        jnp.any(boundary), _boundary, _keep,
        (ring, cache["uspec_re"], cache["uspec_im"], tail))
    new = dict(cache, ring=ring, tail=tail2, uspec_re=usr2, uspec_im=usi2)
    return y, new


def stream_push_block(cache: dict, u_block: jax.Array,
                      t0) -> tuple[jax.Array, dict]:
    """Chunked prefill: feed a FULL block of C tokens at positions
    [t0, t0+C), t0 ≡ 0 (mod C). Returns (y (b, C, d) fp32, new cache).

    The intra-block causal conv runs through the head spectrum (the
    length-2C circular conv is wraparound-free on its first C samples),
    reusing the rfft that retires the block — equivalent to C
    :func:`stream_step` calls, at FFT speed.
    """
    b, c, d = cache["ring"].shape
    uf = u_block.astype(jnp.float32)
    u_spec = jnp.fft.rfft(uf, n=2 * c, axis=1)             # (b, F, d)
    ur, ui = jnp.real(u_spec), jnp.imag(u_spec)
    khr, khi = cache["khs_re"][None], cache["khs_im"][None]
    yr = ur * khr - ui * khi
    yi = ur * khi + ui * khr
    y = jnp.fft.irfft(yr + 1j * yi, n=2 * c, axis=1)[:, :c] + cache["tail"]

    j = t0 // c
    usr = jax.lax.dynamic_update_slice(
        cache["uspec_re"], ur[:, None], (0, j, 0, 0))
    usi = jax.lax.dynamic_update_slice(
        cache["uspec_im"], ui[:, None], (0, j, 0, 0))
    tail = _tail_from_specs(usr, usi, cache["kseg_re"], cache["kseg_im"], j)
    new = dict(cache, ring=uf, tail=tail, uspec_re=usr, uspec_im=usi)
    return y, new
