"""Backend selection, block-size autotuning, and ragged-shape policy for
the Pallas kernels.

This module is the single place that decides *how* a kernel runs:

* **Platform detection** — ``platform()`` reports the active JAX backend.
  On TPU the Pallas kernels compile (``interpret=False``); everywhere else
  they run in interpret mode (kernel body executed by the Pallas
  interpreter), and the jnp reference oracles are the default execution
  path (``use_pallas`` resolves to False unless forced).
* **Block sizing** — ``get_blocks`` returns (bn, bd) tile sizes for a
  (kernel, n, d, dtype, platform) key: first from the on-disk autotune
  cache, else (when autotuning is enabled and the inputs are concrete) by
  timing a small candidate sweep, else from a shape-fitted heuristic.
* **Ragged shapes** — ``fit_block`` / ``round_up`` let callers pick tiles
  for n/d that do *not* divide the defaults; kernels zero-pad up to the
  tile multiple and slice the result (zero padding is semantics-preserving
  for every kernel in this package: conv uses zero boundary conditions and
  the interp/gram contractions are linear).

Training-path dispatch (PR 2)
-----------------------------
The Pallas ops carry ``jax.custom_vjp`` rules whose backward passes are
themselves Pallas kernels (transposed siblings of the forwards — see
:mod:`repro.kernels.ski_vjp`), so ``jax.grad`` through the fused SKI
pipeline stays on the kernel path instead of silently requiring the jnp
reference. :func:`resolve_pallas_grad` is the single switch the backward
rules consult at trace time: under "auto" (default) the kernel backward is
used whenever the Pallas forward is; ``REPRO_PALLAS_GRAD=0`` keeps the
Pallas forward but computes cotangents with the jnp reference formulas
(debugging escape hatch / numerical bisection).

Residual/recompute policy: the custom VJPs save only the *inputs* of each
op (plus the per-forward plan already materialised by the caller); no
O(n·r) activation is stored. The pass-1 reduction z = Wᵀx is recomputed
in the backward from the saved x — one extra O(n r d) kernel launch
instead of an (b, r, d) residual held across the whole backward.

Environment knobs (also documented in :mod:`repro.kernels.ops`):

* ``REPRO_USE_PALLAS``    — "1"/"0" force the Pallas/reference path;
  "auto" (default) selects Pallas exactly on TPU.
* ``REPRO_PALLAS_INTERPRET`` — "1"/"0" force interpret/compiled;
  "auto" (default) compiles exactly on TPU.
* ``REPRO_PALLAS_GRAD``   — "1"/"0" force the kernel/reference backward
  under the Pallas forward; "auto" (default) follows the forward path.
* ``REPRO_AUTOTUNE``      — "1" enables the timing sweep on cache miss.
* ``REPRO_AUTOTUNE_CACHE`` — cache file path
  (default ``~/.cache/repro/autotune.json``).

Large-rank SKI dispatch (PR 3)
------------------------------
:func:`ski_rank_variant` is the single policy point that picks how the
fused SKI pipeline applies the r×r inducing Gram:

* ``dense``    — r ≤ 512 (``REPRO_SKI_DENSE_RMAX``) and the (d, r, r)
  dense Gram under the 64 MB budget: the original fused kernel with the
  whole Gram VMEM-resident per d-tile.
* ``windowed`` — 512 < r ≤ 4096 (``REPRO_SKI_WINDOWED_RMAX``): the O(n)
  banded-W kernel streaming (bw, bw) Toeplitz band blocks regenerated
  from coefficients; the band width follows the sequence tile via
  :func:`band_fit` under the ``REPRO_SKI_BAND_MAX`` budget (default 128).
* ``fft``      — beyond the windowed ceiling: the Toeplitz Gram is
  applied by a length-2r rfft/irfft circulant matvec between the two
  kernel passes (O(r log r)); pass 2 is the Gram-free windowed kernel.

The dense form needs the (d, r, r) materialisation (16 GB at r = 8192,
d = 64) — the coefficient-form variants only ever hold (d, 2r-1).
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax

_ENV_BACKEND = "REPRO_USE_PALLAS"
_ENV_INTERPRET = "REPRO_PALLAS_INTERPRET"
_ENV_GRAD = "REPRO_PALLAS_GRAD"
_ENV_AUTOTUNE = "REPRO_AUTOTUNE"
_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
_ENV_DENSE_RMAX = "REPRO_SKI_DENSE_RMAX"
_ENV_WINDOWED_RMAX = "REPRO_SKI_WINDOWED_RMAX"
_ENV_BAND_MAX = "REPRO_SKI_BAND_MAX"
_ENV_FD_STREAM = "REPRO_FD_STREAM"
_ENV_FD_STREAM_C = "REPRO_FD_STREAM_C"

_FORCED_DEFAULT: bool | None = None     # set_default_use_pallas override
_FORCED_GRAD: bool | None = None        # set_default_pallas_grad override


# ------------------------------------------------------------- dispatch
def platform() -> str:
    """Active JAX backend: "cpu" | "tpu" | "gpu"."""
    return jax.default_backend()


def set_default_use_pallas(flag: bool | None) -> None:
    """Programmatic override of the global default (None = back to auto)."""
    global _FORCED_DEFAULT
    _FORCED_DEFAULT = None if flag is None else bool(flag)


def use_pallas_default() -> bool:
    if _FORCED_DEFAULT is not None:
        return _FORCED_DEFAULT
    v = os.environ.get(_ENV_BACKEND, "auto").lower()
    if v in ("1", "true"):
        return True
    if v in ("0", "false"):
        return False
    return platform() == "tpu"


def resolve_use_pallas(flag) -> bool:
    """Explicit per-call flag wins; None falls back to the global policy."""
    return use_pallas_default() if flag is None else bool(flag)


def resolve_interpret(flag=None) -> bool:
    """Compiled Pallas only on TPU unless explicitly forced."""
    if flag is not None:
        return bool(flag)
    v = os.environ.get(_ENV_INTERPRET, "auto").lower()
    if v in ("1", "true"):
        return True
    if v in ("0", "false"):
        return False
    return platform() != "tpu"


def set_default_pallas_grad(flag: bool | None) -> None:
    """Programmatic override of the backward-path policy (None = auto)."""
    global _FORCED_GRAD
    _FORCED_GRAD = None if flag is None else bool(flag)


def resolve_pallas_grad(flag=None) -> bool:
    """Should a Pallas forward use its Pallas backward kernels?

    Consulted (at trace time) by the ``jax.custom_vjp`` backward rules of
    the Pallas ops. "auto" (default) returns True — the kernel backward
    runs whenever the kernel forward was selected; ``REPRO_PALLAS_GRAD=0``
    (or :func:`set_default_pallas_grad`) swaps in the jnp reference
    cotangent formulas while keeping the Pallas forward, for debugging.
    """
    if flag is not None:
        return bool(flag)
    if _FORCED_GRAD is not None:
        return _FORCED_GRAD
    v = os.environ.get(_ENV_GRAD, "auto").lower()
    if v in ("1", "true"):
        return True
    if v in ("0", "false"):
        return False
    return True


def describe() -> str:
    """One-line dispatch summary (logged by the trainer at startup so a
    silent wrong-path run is visible in the step log)."""
    return (f"platform={platform()} use_pallas={use_pallas_default()} "
            f"interpret={resolve_interpret()} "
            f"pallas_grad={resolve_pallas_grad()} "
            f"ski_variant=(dense<={ski_dense_rank_max()}"
            f"<windowed<={ski_windowed_rank_max()}<fft"
            f"|band<={band_budget()}) "
            f"fd_stream={fd_stream_enabled()}(C={fd_stream_block()})")


def log_describe() -> None:
    """Emit the :func:`describe` banner through the obs logger (one INFO
    line; quiet under pytest / ``REPRO_LOG_LEVEL=WARNING``)."""
    from repro.obs import log as obs_log
    obs_log.banner(describe(), "backend")


# ------------------------------------------------- FD streaming decode
def fd_stream_enabled() -> bool:
    """Serving policy: replace the O(n·d)-per-token hist-replay decode of
    ``fd`` mixers with the overlap-save streaming cache
    (kernels/fd_stream.py). "auto" (default) enables it whenever the
    cache can be built (params available at init); ``REPRO_FD_STREAM=0``
    pins the legacy hist-replay cache (debug / A-B comparison)."""
    v = os.environ.get(_ENV_FD_STREAM, "auto").lower()
    if v in ("1", "true", "on"):
        return True
    if v in ("0", "false", "off"):
        return False
    if v in ("auto", ""):
        return True
    # a typo'd knob must not silently serve through a different decode
    # path than the user believes (the describe() banner principle)
    raise ValueError(f"{_ENV_FD_STREAM}={v!r} is not one of "
                     "auto/1/0/true/false/on/off")


def fd_stream_block() -> int:
    """Overlap-save block size C: the ring holds the last C tokens, block
    spectra are length-2C rffts, and the kernel-tail refresh runs every C
    steps. Larger C amortises the refresh further but grows the direct
    head work (O(C·d) per token) and the refresh latency spike."""
    c = _env_int(_ENV_FD_STREAM_C, 64)
    if c < 2:
        raise ValueError(f"{_ENV_FD_STREAM_C}={c} must be >= 2")
    return c


# ------------------------------------------------- large-rank SKI policy
#: dense (d, r, r) Gram budget for the original fused kernel (bytes)
SKI_GRAM_BYTES_MAX = 64 << 20


def _env_int(name: str, default: int) -> int:
    v = os.environ.get(name)
    if v is None or v == "":
        return default
    try:
        return int(v)
    except ValueError:
        # a typo'd knob must not silently dispatch to a different kernel
        # variant than the user believes (the describe() banner principle)
        raise ValueError(f"{name}={v!r} is not an integer") from None


def ski_dense_rank_max() -> int:
    """Largest r served by the dense-Gram fused kernel (the (bd, r, r)
    VMEM panel; paper's dense-beats-FFT observation holds to here)."""
    return _env_int(_ENV_DENSE_RMAX, 512)


def ski_windowed_rank_max() -> int:
    """Largest r served by the windowed banded-W kernel; beyond it the
    per-row O(r) band work loses to the O(log r) FFT-Gram amortisation."""
    return _env_int(_ENV_WINDOWED_RMAX, 4096)


def band_budget() -> int:
    """Max Gram band width bw: per-tile band-block VMEM is bd·bw²·4 B
    (plus the (bd, 2rp-1) coefficient line), so 128 keeps the transient
    block ≤ 0.5 MB at the interpret-default bd=8 and ≤ 8 MB at the
    compiled lane width bd=128."""
    return _env_int(_ENV_BAND_MAX, 128)


def ski_rank_variant(r: int, d: int | None = None) -> str:
    """How the fused SKI pipeline applies the r×r inducing Gram:
    "dense" | "windowed" | "fft" (see module docstring). ``d`` (channels)
    feeds the dense (d, r, r) byte budget when known."""
    if r <= ski_dense_rank_max() and (
            d is None or d * r * r * 4 <= SKI_GRAM_BYTES_MAX):
        return "dense"
    if r <= ski_windowed_rank_max():
        return "windowed"
    return "fft"


def band_width(bn: int, n: int, r: int) -> int:
    """Static Gram band width covering every hat tap of a length-bn
    sequence tile: the tile's rows span (bn-1)/h inducing columns, plus
    one tap each side and fp32-floor slack, rounded to the sublane unit
    and capped at the (padded) grid size."""
    h = (n - 1) / max(1, r - 1)
    bw = round_up(int((bn - 1) / h) + 4, 8)
    return max(8, min(bw, round_up(r, 8)))


def band_fit(bn: int, n: int, r: int) -> tuple[int, int]:
    """(bn, bw) with bn shrunk (halved to the sublane floor) until the
    band fits :func:`band_budget` — band width follows the sequence tile
    (bw ≈ bn·r/n), so shrinking the tile is the legal way to shrink the
    band without changing semantics."""
    bw = band_width(bn, n, r)
    while bw > band_budget() and bn > 8:
        bn = max(8, round_up(bn // 2, 8))
        bw = band_width(bn, n, r)
    return bn, bw


# ---------------------------------------------------------- shape fitting
def round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def lane_unit(interpret: bool) -> int:
    """Last-dim (lane) padding unit: 128 on compiled TPU, 8 elsewhere."""
    return 8 if interpret else 128


def fit_block(size: int, target: int, unit: int = 8) -> int:
    """Largest-balanced block <= target for a possibly-ragged dimension.

    Splits ``size`` into ceil(size/target) near-equal tiles rounded up to
    ``unit`` so padding waste stays < unit per tile (e.g. n=300, target=256
    -> bn=152, padded n=304 — not 512)."""
    if size <= target:
        return round_up(size, unit)
    tiles = -(-size // target)
    return round_up(-(-size // tiles), unit)


# --------------------------------------------------------- autotune cache
_DEFAULT_TARGETS = {
    # kernel -> (bn target, bd target) heuristic starting point
    "short_conv": (256, 128),
    "interp_reduce": (256, 128),
    "interp_expand": (256, 128),
    "ski_fused": (256, 128),
    "ski_windowed": (256, 128),
    "ski_expand2": (256, 128),
    "conv_tap_grad": (256, 128),
    # causal FD-TNO pipeline (kernels/fd_fused.py): freq-tile × d-tile for
    # the spectral multiply / khat reduction, d-tile × lag-tile for the
    # Hilbert lag window
    "fd_mul": (256, 128),
    "fd_khat_grad": (256, 128),
    "hilbert_window": (128, 512),
}

_cache_lock = threading.Lock()
_cache_data: dict | None = None
_pretuned_data: dict | None = None

#: shipped autotune tables (one file per platform×mode, e.g.
#: cpu_interpret.json) — measured once and committed so fresh checkouts
#: start from tuned blocks instead of the shape heuristic. Consulted only
#: when ``REPRO_AUTOTUNE_CACHE`` is unset; an explicit cache file is the
#: user saying "use exactly this table". Precedence:
#: user cache entry > pretuned entry > autotune sweep > heuristic.
PRETUNED_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "pretuned")


def cache_path() -> str:
    return os.environ.get(
        _ENV_CACHE,
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


def _load_pretuned() -> dict:
    global _pretuned_data
    if _pretuned_data is None:
        entries: dict = {}
        try:
            for fn in sorted(os.listdir(PRETUNED_DIR)):
                if not fn.endswith(".json"):
                    continue
                try:
                    with open(os.path.join(PRETUNED_DIR, fn)) as f:
                        data = json.load(f)
                except (OSError, ValueError):
                    continue
                if isinstance(data, dict):
                    entries.update(data.get("entries", {}))
        except OSError:
            pass
        _pretuned_data = entries
    return _pretuned_data


def _load_cache() -> dict:
    global _cache_data
    if _cache_data is None:
        try:
            with open(cache_path()) as f:
                data = json.load(f)
            _cache_data = data.get("entries", {}) if isinstance(data, dict) else {}
        except (OSError, ValueError):
            _cache_data = {}
    return _cache_data


def _save_cache() -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump({"version": 1, "entries": _cache_data}, f, indent=1,
                      sort_keys=True)
    except OSError:
        pass                      # read-only FS: tuning just isn't persisted


def clear_cache(memory_only: bool = False) -> None:
    """Drop the in-memory caches (tests); optionally keep the file. The
    pretuned table memo is reset too so env-var changes re-resolve."""
    global _cache_data, _pretuned_data
    with _cache_lock:
        _cache_data = None
        _pretuned_data = None
        if not memory_only:
            try:
                os.remove(cache_path())
            except OSError:
                pass


def _key(kernel: str, n: int, d: int, dtype, interpret: bool,
         extra: str = "") -> str:
    mode = "interpret" if interpret else "compiled"
    tail = f"|{extra}" if extra else ""
    return (f"{kernel}|n={n}|d={d}|{jax.numpy.dtype(dtype).name}"
            f"|{platform()}|{mode}{tail}")


def autotune_enabled() -> bool:
    return os.environ.get(_ENV_AUTOTUNE, "0").lower() in ("1", "true")


def is_concrete(*arrays) -> bool:
    """True when no argument is a tracer (so timing sweeps are possible)."""
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def heuristic_blocks(kernel: str, n: int, d: int, interpret: bool) -> tuple[int, int]:
    tn, td = _DEFAULT_TARGETS.get(kernel, (256, 128))
    return fit_block(n, tn, 8), fit_block(d, td, lane_unit(interpret))


def clamp_blocks(bn: int, bd: int, n: int, d: int,
                 interpret: bool) -> tuple[int, int]:
    """Shrink cached/requested blocks to the actual array, preserving the
    sublane (8) / lane (128 compiled, 8 interpret) padding units — shared
    by every kernel wrapper so the clamp policy lives in one place."""
    return (min(bn, round_up(n, 8)),
            min(bd, round_up(d, lane_unit(interpret))))


def _candidates(n: int, d: int, interpret: bool):
    ud = lane_unit(interpret)
    bns = sorted({fit_block(n, t, 8) for t in (128, 256, 512)})
    bds = sorted({fit_block(d, t, ud) for t in (128, 256)})
    return [(bn, bd) for bn in bns for bd in bds]


def _time_call(fn, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def get_blocks(kernel: str, n: int, d: int, dtype, interpret: bool,
               tune_call=None, extra: str = "") -> tuple[int, int]:
    """(bn, bd) for a kernel instance:
    cache > pretuned table (env cache unset) > autotune sweep > heuristic.

    ``tune_call(bn, bd)`` must execute the kernel with those blocks and
    return its output; pass it only when the inputs are concrete. ``extra``
    carries further legality/footprint parameters into the cache key
    (e.g. filter width m for short_conv — bn >= m — and rank r for the
    Gram-carrying fused kernel). The sweep runs once per (kernel, shape,
    dtype, platform, mode, extra) and persists to :func:`cache_path`.
    """
    key = _key(kernel, n, d, dtype, interpret, extra)
    with _cache_lock:
        hit = _load_cache().get(key)
    source = "cache"
    if hit is None and os.environ.get(_ENV_CACHE) is None:
        # no explicit cache file: seed from the shipped pretuned tables
        hit = _load_pretuned().get(key)
        source = "pretuned"
    if hit:
        _count_dispatch(kernel, source)
        return int(hit["bn"]), int(hit["bd"])
    if tune_call is not None and autotune_enabled():
        best, best_t = None, float("inf")
        for bn, bd in _candidates(n, d, interpret):
            try:
                t = _time_call(lambda: tune_call(bn, bd))
            except Exception:
                continue
            if t < best_t:
                best, best_t = (bn, bd), t
        if best is not None:
            with _cache_lock:
                _load_cache()[key] = {"bn": best[0], "bd": best[1],
                                      "seconds": best_t}
                _save_cache()
            _count_dispatch(kernel, "autotune")
            return best
    _count_dispatch(kernel, "heuristic")
    return heuristic_blocks(kernel, n, d, interpret)


def _count_dispatch(kernel: str, source: str) -> None:
    """Per-op block-resolution counter (ISSUE 9): how each kernel's
    (bn, bd) was decided — cache hit, shipped pretuned table, fresh
    autotune sweep, or the heuristic fallback. Routed through the lazy
    process default registry (a no-op unless ``REPRO_METRICS`` is set or
    an explicit registry was installed), so the resolve path — already
    trace-time only — costs one no-op call when observability is off."""
    from repro.obs import metrics as obs_metrics
    obs_metrics.default_registry().counter(
        "repro_kernel_dispatch_total",
        "kernel block resolutions by source",
        ("kernel", "source")).labels(kernel=kernel, source=source).inc()
