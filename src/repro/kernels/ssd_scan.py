"""Pallas TPU kernel: Mamba-2 SSD chunked scan (intra + inter chunk fused).

One (batch*head) slice per grid row; the chunk axis is the innermost,
*sequential* grid dimension so the (p, s) running state lives in a VMEM
scratch accumulator across chunk steps — the HBM<->VMEM traffic is exactly
one pass over x/dt/B/C and one (q, p) output tile per chunk, i.e. the
kernel is memory-roofline optimal for the SSD layer.

Per chunk (all MXU matmuls):
  scores = (C Bᵀ) ⊙ L ⊙ dt   (q,q)   y_intra = scores @ X      (q,p)
  y_inter = (C ⊙ e^{cum}) @ Sᵀ        state' = e^{cum_q} S + Xᵀ(B ⊙ w)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dsk_ref, o_ref, state_ref,
            *, q, p, s):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        state_ref[...] = jnp.zeros((p, s), jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # (q, p)
    dt = dt_ref[0].astype(jnp.float32)        # (q,)
    a = a_ref[0]                              # scalar
    bm = b_ref[0].astype(jnp.float32)         # (q, s)
    cm = c_ref[0].astype(jnp.float32)         # (q, s)

    loga = dt * a                             # (q,) <= 0
    cum = jnp.cumsum(loga)                    # inclusive
    seg = cum[:, None] - cum[None, :]         # (q, q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(tri, jnp.exp(seg), 0.0)

    scores = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    scores = scores * l_mat * dt[None, :]
    y = jnp.dot(scores, x, preferred_element_type=jnp.float32)      # intra

    state = state_ref[...]                    # (p, s)
    y = y + jnp.dot(cm * jnp.exp(cum)[:, None], state.T,
                    preferred_element_type=jnp.float32)             # inter

    w = (jnp.exp(cum[-1] - cum) * dt)[:, None]                      # (q, 1)
    state_ref[...] = state * jnp.exp(cum[-1]) + jnp.dot(
        x.T, bm * w, preferred_element_type=jnp.float32)

    y = y + x * dsk_ref[0]
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, a, b, c, d_skip, *, chunk=64, interpret=True):
    """Shapes as ref.ssd_scan_ref. b/c are per-group; repeated to per-head
    outside the kernel (g is small; repeat cost is n*h*s reads)."""
    bt, n, h, p = x.shape
    g, s = b.shape[2], b.shape[3]
    hpg = h // g
    q = min(chunk, n)
    assert n % q == 0, (n, q)
    nc = n // q

    bx = jnp.repeat(b, hpg, axis=2)           # (bt, n, h, s)
    cx = jnp.repeat(c, hpg, axis=2)
    # flatten (bt, h) into one grid axis; layout (bt*h, n, ·)
    xf = jnp.moveaxis(x, 2, 1).reshape(bt * h, n, p)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(bt * h, n)
    bf = jnp.moveaxis(bx, 2, 1).reshape(bt * h, n, s)
    cf = jnp.moveaxis(cx, 2, 1).reshape(bt * h, n, s)
    af = jnp.tile(a, (bt,)).reshape(bt * h)
    df = jnp.tile(d_skip, (bt,)).reshape(bt * h)

    grid = (bt * h, nc)
    out = pl.pallas_call(
        functools.partial(_kernel, q=q, p=p, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, q), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1,), lambda bh, ci: (bh,)),
            pl.BlockSpec((1, q, s), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, q, s), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1,), lambda bh, ci: (bh,)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bt * h, n, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, s), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf, df)
    return jnp.moveaxis(out.reshape(bt, h, n, p), 1, 2)
