"""jit'd dispatch wrappers over the Pallas kernels and their jnp oracles.

Dispatch policy: explicit ``use_pallas`` argument wins; the global default
(set via :func:`set_default_backend` / ``REPRO_USE_PALLAS``) is used
otherwise. On this CPU container the Pallas path runs in interpret mode
(tests); TPU is the compiled target.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref

_DEFAULT_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def set_default_backend(use_pallas: bool) -> None:
    global _DEFAULT_PALLAS
    _DEFAULT_PALLAS = bool(use_pallas)


def _use_pallas(flag) -> bool:
    return _DEFAULT_PALLAS if flag is None else bool(flag)


def short_conv(x, filt, causal: bool, *, use_pallas=None, interpret=True):
    """Depthwise short conv (sparse Toeplitz component). x (b,n,d), filt (d,m)."""
    if _use_pallas(use_pallas):
        from repro.kernels import short_conv as k
        return k.short_conv_pallas(x, filt, causal, interpret=interpret)
    return ref.short_conv_ref(x, filt, causal)


def interp_reduce(x, idx_lo, w_lo, r: int, *, use_pallas=None, interpret=True):
    """z = W^T x, banded linear-interp W. x (b,n,d) -> (b,r,d)."""
    if _use_pallas(use_pallas):
        from repro.kernels import interp_matvec as k
        return k.interp_reduce_pallas(x, idx_lo, w_lo, r, interpret=interpret)
    return ref.interp_reduce_ref(x, idx_lo, w_lo, r)


def interp_expand(z, idx_lo, w_lo, *, use_pallas=None, interpret=True):
    """y = W z. z (b,r,d) -> (b,n,d)."""
    if _use_pallas(use_pallas):
        from repro.kernels import interp_matvec as k
        return k.interp_expand_pallas(z, idx_lo, w_lo, interpret=interpret)
    return ref.interp_expand_ref(z, idx_lo, w_lo)


def ssd_scan(x, dt, a, b, c, d_skip, *, chunk=64, use_pallas=None,
             interpret=True, hshard=None):
    """Mamba-2 SSD. See ref.ssd_scan_ref for shapes."""
    if _use_pallas(use_pallas):
        from repro.kernels import ssd_scan as k
        return k.ssd_scan_pallas(x, dt, a, b, c, d_skip, chunk=chunk,
                                 interpret=interpret)
    from repro.kernels import ssd_chunked
    return ssd_chunked.ssd_scan_chunked(x, dt, a, b, c, d_skip, chunk=chunk,
                                        hshard=hshard)
