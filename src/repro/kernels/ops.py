"""jit'd dispatch wrappers over the Pallas kernels and their jnp oracles.

Dispatch policy (implemented in :mod:`repro.kernels.backend`)
-------------------------------------------------------------
Every op takes ``use_pallas`` / ``interpret`` keywords; ``None`` (the
default) defers to the global policy, resolved in this order:

1. **Explicit argument** — ``use_pallas=True/False`` per call site wins.
2. **Programmatic default** — :func:`set_default_backend`.
3. **Environment** — ``REPRO_USE_PALLAS`` = "1" / "0" / "auto" (default
   "auto"); ``REPRO_PALLAS_INTERPRET`` = "1" / "0" / "auto".
4. **Platform detection** — under "auto", the Pallas path (and compiled,
   non-interpret execution) is selected exactly when
   ``jax.default_backend() == "tpu"``; on CPU/GPU the jnp reference
   oracles run, and any forced Pallas call uses interpret mode.

Training (PR 2): the Pallas wrappers carry ``jax.custom_vjp`` rules whose
backwards are themselves kernel launches (custom_vjp bypasses the
pallas_call autodiff limitation, so this holds in interpret mode too) —
``jax.grad`` through any op here stays on whichever path the forward
dispatched to. ``REPRO_PALLAS_GRAD`` = "0" forces the jnp reference
cotangent formulas under a Pallas forward (debugging escape hatch).

Block sizes are *not* hardcoded: each kernel wrapper asks
``backend.get_blocks(kernel, n, d, dtype, platform, mode)``, which
consults an **on-disk autotune cache** (``REPRO_AUTOTUNE_CACHE``, default
``~/.cache/repro/autotune.json``), runs a timing sweep on miss when
``REPRO_AUTOTUNE=1`` and the inputs are concrete, and otherwise falls back
to a shape-fitted heuristic. Ragged n / d (not multiples of the tile) are
zero-padded to the tile boundary and sliced back — padding is
semantics-preserving for every kernel here (zero-boundary conv, linear
interp/Gram contractions). Shapes too small to tile legally (e.g. n
smaller than the conv filter) fall back to the reference path instead of
asserting.
"""
from __future__ import annotations

from repro.kernels import backend, ref
from repro.obs import devstats as obs_devstats


def set_default_backend(use_pallas: bool | None) -> None:
    """Force the global Pallas/reference default (None = platform auto)."""
    backend.set_default_use_pallas(use_pallas)


def short_conv(x, filt, causal: bool, *, use_pallas=None, interpret=None):
    """Depthwise short conv — the m-tap sparse Toeplitz component.

    x (b, n, d) fp32/bf16; filt (d, m) per-channel taps; returns
    (b, n, d) in x's dtype. ``causal=True`` convolves lags 0..m-1
    (zero left boundary), ``False`` centres the taps. Oracle:
    ref.short_conv_ref; the Pallas kernel tiles the sequence with an
    (m-1)-halo. Backward: flipped taps + mirrored offset for the signal,
    ``conv_tap_grad`` correlation for the taps."""
    with obs_devstats.kernel_region("short_conv"):
        if backend.resolve_use_pallas(use_pallas):
            from repro.kernels import short_conv as k
            return k.short_conv_pallas(x, filt, causal, interpret=interpret)
        return ref.short_conv_ref(x, filt, causal)


def interp_reduce(x, idx_lo, w_lo, r: int, *, use_pallas=None, interpret=None):
    """z = Wᵀ x — project n positions onto r inducing points.

    x (b, n, d) fp32/bf16; idx_lo (n,) int32 lower-neighbour indices and
    w_lo (n,) weights describe the banded linear-interp W (reference
    path only — the Pallas kernel regenerates the hat weights in VMEM
    from the uniform grid); returns (b, r, d) in x's dtype. Oracle:
    ref.interp_reduce_ref. Backward: one :func:`interp_expand` launch
    (W is linear, so the adjoint is the sibling kernel)."""
    with obs_devstats.kernel_region("interp_reduce"):
        if backend.resolve_use_pallas(use_pallas):
            from repro.kernels import interp_matvec as k
            return k.interp_reduce_pallas(x, idx_lo, w_lo, r,
                                          interpret=interpret)
        return ref.interp_reduce_ref(x, idx_lo, w_lo, r)


def interp_expand(z, idx_lo, w_lo, *, use_pallas=None, interpret=None):
    """y = W z — interpolate r inducing values back to n positions.

    z (b, r, d) fp32/bf16; idx_lo (n,) int32 / w_lo (n,) as in
    :func:`interp_reduce` (n is read off idx_lo); returns (b, n, d) in
    z's dtype. Oracle: ref.interp_expand_ref. Backward: one
    :func:`interp_reduce` launch."""
    with obs_devstats.kernel_region("interp_expand"):
        if backend.resolve_use_pallas(use_pallas):
            from repro.kernels import interp_matvec as k
            return k.interp_expand_pallas(z, idx_lo, w_lo,
                                          interpret=interpret)
        return ref.interp_expand_ref(z, idx_lo, w_lo)


def ski_fused_pass2(x, z, a_dense, filt, causal: bool, *, use_pallas=None,
                    interpret=None):
    """Fused SKI pass 2: y = W (A z) + T_sparse x in one kernel / one write.

    x (b,n,d); z = Wᵀx (b,r,d); a_dense (d,r,r); filt (d,m). Together with
    :func:`interp_reduce` (pass 1) this is the two-pass fused SKI-TNO
    pipeline — see kernels/ski_fused.py. Forward-only on the Pallas path
    (z is an already-materialised intermediate); the trainable form is
    :func:`ski_fused_tno`.
    """
    with obs_devstats.kernel_region("ski_fused"):
        if backend.resolve_use_pallas(use_pallas):
            from repro.kernels import ski_fused as k
            return k.ski_fused_pass2_pallas(x, z, a_dense, filt, causal,
                                            interpret=interpret)
        return ref.ski_fused_pass2_ref(x, z, a_dense, filt, causal)


def ski_fused_tno(x, a_dense, filt, idx_lo, w_lo, r: int, causal: bool, *,
                  use_pallas=None, interpret=None):
    """Differentiable two-pass fused SKI-TNO: y = W (A (Wᵀ x)) + T_sparse x.

    x (b,n,d); a_dense (d,r,r) per-channel inducing Gram; filt (d,m);
    idx_lo/w_lo: inducing geometry (ref path only — the Pallas kernels
    regenerate the hat weights from the uniform grid). This is the op the
    TNN block trains through: on the Pallas path it carries a custom VJP
    whose backward is itself kernel launches (kernels/ski_vjp.py), so
    ``jax.grad`` stays at kernel speed instead of silently needing the
    reference; on the reference path plain autodiff applies. The
    ``REPRO_PALLAS_GRAD`` knob (kernels/backend.py) can force the
    reference cotangent formulas under the Pallas forward for debugging.
    """
    with obs_devstats.kernel_region("ski_fused"):
        if backend.resolve_use_pallas(use_pallas):
            from repro.kernels import ski_vjp as k
            return k.ski_fused_tno_pallas(x, a_dense, filt, int(r),
                                          bool(causal),
                                          backend.resolve_interpret(interpret))
        return ref.ski_fused_tno_ref(x, a_dense, filt, idx_lo, w_lo, r,
                                     causal)


def ski_fused_tno_coef(x, a_coef, filt, idx_lo, w_lo, r: int, causal: bool,
                       variant: str = "windowed", *, use_pallas=None,
                       interpret=None):
    """Large-rank differentiable fused SKI-TNO, coefficient-form Gram.

    x (b,n,d); a_coef (d,2r-1) Toeplitz lags of the inducing Gram (the
    dense (d,r,r) form is never materialised — 16 GB at r=8192, d=64);
    filt (d,m); idx_lo/w_lo: inducing geometry (ref path only). ``variant``
    is "windowed" (banded-W kernel streaming (bw,bw) Gram band blocks) or
    "fft" (rfft/irfft circulant Gram between the passes) — pick via
    ``backend.ski_rank_variant``. Both execution strategies compute the
    same operator and share the oracle ref.ski_fused_tno_coef_ref; the
    Pallas path carries a custom VJP whose signal cotangent is the same
    windowed kernel with the band transposed (coefficients lag-flipped)
    and the conv offset mirrored (kernels/ski_vjp.py).
    """
    with obs_devstats.kernel_region(f"ski_{variant}"):
        if backend.resolve_use_pallas(use_pallas):
            from repro.kernels import ski_vjp as k
            return k.ski_fused_tno_coef_pallas(
                x, a_coef, filt, int(r), bool(causal), str(variant),
                backend.resolve_interpret(interpret))
        return ref.ski_fused_tno_coef_ref(x, a_coef, filt, idx_lo, w_lo, r,
                                          causal)


def fd_tno(x, khat_real, *, use_pallas=None, interpret=None):
    """Differentiable causal FD-TNO (paper §3.3, Algorithm 2): one op for
    Hilbert-completed spectrum + per-channel spectral multiply + (i)rfft
    staging.

    x (b, n, d); khat_real (d, n+1) — the RPE's raw real frequency
    response on the rfft grid (no decay bias). On the Pallas path the lag
    window, the complex spectral multiply and the backward's khat
    reduction are blocked Pallas kernels fused around the XLA FFT stages
    (kernels/fd_fused.py), and the op carries a custom VJP whose signal
    cotangent reuses the forward multiply kernel with the spectrum
    conjugated (causal ⇄ anticausal) — so ``jax.grad`` of a causal FD
    block stays on the kernel path, same contract as :func:`ski_fused_tno`
    (counters in fd_fused assert no silent ref fallback). On the
    reference path plain autodiff through ref.fd_tno_ref applies.
    """
    with obs_devstats.kernel_region("fd_tno"):
        if backend.resolve_use_pallas(use_pallas):
            from repro.kernels import fd_fused as k
            return k.fd_tno_pallas(x, khat_real,
                                   backend.resolve_interpret(interpret))
        return ref.fd_tno_ref(x, khat_real)


def ssd_scan(x, dt, a, b, c, d_skip, *, chunk=64, use_pallas=None,
             interpret=None, hshard=None):
    """Mamba-2 SSD chunked scan (the model-zoo state-space mixer).

    x (bt, n, h, p) fp32/bf16 per-head inputs; dt (bt, n, h) positive
    step sizes; a (h,) negative decay rates; b/c (bt, n, g, s) in/out
    projections (g groups, s state dim); d_skip (h,) skip; returns
    (bt, n, h, p). Sequential-recurrence oracle: ref.ssd_scan_ref; the
    dispatched paths (Pallas kernel / ssd_chunked reference) both use
    the chunked intra/inter-state formulation with ``chunk``-length
    blocks. ``hshard`` re-asserts head-axis TP sharding on the
    chunk-state carry (reference path; see ssd_chunked docstring)."""
    with obs_devstats.kernel_region("ssd"):
        if backend.resolve_use_pallas(use_pallas):
            from repro.kernels import ssd_scan as k
            return k.ssd_scan_pallas(
                x, dt, a, b, c, d_skip, chunk=chunk,
                interpret=backend.resolve_interpret(interpret))
        from repro.kernels import ssd_chunked
        return ssd_chunked.ssd_scan_chunked(x, dt, a, b, c, d_skip,
                                            chunk=chunk, hshard=hshard)
