"""Sharded manifest checkpoints: atomic, async, mesh-agnostic.

Layout (one directory per step)::

    ckpt_dir/
      step_000123/
        manifest.json        # tree structure, shapes, dtypes, chunk map
        data/<leaf-id>.npy   # one file per pytree leaf (chunked if large)
      step_000123.COMMITTED  # atomic commit marker (written last)
      LATEST                 # text file: last committed step

Properties needed at 1000+-node scale (DESIGN §5):

* **Atomicity** — a crash mid-save never corrupts the latest checkpoint:
  the COMMITTED marker is renamed into place only after every leaf file
  is fsync'd; restore reads only committed steps.
* **Mesh-agnostic ("elastic")** — leaves are stored as *full logical
  arrays*; restore re-shards onto whatever mesh/sharding the new job
  passes in. A job can stop on (16,16) and resume on (8,8) — tested.
  (At real scale each host writes only the shards it owns and restore
  does a distributed gather; the manifest format already records
  per-chunk offsets to support that layout.)
* **Async** — ``save_async`` snapshots device arrays to host memory
  synchronously (cheap) and writes in a background thread so the train
  loop only blocks on the *previous* save (double-buffering).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_SENTINEL_NONE = "__none__"

_NP_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
              "int8", "uint64", "uint32", "uint16", "uint8", "bool",
              "complex64", "complex128"}


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _tree_structure_json(treedef) -> str:
    return str(treedef)


def _fsync_file(f):
    f.flush()
    os.fsync(f.fileno())


def _fsync_dir(path: str):
    """fsync a directory so renames/creates inside it are durable before
    the commit marker goes down (the atomicity claim above)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Optional[dict] = None):
    """Synchronous atomic save of a pytree of arrays."""
    leaves, treedef = _leaf_paths(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    if os.path.exists(tmp_dir):
        shutil.rmtree(tmp_dir)
    os.makedirs(os.path.join(tmp_dir, "data"), exist_ok=True)

    manifest = {
        "step": step,
        "treedef": _tree_structure_json(treedef),
        "n_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:06d}.npy"
        dtype_str = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype_str not in _NP_NATIVE:
            # ml_dtypes (bfloat16, fp8, ...) do not survive np.save —
            # store the raw bytes as uint8 and record the logical dtype.
            to_store, stored = arr.view(np.uint8), "raw_u8"
        else:
            to_store, stored = arr, dtype_str
        # every data file is fsync'd before the COMMITTED marker exists:
        # a crash between commit and a lazy page writeback must not leave
        # a committed-but-truncated leaf behind
        with open(os.path.join(tmp_dir, "data", fname), "wb") as f:
            np.save(f, to_store)
            _fsync_file(f)
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape), "dtype": dtype_str,
             "stored": stored})
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        _fsync_file(f)
    _fsync_dir(os.path.join(tmp_dir, "data"))   # dir entries durable too
    _fsync_dir(tmp_dir)

    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)                       # atomic on POSIX
    _fsync_dir(ckpt_dir)                               # rename durable
    marker = step_dir + ".COMMITTED"
    with open(marker, "w") as f:
        f.write(str(step))
        _fsync_file(f)
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
        _fsync_file(f)
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _fsync_dir(ckpt_dir)
    return step_dir


def latest_step(ckpt_dir: str) -> Optional[int]:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        step = int(f.read().strip())
    if os.path.exists(os.path.join(ckpt_dir, f"step_{step:09d}.COMMITTED")):
        return step
    # LATEST points at an uncommitted step (crash window): scan backwards.
    steps = sorted(
        int(p.split("_")[1].split(".")[0])
        for p in os.listdir(ckpt_dir) if p.endswith(".COMMITTED"))
    return steps[-1] if steps else None


def restore(ckpt_dir: str, tree_like: Any, *, step: Optional[int] = None,
            shardings: Any = None):
    """Restore into the structure of ``tree_like``. If ``shardings`` (a
    matching tree of NamedSharding) is given, each leaf is placed with
    that sharding — this is the elastic-restore path: the stored arrays
    are full logical values, so any mesh works."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _leaf_paths(tree_like)
    # real exceptions, not asserts: asserts vanish under `python -O`,
    # silently restoring a mismatched checkpoint into the wrong tree
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, "
            f"model expects {len(leaves_like)}")
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    out = []
    for meta, like, shd in zip(manifest["leaves"], leaves_like, shard_leaves):
        arr = np.load(os.path.join(step_dir, "data", meta["file"]))
        if meta.get("stored") == "raw_u8":
            import ml_dtypes  # noqa: F401 (registers bf16 with numpy)
            arr = arr.view(np.dtype(meta["dtype"])).reshape(meta["shape"])
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"leaf {meta['file']}: shape mismatch "
                f"{tuple(arr.shape)} vs {tuple(like.shape)}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.device_put(arr.astype(like.dtype)))
    return jax.tree.unflatten(treedef, out), manifest["extra"]


class AsyncCheckpointer:
    """Double-buffered async saver: snapshot now, write in background."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any, *, extra: Optional[dict] = None):
        self.wait()                                    # block on previous save
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _work():
            try:
                save(self.ckpt_dir, step, host_tree, extra=extra)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(p.split("_")[1].split(".")[0])
            for p in os.listdir(self.ckpt_dir) if p.endswith(".COMMITTED"))
        for s in steps[: -self.keep]:
            base = os.path.join(self.ckpt_dir, f"step_{s:09d}")
            shutil.rmtree(base, ignore_errors=True)
            for suffix in (".COMMITTED",):
                try:
                    os.remove(base + suffix)
                except FileNotFoundError:
                    pass
