from repro.checkpoint.manifest import (AsyncCheckpointer, latest_step,
                                       restore, save)
