"""AdamW with warmup-cosine schedule, global-norm clipping, optional bf16
moments (halves optimizer HBM) and optional int8 gradient compression with
error feedback (distributed-opt trick; off by default, validated in tests).

Pure-functional: ``init -> state``, ``step(state, grads, params) ->
(new_state, new_params)``. State is a pytree mirroring params, so the
checkpoint layer and the sharding layer treat it like a second param tree
(moments inherit each parameter's NamedSharding).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"   # "bfloat16" halves optimizer memory
    compress_grads: bool = False     # int8 + error feedback (DP traffic /4)


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    err: Any   # error-feedback residual (zeros-like unless compress_grads)


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(cfg: OptConfig, params) -> OptState:
    mdt = jnp.dtype(cfg.moments_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    mu = jax.tree.map(zeros, params)
    nu = jax.tree.map(zeros, params)
    err = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
           if cfg.compress_grads else jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params))
    return OptState(jnp.zeros((), jnp.int32), mu, nu, err)


# -------------------------------------------------- int8 compression (EF)
def _quantize_int8(g: jax.Array):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(g: jax.Array, err: jax.Array):
    """Error-feedback int8: quantize (g + carried residual), carry the
    quantization error to the next step. Unbiased over time; the DP
    all-reduce then moves int8 (4x less traffic)."""
    target = g.astype(jnp.float32) + err
    q, scale = _quantize_int8(target)
    deq = _dequantize(q, scale)
    new_err = target - deq
    return deq, new_err


# ---------------------------------------------------------------- update
def clip_by_global_norm(grads, max_norm: float):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def step(cfg: OptConfig, state: OptState, grads, params):
    """Returns (new_state, new_params, metrics)."""
    if cfg.compress_grads:
        pairs = jax.tree.map(compress_with_feedback, grads, state.err)
        grads = jax.tree.map(lambda pr: pr[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda pr: pr[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    else:
        new_err = state.err

    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.step + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    leaf3 = lambda x: isinstance(x, tuple) and len(x) == 3
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=leaf3)
    mu = jax.tree.map(lambda t: t[1], out, is_leaf=leaf3)
    nu = jax.tree.map(lambda t: t[2], out, is_leaf=leaf3)
    return OptState(count, mu, nu, new_err), newp, {"grad_norm": gnorm, "lr": lr}
