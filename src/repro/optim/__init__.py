from repro.optim.adamw import (OptConfig, OptState, clip_by_global_norm,
                               compress_with_feedback, init, schedule, step)
