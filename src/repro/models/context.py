"""Mesh/activation-sharding context threaded through model apply fns.

Models never import mesh axes directly: they request *logical* activation
shardings via ``shard(ctx, x, "batch", "seq", "embed")`` and the context
maps logical names to mesh axes (None mesh = no-op, used by CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Ctx:
    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    use_pallas: Optional[bool] = None
    decode: bool = False
    seq_shard_resid: bool = False   # sequence-parallel residual stream
    # KV-cache sequence sharding axes (flash decoding). For batch-1 long-
    # context decode the idle data axes fold in here, e.g. ("data","model")
    # = 256-way sequence sharding of a 512k cache (DESIGN §5).
    seq_kv_axes: Tuple[str, ...] = ("model",)

    def rules(self):
        m = (self.model_axis,)
        return {
            "batch": self.data_axes or None,
            "seq": m if self.seq_shard_resid else None,
            "seq_any": None,
            "seq_kv": self.seq_kv_axes,
            "embed": None,
            "heads": m,
            "kv_heads": None,
            "head_dim": None,
            "ffn": m,
            "vocab": m,
            "expert": None,
            "state": None,
            "tno_channel": m,
            None: None,
        }


def shard(ctx: Ctx, x: jax.Array, *axes):
    """Apply a logical activation sharding constraint (no-op without mesh)."""
    if ctx.mesh is None or ctx.mesh.empty:
        return x
    rules = ctx.rules()
    spec = []
    for a in axes:
        r = rules[a]
        spec.append(r if r is None else (r if isinstance(r, str) else tuple(r)))
    assert len(spec) == x.ndim, (axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))
