"""Generic model assembly: decoder LMs, enc-dec, prefix-VLM, hybrid/SSM —
all driven by ArchConfig.pattern, with layers scanned over pattern periods
(small HLO, fast compile, remat-friendly) and the paper's TNO variants
available as drop-in token mixers.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core.block import TNNBlockConfig, gtu_apply, gtu_init
from repro.core.tno import TNOConfig
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models.config import ArchConfig
from repro.models.context import Ctx, shard
from repro.nn.layers import ACTS, rmsnorm, rmsnorm_init
from repro.nn.params import KeyGen, boxed, rebox, unbox


# ------------------------------------------------------------------ pieces
def ffn_init(key, cfg: ArchConfig):
    kg = KeyGen(key)
    d, f = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "w_gate": boxed(kg(), (d, f), ("embed", "ffn"), "lecun", dt),
        "w_up": boxed(kg(), (d, f), ("embed", "ffn"), "lecun", dt),
        "w_down": boxed(kg(), (f, d), ("ffn", "embed"), "lecun", dt),
    }


def ffn_apply(params, cfg: ArchConfig, ctx: Ctx, x):
    act = ACTS[cfg.act]
    h = act(x @ params["w_gate"].astype(x.dtype)) * (x @ params["w_up"].astype(x.dtype))
    h = shard(ctx, h, "batch", "seq_any", "ffn")
    return h @ params["w_down"].astype(x.dtype)


def _tno_cfg(cfg: ArchConfig, variant: str, causal: bool) -> TNNBlockConfig:
    tno = TNOConfig(
        d=cfg.d_model, variant=variant, causal=causal, lam=cfg.tno_lam,
        rpe_hidden=cfg.tno_rpe_hidden, rpe_layers=cfg.tno_rpe_layers,
        rpe_act=cfg.tno_rpe_act, rank=cfg.tno_rank,
        filter_size=cfg.tno_filter)
    return TNNBlockConfig(cfg.d_model, tno=tno, act=cfg.act)


# ------------------------------------------------------------------ layers
def mixer_init(key, cfg: ArchConfig, mixer: str, *, causal=True):
    if mixer in ("attention", "local"):
        return attn_init_wrap(key, cfg)
    if mixer == "mamba":
        return mb.mamba_init(key, cfg)
    if mixer in ("tno", "ski", "fd"):
        return gtu_init(key, _tno_cfg(cfg, mixer, causal))
    raise ValueError(mixer)


def attn_init_wrap(key, cfg):
    return attn.attn_init(key, cfg)


def mixer_apply(params, cfg: ArchConfig, ctx: Ctx, mixer: str, x, *,
                mask_kind, prefix=0):
    if mixer in ("attention", "local"):
        mk = "local" if mixer == "local" else mask_kind
        return attn.attn_apply(params, cfg, ctx, x, mask_kind=mk, prefix=prefix)
    if mixer == "mamba":
        return mb.mamba_apply(params, cfg, ctx, x)
    if mixer in ("tno", "ski", "fd"):
        causal = mask_kind in ("causal", "local")
        # GTU internals run fp32 (FFTs); keep the residual dtype stable
        return gtu_apply(params, _tno_cfg(cfg, mixer, causal), x).astype(x.dtype)
    raise ValueError(mixer)


def layer_init(key, cfg: ArchConfig, mixer: str, ffn: str, *, cross=False,
               causal=True):
    kg = KeyGen(key)
    p = {
        "norm1": rmsnorm_init(kg(), cfg.d_model),
        "mixer": mixer_init(kg(), cfg, mixer, causal=causal),
    }
    if cross:
        p["norm_x"] = rmsnorm_init(kg(), cfg.d_model)
        p["cross"] = attn.attn_init(kg(), cfg, cross=True)
    if ffn == "dense":
        p["norm2"] = rmsnorm_init(kg(), cfg.d_model)
        p["ffn"] = ffn_init(kg(), cfg)
    elif ffn == "moe":
        p["norm2"] = rmsnorm_init(kg(), cfg.d_model)
        p["ffn"] = moe_mod.moe_init(kg(), cfg)
    return p


def _gathered_norm(params_norm, cfg, ctx, x):
    """SP gather + norm, ordered so the collective moves bf16.

    rmsnorm is per-position, so norm∘gather == gather∘norm; gathering the
    bf16 residual FIRST halves the all-gather bytes vs letting XLA hoist
    the gather inside the norm's fp32 region (§Perf iteration 1: 2×
    f32(b,s,d) gathers were 28% of qwen train_4k collective bytes)."""
    xg = shard(ctx, x, "batch", "seq_any", "embed")     # bf16 gather
    return rmsnorm(params_norm, xg, cfg.norm_eps)


def layer_apply(params, cfg: ArchConfig, ctx: Ctx, mixer: str, ffn: str, x,
                *, mask_kind, prefix=0, enc_out=None):
    x = shard(ctx, x, "batch", "seq", "embed")
    h = _gathered_norm(params["norm1"], cfg, ctx, x)
    y = mixer_apply(params["mixer"], cfg, ctx, mixer, h,
                    mask_kind=mask_kind, prefix=prefix)
    # constrain the mixer/FFN output back to the seq-sharded layout BEFORE
    # the residual add: the partitioner then emits reduce-scatter on the
    # TP partial sums instead of full all-reduce + later re-shard
    x = x + shard(ctx, y, "batch", "seq", "embed")
    aux = jnp.zeros((), jnp.float32)
    if "cross" in params:
        h = _gathered_norm(params["norm_x"], cfg, ctx, x)
        y = attn.attn_apply(params["cross"], cfg, ctx, h,
                            mask_kind="full", kv_src=enc_out)
        x = x + shard(ctx, y, "batch", "seq", "embed")
    if ffn == "dense":
        h = _gathered_norm(params["norm2"], cfg, ctx, x)
        x = x + shard(ctx, ffn_apply(params["ffn"], cfg, ctx, h),
                      "batch", "seq", "embed")
    elif ffn == "moe":
        if cfg.moe_impl == "ep":
            # EP consumes seq-sharded tokens directly: no gather at all
            # (rmsnorm is per-position, so it commutes with the sharding)
            h = rmsnorm(params["norm2"], x, cfg.norm_eps)
        else:
            h = _gathered_norm(params["norm2"], cfg, ctx, x)
        y, aux = moe_mod.moe_apply(params["ffn"], cfg, ctx, h)
        x = x + shard(ctx, y, "batch", "seq", "embed")
    x = shard(ctx, x, "batch", "seq", "embed")
    return x, aux


# -------------------------------------------------------------- model init
def init_model(key, cfg: ArchConfig):
    """Returns a Box tree (call unbox() for (params, logical axes))."""
    kg = KeyGen(key)
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    spec = cfg.layers_spec
    cross = cfg.kind == "encdec"
    causal = cfg.kind != "encoder"

    def init_block(k):
        kgb = KeyGen(k)
        return {f"sub{i}": layer_init(kgb(), cfg, m, f, cross=cross,
                                      causal=causal)
                for i, (m, f) in enumerate(spec[: cfg.period])}

    p: Dict[str, Any] = {}
    if cfg.vocab:
        p["embed"] = boxed(kg(), (cfg.vocab_padded, d), (None, "embed_tp"),
                           "embed", dt, scale=0.02)
        p["unembed"] = boxed(kg(), (d, cfg.vocab_padded), ("embed", "vocab"),
                             "lecun", dt)
    nb = cfg.n_scan_blocks
    if nb:
        _, axes = unbox(init_block(kg()))             # axes template
        keys = jax.random.split(kg(), nb)
        vals = jax.vmap(lambda k: unbox(init_block(k))[0])(keys)
        p["blocks"] = rebox(vals, axes, prepend=("layers",))
    for i in range(cfg.n_tail_layers):
        li = nb * cfg.period + i
        m, f = spec[li]
        p[f"tail{i}"] = layer_init(kg(), cfg, m, f, cross=cross, causal=causal)
    p["norm_f"] = rmsnorm_init(kg(), d)

    if cfg.kind == "encdec":
        def init_enc_layer(k):
            return layer_init(k, cfg, "attention", "dense", causal=False)
        keys = jax.random.split(kg(), cfg.enc_layers)
        _, eaxes = unbox(init_enc_layer(keys[0]))
        evals = jax.vmap(lambda k: unbox(init_enc_layer(k))[0])(keys)
        p["enc_blocks"] = rebox(evals, eaxes, prepend=("layers",))
        p["enc_norm_f"] = rmsnorm_init(kg(), d)
    return p


# ------------------------------------------------------------ forward pass
def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


def _run_blocks(params, cfg: ArchConfig, ctx: Ctx, x, *, mask_kind, prefix=0,
                enc_out=None):
    spec = cfg.layers_spec

    def block_fn(x, block_params):
        # remat at LAYER granularity: block-level checkpointing keeps the
        # whole period's cotangents + recompute buffers live at once
        # (141 GiB/device at jamba train_4k, 8-layer period); per-layer
        # remat bounds the backward working set to one sublayer.
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.period):
            m, f = spec[i]

            def layer_fn(x, p, m=m, f=f):
                return layer_apply(p, cfg, ctx, m, f, x,
                                   mask_kind=mask_kind, prefix=prefix,
                                   enc_out=enc_out)

            x, a = _maybe_remat(layer_fn, cfg)(x, block_params[f"sub{i}"])
            aux = aux + a
        return x, aux
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.n_scan_blocks:
        def scan_body(carry, bp):
            x, aux = carry
            x, a = block_fn(x, bp)
            return (x, aux + a), None
        (x, aux_total), _ = jax.lax.scan(scan_body, (x, aux_total),
                                         params["blocks"])
    for i in range(cfg.n_tail_layers):
        li = cfg.n_scan_blocks * cfg.period + i
        m, f = spec[li]

        def tail_fn(x, p, m=m, f=f):
            return layer_apply(p, cfg, ctx, m, f, x, mask_kind=mask_kind,
                               prefix=prefix, enc_out=enc_out)

        # remat unrolled layers too: keeps memory flat and makes the
        # unrolled cost probes (launch/dryrun) faithful to the scanned body
        x, a = _maybe_remat(tail_fn, cfg)(x, params[f"tail{i}"])
        aux_total = aux_total + a
    return x, aux_total


def _run_encoder(params, cfg: ArchConfig, ctx: Ctx, x):
    def body(x, bp):
        x, _ = layer_apply(bp, cfg, ctx, "attention", "dense", x,
                           mask_kind="full")
        return x, None
    body = _maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rmsnorm(params["enc_norm_f"], x, cfg.norm_eps)


def embed_tokens(params, cfg: ArchConfig, ctx: Ctx, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return shard(ctx, x.astype(jnp.dtype(cfg.dtype)), "batch", "seq", "embed")


def unembed(params, cfg: ArchConfig, ctx: Ctx, x):
    logits = x @ params["unembed"].astype(x.dtype)
    return shard(ctx, logits, "batch", "seq_any", "vocab")


def backbone(params, cfg: ArchConfig, ctx: Ctx, batch):
    """batch: dict -> (hidden (b, s, d) post-final-norm, aux). For
    prefix_vlm the prefix positions are already stripped."""
    mask_kind = "causal"
    prefix = 0
    enc_out = None
    if cfg.kind == "prefix_vlm":
        patches = batch["patches"].astype(jnp.dtype(cfg.dtype))
        tok_x = embed_tokens(params, cfg, ctx, batch["tokens"])
        x = jnp.concatenate([patches, tok_x], axis=1)
        mask_kind, prefix = "prefix", cfg.n_prefix
    elif cfg.kind == "encdec":
        enc_out = _run_encoder(params, cfg, ctx,
                               batch["enc_embed"].astype(jnp.dtype(cfg.dtype)))
        x = embed_tokens(params, cfg, ctx, batch["tokens"])
    else:
        x = embed_tokens(params, cfg, ctx, batch["tokens"])
    x, aux = _run_blocks(params, cfg, ctx, x, mask_kind=mask_kind,
                         prefix=prefix, enc_out=enc_out)
    x = rmsnorm(params["norm_f"], x, cfg.norm_eps)
    if cfg.kind == "prefix_vlm":
        x = x[:, cfg.n_prefix:]
    return x, aux


def forward(params, cfg: ArchConfig, ctx: Ctx, batch):
    """batch: dict -> (logits (b, s, V_pad), aux)."""
    x, aux = backbone(params, cfg, ctx, batch)
    return unembed(params, cfg, ctx, x), aux


def _ce_terms(cfg: ArchConfig, logits, labels):
    """Sum of per-token (lse - ll). logits fp32 (b, c, V_pad); labels
    (b, c). The label gather is a fused masked-reduce: never a one-hot
    matmul, and shard-friendly along a `model`-sharded vocab axis."""
    v = cfg.vocab_padded
    pad_mask = jnp.arange(v) < cfg.vocab
    logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    sel = jnp.arange(v)[None, None, :] == labels[..., None]
    ll = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
    return jnp.sum(lse - ll)


def loss_fn(params, cfg: ArchConfig, ctx: Ctx, batch, *, aux_weight=0.01):
    """Cross-entropy with sequence-chunked logits: the full (b, s, V)
    logits tensor is never materialised — each chunk's logits reduce to a
    scalar and are rematerialised in backward (jax.checkpoint), bounding
    CE memory to (b, loss_chunk, V). At vocab 262k × seq 4k this is the
    difference between fitting HBM and not."""
    x, aux = backbone(params, cfg, ctx, batch)
    labels = batch["labels"]
    b, s, d = x.shape

    def chunk_nll(xc, lc):
        logits = unembed(params, cfg, ctx, xc).astype(jnp.float32)
        return _ce_terms(cfg, logits, lc)

    c = cfg.loss_chunk
    if c and s > c and s % c == 0:
        nc = s // c
        xs = jnp.moveaxis(x.reshape(b, nc, c, d), 1, 0)        # (nc, b, c, d)
        ls = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
        chunk_fn = jax.checkpoint(chunk_nll)

        def body(acc, inp):
            xc, lc = inp
            return acc + chunk_fn(xc, lc), None

        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls),
                                unroll=nc if cfg.unroll_inner else 1)
    else:
        total = chunk_nll(x, labels)
    nll = total / (b * s)
    return nll + aux_weight * aux, {"nll": nll, "aux": aux}
