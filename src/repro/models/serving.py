"""Serving: prefill + single-token decode with per-layer caches.

Cache layout mirrors the scanned parameter blocks: a pytree stacked over
scan blocks, so the decode step is itself a ``lax.scan`` over layers with
the cache as per-step input/output. Attention caches are sequence-sharded
over `model` (flash-decoding, DESIGN §5); mamba caches are O(1).

TNO-mixer decode keeps the mixer-input history (the Toeplitz action needs
it: y_t = Σ_τ k[τ] u_{t-τ}) — same O(n·d) as a KV cache but without heads.
SKI decode is deliberately unsupported: the paper's Appendix B shows causal
masking negates SKI's benefit; causal serving uses FD/TNO kernels.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import fd as fd_mod
from repro.core import tno as tno_mod
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models.config import ArchConfig
from repro.models.context import Ctx, shard
from repro.models.transformer import (_run_encoder, _tno_cfg, embed_tokens,
                                      ffn_apply, unembed)
from repro.models import moe as moe_mod
from repro.nn.layers import ACTS, rmsnorm


# ------------------------------------------------------------- cache init
def _layer_cache(cfg: ArchConfig, mixer: str, batch: int, max_len: int, dtype):
    if mixer in ("attention", "local"):
        return attn.decode_cache_init(cfg, batch, max_len, dtype)
    if mixer == "mamba":
        return mb.mamba_cache_init(cfg, batch, dtype)
    if mixer in ("tno", "fd"):
        return {"hist": jnp.zeros((batch, max_len, cfg.d_model), dtype)}
    raise NotImplementedError(f"decode for mixer {mixer} (ski: Appendix B)")


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    spec = cfg.layers_spec

    def block_cache():
        return {f"sub{i}": _layer_cache(cfg, spec[i][0], batch, max_len, dtype)
                for i in range(cfg.period)}

    cache: Dict[str, Any] = {}
    if cfg.n_scan_blocks:
        one = block_cache()
        cache["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_scan_blocks,) + x.shape),
            one)
    for i in range(cfg.n_tail_layers):
        li = cfg.n_scan_blocks * cfg.period + i
        cache[f"tail{i}"] = _layer_cache(cfg, spec[li][0], batch, max_len, dtype)
    return cache


def shard_cache(cfg: ArchConfig, ctx: Ctx, cache):
    """Apply seq-sharded (flash-decoding) constraints to attention caches."""
    def f(path, x):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if names and names[-1] in ("k", "v"):
            lead = x.ndim - 4
            return shard(ctx, x, *([None] * lead), "batch", "seq_kv",
                         "kv_heads", "head_dim")
        if names and names[-1] == "hist":
            lead = x.ndim - 3
            return shard(ctx, x, *([None] * lead), "batch", "seq_kv", "embed")
        return x
    return jax.tree_util.tree_map_with_path(f, cache)


# ------------------------------------------------------- tno decode mixer
def _tno_decode(params, cfg: ArchConfig, ctx: Ctx, mixer: str, x, cache,
                cur_len):
    """GTU decode: cache the TNO input stream u; y_t = Σ k[τ] u_{t-τ}."""
    from repro.nn.layers import dense
    bcfg = _tno_cfg(cfg, mixer, causal=True)
    act = ACTS[bcfg.act]
    u = act(dense(params["wu"], x))                    # (b,1,d)
    v = act(dense(params["wv"], x))
    hist = jax.lax.dynamic_update_slice_in_dim(
        cache["hist"], u.astype(cache["hist"].dtype), cur_len, axis=1)
    s = hist.shape[1]
    if mixer == "fd":
        kt = fd_mod.fd_kernel_time(params["tno"], bcfg.tno.fd_cfg(), s)
        k_causal = kt[:, :s]                            # (d, s) lags 0..s-1
    else:
        k_causal = tno_mod.baseline_coeffs(params["tno"], bcfg.tno, s)[:, s - 1:]
    # y_t = Σ_{τ=0..cur_len} k[τ] u[t-τ]; history index j = cur_len - τ
    idx = jnp.arange(s)
    tau = cur_len - idx                                 # lag of each slot
    valid = tau >= 0
    kmat = jnp.where(valid[None, :], jnp.take(k_causal, jnp.clip(tau, 0, s - 1),
                                              axis=1), 0.0)  # (d, s)
    o = jnp.einsum("bsd,ds->bd", hist.astype(jnp.float32),
                   kmat.astype(jnp.float32))[:, None, :].astype(x.dtype)
    return dense(params["wo"], o * v), {"hist": hist}


# ------------------------------------------------------------- layer step
def _layer_decode(params, cfg: ArchConfig, ctx: Ctx, mixer: str, ffn: str,
                  x, cache, cur_len, enc_out=None):
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if mixer in ("attention", "local"):
        y, cache = attn.attn_decode(
            params["mixer"], cfg, ctx, h, cache, cur_len,
            mask_kind="local" if mixer == "local" else "causal",
            window=cfg.window)
    elif mixer == "mamba":
        y, cache = mb.mamba_decode(params["mixer"], cfg, ctx, h, cache)
    else:
        y, cache = _tno_decode(params["mixer"], cfg, ctx, mixer, h, cache,
                               cur_len)
    x = x + y
    if "cross" in params:
        h = rmsnorm(params["norm_x"], x, cfg.norm_eps)
        x = x + attn.attn_apply(params["cross"], cfg, ctx, h,
                                mask_kind="full", kv_src=enc_out)
    if ffn == "dense":
        x = x + ffn_apply(params["ffn"], cfg, ctx,
                          rmsnorm(params["norm2"], x, cfg.norm_eps))
    elif ffn == "moe":
        y, _ = moe_mod.moe_apply(params["ffn"], cfg, ctx,
                                 rmsnorm(params["norm2"], x, cfg.norm_eps))
        x = x + y
    return x, cache


def decode_step(params, cfg: ArchConfig, ctx: Ctx, batch, cache, cur_len):
    """One new token. batch: {"tokens": (b, 1)} (+ "enc_out" for encdec).

    Returns (logits (b, 1, V_pad), new_cache)."""
    spec = cfg.layers_spec
    enc_out = batch.get("enc_out")
    x = embed_tokens(params, cfg, ctx, batch["tokens"])
    cache = shard_cache(cfg, ctx, cache)

    new_cache: Dict[str, Any] = {}
    if cfg.n_scan_blocks:
        def body(x, inp):
            bp, bc = inp
            nc = {}
            for i in range(cfg.period):
                m, f = spec[i]
                x, nc[f"sub{i}"] = _layer_decode(
                    bp[f"sub{i}"], cfg, ctx, m, f, x, bc[f"sub{i}"],
                    cur_len, enc_out)
            return x, nc
        x, new_cache["blocks"] = jax.lax.scan(
            body, x, (params["blocks"], cache["blocks"]))
    for i in range(cfg.n_tail_layers):
        li = cfg.n_scan_blocks * cfg.period + i
        m, f = spec[li]
        x, new_cache[f"tail{i}"] = _layer_decode(
            params[f"tail{i}"], cfg, ctx, m, f, x, cache[f"tail{i}"],
            cur_len, enc_out)
    x = rmsnorm(params["norm_f"], x, cfg.norm_eps)
    new_cache = shard_cache(cfg, ctx, new_cache)
    return unembed(params, cfg, ctx, x), new_cache


def prefill(params, cfg: ArchConfig, ctx: Ctx, batch, max_len: int):
    """Run the prompt through the model, filling caches.

    Implemented as chunk-of-one-step scans would be O(n^2); instead we run
    the training-style forward for logits and fill attention caches from
    the projected K/V directly (mamba/tno caches are filled by a short
    replay of the final window/state — see _prefill_caches)."""
    from repro.models.transformer import forward
    logits, _ = forward(params, cfg, ctx, batch)
    return logits


def encode(params, cfg: ArchConfig, ctx: Ctx, enc_embed):
    return _run_encoder(params, cfg, ctx,
                        enc_embed.astype(jnp.dtype(cfg.dtype)))
