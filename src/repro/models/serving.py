"""Serving: prefill + single-token decode with per-layer caches.

Cache layout mirrors the scanned parameter blocks: a pytree stacked over
scan blocks, so the decode step is itself a ``lax.scan`` over layers with
the cache as per-step input/output. Attention caches are sequence-sharded
over `model` (flash-decoding, DESIGN §5); mamba caches are O(1).

TNO-mixer decode keeps the mixer-input history (the Toeplitz action needs
it: y_t = Σ_τ k[τ] u_{t-τ}) — same O(n·d) as a KV cache but without heads.
**FD mixers stream** (PR 4): when ``init_cache`` receives the params, the
hist-replay cache is replaced by the overlap-save block cache of
kernels/fd_stream.py — a ring of the last C tokens plus precomputed
kernel-tail contributions refreshed every C steps, O(d) per-token work
with O(d log C) amortised instead of O(n·d) replay. ``decode_chunk``
feeds C tokens at once through the same block machinery, which is what
chunked prefill is. ``REPRO_FD_STREAM=0`` pins the legacy hist cache.
SKI decode is deliberately unsupported: the paper's Appendix B shows causal
masking negates SKI's benefit; causal serving uses FD/TNO kernels.

**Ragged positions (PR 5):** ``decode_step`` takes ``cur_len`` either as
one traced scalar (every batch row at the same position — the classic
single-request loop) or as a ``(b,)`` vector of per-slot positions (the
continuous-batching engine, repro.serving_engine: each row is a slot
serving a different request at its own length). Every mixer's decode is
written so the scalar case is the vector case broadcast — lockstep and
ragged decode are bit-identical per row.

**Plan reuse (PR 5):** the hist-replay fallback used to re-realise the
per-layer kernel (the RPE spectrum / coefficient evaluation) on *every*
decode step. ``init_cache(params=...)`` now realises it once per layer
into the cache (``kcoef`` leaf, (d, max_len) causal taps — the length
bucket is the cache's max_len) and ``_tno_decode`` replays from it;
:data:`PLAN_EVALS` counts realisations so tests can pin "one evaluation
per (layer, length-bucket)".
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import fd as fd_mod
from repro.core import tno as tno_mod
from repro.kernels import backend, fd_stream
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models.config import ArchConfig
from repro.models.context import Ctx, shard
from repro.models.transformer import (_run_encoder, _tno_cfg, embed_tokens,
                                      ffn_apply, unembed)
from repro.models import moe as moe_mod
from repro.nn.layers import ACTS, rmsnorm


# ------------------------------------------------------------- cache init
#: realisation counter for the per-layer decode kernel (RPE spectrum /
#: coefficient evaluation), keyed by mixer. Bumped once per realisation
#: *trace* — with plan reuse that is once per (sub-layer, length-bucket)
#: at cache init (scan blocks share one vmapped trace), never per step.
PLAN_EVALS: Dict[str, int] = {"fd": 0, "tno": 0}


def _realise_kcoef(cfg: ArchConfig, mixer: str, layer_params,
                   max_len: int) -> jax.Array:
    """(d, max_len) causal kernel taps for a tno/fd layer — exactly what
    the per-step hist-replay evaluation produces for s = max_len."""
    PLAN_EVALS[mixer] = PLAN_EVALS.get(mixer, 0) + 1
    bcfg = _tno_cfg(cfg, mixer, causal=True)
    if mixer == "fd":
        kt = fd_mod.fd_kernel_time(layer_params["tno"], bcfg.tno.fd_cfg(),
                                   max_len)
        return kt[:, :max_len]                         # lags 0..max_len-1
    return tno_mod.baseline_coeffs(layer_params["tno"], bcfg.tno,
                                   max_len)[:, max_len - 1:]


def _layer_cache(cfg: ArchConfig, mixer: str, batch: int, max_len: int,
                 dtype, layer_params=None):
    if mixer in ("attention", "local"):
        return attn.decode_cache_init(cfg, batch, max_len, dtype)
    if mixer == "mamba":
        return mb.mamba_cache_init(cfg, batch, dtype)
    if mixer == "fd" and layer_params is not None \
            and backend.fd_stream_enabled():
        # overlap-save streaming cache: needs the layer's causal kernel,
        # hence the params (same kernel the hist path realises per step)
        kt = _realise_kcoef(cfg, mixer, layer_params["mixer"], max_len)
        return fd_stream.fd_stream_cache(kt, batch, max_len,
                                         backend.fd_stream_block())
    if mixer in ("tno", "fd"):
        hist = {"hist": jnp.zeros((batch, max_len, cfg.d_model), dtype)}
        if layer_params is not None:
            # plan reuse: realise the causal kernel ONCE per layer per
            # length bucket instead of re-evaluating the RPE every step
            hist["kcoef"] = _realise_kcoef(cfg, mixer,
                                           layer_params["mixer"], max_len)
        return hist
    raise NotImplementedError(f"decode for mixer {mixer} (ski: Appendix B)")


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None,
               params=None):
    """Per-layer decode caches. ``params`` (optional) enables the
    parameter-derived caches — the FD streaming cache and the memoised
    hist-fallback kernel (``kcoef``); without it (shape-only callers:
    dry-run input specs, eval_shape) every mixer gets its parameter-free
    layout (fd falls back to per-step hist-replay)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    spec = cfg.layers_spec

    def block_cache(block_params=None):
        return {f"sub{i}": _layer_cache(
                    cfg, spec[i][0], batch, max_len, dtype,
                    None if block_params is None
                    else block_params[f"sub{i}"])
                for i in range(cfg.period)}

    needs_params = (params is not None
                    and any(m in ("tno", "fd") for m, _ in spec))
    cache: Dict[str, Any] = {}
    if cfg.n_scan_blocks:
        if needs_params:
            # per-layer kernels differ across scan blocks: vmap the cache
            # builder over the stacked block params (parameter-free leaves
            # broadcast, matching the legacy layout)
            cache["blocks"] = jax.vmap(block_cache)(params["blocks"])
        else:
            one = block_cache()
            cache["blocks"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_scan_blocks,) + x.shape),
                one)
    for i in range(cfg.n_tail_layers):
        li = cfg.n_scan_blocks * cfg.period + i
        cache[f"tail{i}"] = _layer_cache(
            cfg, spec[li][0], batch, max_len, dtype,
            None if params is None else params.get(f"tail{i}"))
    return cache


def cache_capacity(cache) -> int | None:
    """Slot capacity (max positions a slot can hold) of a model cache
    tree, read from static leaf shapes: the min over attention KV /
    hist-replay sequence extents and streaming-cache ``cap`` markers.
    None when the cache has no length-bounded leaf (e.g. pure-mamba:
    O(1) state, unbounded). The serving engine gates admission on this —
    an over-capacity insert would silently clamp/corrupt the cache."""
    caps = []

    def f(path, x):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        leaf = names[-1] if names else ""
        if leaf in ("k", "v"):
            caps.append(int(x.shape[-3]))
        elif leaf == "hist":
            caps.append(int(x.shape[-2]))
        elif leaf == "cap":
            caps.append(int(x.shape[-2]))
        return x
    jax.tree_util.tree_map_with_path(f, cache)
    return min(caps) if caps else None


def shard_cache(cfg: ArchConfig, ctx: Ctx, cache):
    """Apply seq-sharded (flash-decoding) constraints to attention caches."""
    def f(path, x):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if names and names[-1] in ("k", "v"):
            lead = x.ndim - 4
            return shard(ctx, x, *([None] * lead), "batch", "seq_kv",
                         "kv_heads", "head_dim")
        if names and names[-1] == "hist":
            lead = x.ndim - 3
            return shard(ctx, x, *([None] * lead), "batch", "seq_kv", "embed")
        return x
    return jax.tree_util.tree_map_with_path(f, cache)


# ------------------------------------------------------- tno decode mixer
def _tno_decode(params, cfg: ArchConfig, ctx: Ctx, mixer: str, x, cache,
                cur_len):
    """GTU decode: cache the TNO input stream u; y_t = Σ k[τ] u_{t-τ}.

    ``cur_len`` — traced scalar or (b,) per-slot positions (ragged). FD
    mixers with a streaming cache take the O(d)-per-token overlap-save
    step (kernels/fd_stream.py) instead of replaying the history; the
    hist fallback replays against the memoised ``kcoef`` taps when the
    cache carries them (params-aware init), else re-realises per step
    (shape-only caches — counted in :data:`PLAN_EVALS`)."""
    from repro.nn.layers import dense
    bcfg = _tno_cfg(cfg, mixer, causal=True)
    act = ACTS[bcfg.act]
    u = act(dense(params["wu"], x))                    # (b,1,d)
    v = act(dense(params["wv"], x))
    if fd_stream.is_stream_cache(cache):
        y, cache = fd_stream.stream_step(cache, u[:, 0, :], cur_len)
        o = y[:, None, :].astype(x.dtype)
        # GTU internals may run fp32 (transformer.mixer_apply casts the
        # training path back too): keep the residual dtype stable
        return dense(params["wo"], o * v).astype(x.dtype), cache
    b = x.shape[0]
    s = cache["hist"].shape[1]
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    idx = jnp.arange(s)
    wsel = (idx[None, :] == cur[:, None])[..., None]    # (b, s, 1)
    hist = jnp.where(wsel, u.astype(cache["hist"].dtype), cache["hist"])
    if "kcoef" in cache:
        k_causal = cache["kcoef"]                       # memoised plan
    elif mixer == "fd":
        PLAN_EVALS[mixer] = PLAN_EVALS.get(mixer, 0) + 1
        kt = fd_mod.fd_kernel_time(params["tno"], bcfg.tno.fd_cfg(), s)
        k_causal = kt[:, :s]                            # (d, s) lags 0..s-1
    else:
        PLAN_EVALS[mixer] = PLAN_EVALS.get(mixer, 0) + 1
        k_causal = tno_mod.baseline_coeffs(params["tno"], bcfg.tno, s)[:, s - 1:]
    # y_t = Σ_{τ=0..cur_len} k[τ] u[t-τ]; history index j = cur_len - τ
    tau = cur[:, None] - idx[None, :]                   # (b, s) lag per slot
    kmat = jnp.where(tau[None] >= 0,
                     jnp.take(k_causal, jnp.clip(tau, 0, s - 1), axis=1),
                     0.0)                               # (d, b, s)
    o = jnp.einsum("bsd,dbs->bd", hist.astype(jnp.float32),
                   kmat.astype(jnp.float32))[:, None, :].astype(x.dtype)
    new = dict(cache, hist=hist)
    return dense(params["wo"], o * v).astype(x.dtype), new


# ------------------------------------------------------------- layer step
def _layer_decode(params, cfg: ArchConfig, ctx: Ctx, mixer: str, ffn: str,
                  x, cache, cur_len, enc_out=None):
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if mixer in ("attention", "local"):
        y, cache = attn.attn_decode(
            params["mixer"], cfg, ctx, h, cache, cur_len,
            mask_kind="local" if mixer == "local" else "causal",
            window=cfg.window)
    elif mixer == "mamba":
        y, cache = mb.mamba_decode(params["mixer"], cfg, ctx, h, cache)
    else:
        y, cache = _tno_decode(params["mixer"], cfg, ctx, mixer, h, cache,
                               cur_len)
    x = x + y
    if "cross" in params:
        h = rmsnorm(params["norm_x"], x, cfg.norm_eps)
        x = x + attn.attn_apply(params["cross"], cfg, ctx, h,
                                mask_kind="full", kv_src=enc_out)
    if ffn == "dense":
        x = x + ffn_apply(params["ffn"], cfg, ctx,
                          rmsnorm(params["norm2"], x, cfg.norm_eps))
    elif ffn == "moe":
        y, _ = moe_mod.moe_apply(params["ffn"], cfg, ctx,
                                 rmsnorm(params["norm2"], x, cfg.norm_eps))
        x = x + y
    return x, cache


def decode_step(params, cfg: ArchConfig, ctx: Ctx, batch, cache, cur_len):
    """One new token. batch: {"tokens": (b, 1)} (+ "enc_out" for encdec).
    ``cur_len``: traced int32 scalar (all rows at the same position) or a
    (b,) vector of per-slot positions (ragged continuous batching).

    Returns (logits (b, 1, V_pad), new_cache)."""
    spec = cfg.layers_spec
    enc_out = batch.get("enc_out")
    x = embed_tokens(params, cfg, ctx, batch["tokens"])
    cache = shard_cache(cfg, ctx, cache)

    new_cache: Dict[str, Any] = {}
    if cfg.n_scan_blocks:
        def body(x, inp):
            bp, bc = inp
            nc = {}
            for i in range(cfg.period):
                m, f = spec[i]
                x, nc[f"sub{i}"] = _layer_decode(
                    bp[f"sub{i}"], cfg, ctx, m, f, x, bc[f"sub{i}"],
                    cur_len, enc_out)
            return x, nc
        x, new_cache["blocks"] = jax.lax.scan(
            body, x, (params["blocks"], cache["blocks"]))
    for i in range(cfg.n_tail_layers):
        li = cfg.n_scan_blocks * cfg.period + i
        m, f = spec[li]
        x, new_cache[f"tail{i}"] = _layer_decode(
            params[f"tail{i}"], cfg, ctx, m, f, x, cache[f"tail{i}"],
            cur_len, enc_out)
    x = rmsnorm(params["norm_f"], x, cfg.norm_eps)
    new_cache = shard_cache(cfg, ctx, new_cache)
    return unembed(params, cfg, ctx, x), new_cache


# ------------------------------------------------------- chunked prefill
def supports_chunked_prefill(cfg: ArchConfig, cache) -> bool:
    """Chunked prefill rides the FD streaming block machinery: every
    mixer must be a streaming ``fd`` layer (dense FFN, decoder-only) and
    the cache must actually hold streaming leaves (REPRO_FD_STREAM=0 or a
    params-less init_cache fall back to token-by-token prefill)."""
    if cfg.kind != "decoder":
        return False
    if not all(m == "fd" and f == "dense" for m, f in cfg.layers_spec):
        return False
    return stream_block_of(cache) is not None


def stream_block_of(cache) -> int | None:
    """C of the streaming caches in a model cache tree (None if none).
    Scan-block leaves carry a leading layer axis; ring is (…, b, C, d)."""
    found = []

    def f(path, x):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if names and names[-1] == "ring":
            found.append(int(x.shape[-2]))
        return x
    jax.tree_util.tree_map_with_path(f, cache)
    return found[0] if found else None


def _layer_chunk(params, cfg: ArchConfig, ctx: Ctx, x, cache, cur_len):
    """One fd+dense layer over a full C-token chunk (positions
    [cur_len, cur_len+C), cur_len ≡ 0 mod C): the mixer goes through
    stream_push_block; norms/FFN are position-wise, so the training-style
    code applies unchanged."""
    from repro.nn.layers import dense
    bcfg = _tno_cfg(cfg, "fd", causal=True)
    act = ACTS[bcfg.act]
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    mp = params["mixer"]
    u = act(dense(mp["wu"], h))                        # (b, C, d)
    v = act(dense(mp["wv"], h))
    y, cache = fd_stream.stream_push_block(cache, u, cur_len)
    x = x + dense(mp["wo"], y.astype(x.dtype) * v).astype(x.dtype)
    x = x + ffn_apply(params["ffn"], cfg, ctx,
                      rmsnorm(params["norm2"], x, cfg.norm_eps))
    return x, cache


def decode_chunk(params, cfg: ArchConfig, ctx: Ctx, batch, cache, cur_len):
    """Chunked prefill step: C prompt tokens at once. batch:
    {"tokens": (b, C)} with C = the streaming block size and
    cur_len ≡ 0 (mod C). Returns (logits (b, C, V_pad), new_cache) —
    cache state afterwards is identical to C decode_step calls
    (gated by :func:`supports_chunked_prefill`)."""
    spec = cfg.layers_spec
    x = embed_tokens(params, cfg, ctx, batch["tokens"])
    new_cache: Dict[str, Any] = {}
    if cfg.n_scan_blocks:
        def body(x, inp):
            bp, bc = inp
            nc = {}
            for i in range(cfg.period):
                x, nc[f"sub{i}"] = _layer_chunk(bp[f"sub{i}"], cfg, ctx, x,
                                                bc[f"sub{i}"], cur_len)
            return x, nc
        x, new_cache["blocks"] = jax.lax.scan(
            body, x, (params["blocks"], cache["blocks"]))
    for i in range(cfg.n_tail_layers):
        x, new_cache[f"tail{i}"] = _layer_chunk(
            params[f"tail{i}"], cfg, ctx, x, cache[f"tail{i}"], cur_len)
    x = rmsnorm(params["norm_f"], x, cfg.norm_eps)
    return unembed(params, cfg, ctx, x), new_cache


def prefill(params, cfg: ArchConfig, ctx: Ctx, batch, max_len: int):
    """Run the prompt through the model, filling caches.

    Implemented as chunk-of-one-step scans would be O(n^2); instead we run
    the training-style forward for logits and fill attention caches from
    the projected K/V directly (mamba/tno caches are filled by a short
    replay of the final window/state — see _prefill_caches)."""
    from repro.models.transformer import forward
    logits, _ = forward(params, cfg, ctx, batch)
    return logits


def encode(params, cfg: ArchConfig, ctx: Ctx, enc_embed):
    return _run_encoder(params, cfg, ctx,
                        enc_embed.astype(jnp.dtype(cfg.dtype)))
