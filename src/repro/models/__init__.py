"""Model zoo: generic transformer assembly, mixer families (attention /
mamba / TNO variants), MoE, and the serving (prefill + decode) layer.
Real package (not a namespace dir) so coverage accounting and
``python -m`` imports resolve it like every sibling."""
