"""Mamba-2 (SSD, state-space duality) mixer.

in_proj -> [z | x | B | C | dt] ; short causal conv over (x,B,C) — reusing
the paper-motivated short_conv kernel — then the chunked SSD scan
(kernels/ssd_chunked XLA path, kernels/ssd_scan Pallas TPU path), gated
output projection. Decode keeps (conv window, SSD state) as the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ssd_chunked import ssd_decode_step
from repro.models.config import ArchConfig
from repro.models.context import Ctx, shard
from repro.nn.params import KeyGen, boxed


def _dims(cfg: ArchConfig):
    di = cfg.d_inner
    h = cfg.ssm_heads
    g, s = cfg.ssm_groups, cfg.ssm_state
    conv_dim = di + 2 * g * s
    return di, h, g, s, conv_dim


def mamba_init(key, cfg: ArchConfig):
    kg = KeyGen(key)
    d = cfg.d_model
    di, h, g, s, conv_dim = _dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    proj_out = 2 * di + 2 * g * s + h
    return {
        "in_proj": boxed(kg(), (d, proj_out), ("embed", "ssm_inner"), "lecun", dt),
        "conv_w": boxed(kg(), (conv_dim, cfg.conv_width), ("ssm_inner", None),
                        "normal", dt, scale=0.3),
        "a_log": boxed(kg(), (h,), ("ssm_heads",), "zeros", jnp.float32),
        "dt_bias": boxed(kg(), (h,), ("ssm_heads",), "zeros", jnp.float32),
        "d_skip": boxed(kg(), (h,), ("ssm_heads",), "ones", jnp.float32),
        "norm_scale": boxed(kg(), (di,), ("ssm_inner",), "ones", jnp.float32),
        "out_proj": boxed(kg(), (di, d), ("ssm_inner", "embed"), "lecun", dt),
    }


def _split_proj(cfg, proj):
    di, h, g, s, _ = _dims(cfg)
    z, xbc_dt = jnp.split(proj, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * g * s], axis=-1)
    return z, xbc, dt


def _gated_norm(scale, x, z, eps):
    dtp = x.dtype
    x = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(dtp)


def mamba_apply(params, cfg: ArchConfig, ctx: Ctx, x):
    """x: (b, n, d) -> (b, n, d)."""
    b, n, d = x.shape
    di, h, g, s, conv_dim = _dims(cfg)
    p = cfg.ssm_head_dim

    proj = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = shard(ctx, xbc, "batch", "seq_any", "ffn")
    xbc = ops.short_conv(xbc, params["conv_w"].astype(x.dtype), causal=True,
                         use_pallas=ctx.use_pallas)
    xbc = jax.nn.silu(xbc)
    xs, bc = jnp.split(xbc, [di], axis=-1)
    bmat, cmat = jnp.split(bc, [g * s], axis=-1)

    xs = xs.reshape(b, n, h, p)
    bmat = bmat.reshape(b, n, g, s)
    cmat = cmat.reshape(b, n, g, s)
    dt_full = jax.nn.softplus(dt.astype(jnp.float32) +
                              params["dt_bias"][None, None, :])
    a = -jnp.exp(params["a_log"])

    def hshard(arr, h_axis):
        if ctx.mesh is None or ctx.mesh.empty:
            return arr
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = [None] * arr.ndim
        if arr.shape[h_axis] % ctx.mesh.shape[ctx.model_axis] == 0:
            spec[h_axis] = ctx.model_axis
        dsz = 1
        for ax in ctx.data_axes:
            dsz *= ctx.mesh.shape[ax]
        if arr.shape[0] % max(dsz, 1) == 0 and ctx.data_axes:
            spec[0] = tuple(ctx.data_axes)
        return jax.lax.with_sharding_constraint(
            arr, NamedSharding(ctx.mesh, P(*spec)))

    y = ops.ssd_scan(xs, dt_full, a, bmat, cmat, params["d_skip"],
                     chunk=cfg.ssd_chunk, use_pallas=ctx.use_pallas,
                     hshard=hshard)
    y = y.reshape(b, n, di)
    y = _gated_norm(params["norm_scale"], y, z, cfg.norm_eps)
    return y @ params["out_proj"].astype(x.dtype)


# ---------------------------------------------------------------- decode
def mamba_cache_init(cfg: ArchConfig, batch: int, dtype):
    di, h, g, s, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, h, cfg.ssm_head_dim, s), jnp.float32),
    }


def mamba_decode(params, cfg: ArchConfig, ctx: Ctx, x, cache):
    """x: (b, 1, d). Recurrent single-token step; cache is O(1) in n."""
    b, _, d = x.shape
    di, h, g, s, conv_dim = _dims(cfg)
    p = cfg.ssm_head_dim

    proj = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, proj)          # (b,1,·)
    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (b,cw, conv_dim)
    w = params["conv_w"].astype(x.dtype)          # (conv_dim, cw); f[k]=lag k
    conv_out = jnp.einsum("bkc,ck->bc", window[:, ::-1], w)[:, None, :]
    xbc_t = jax.nn.silu(conv_out)
    xs, bc = jnp.split(xbc_t[:, 0], [di], axis=-1)
    bmat, cmat = jnp.split(bc, [g * s], axis=-1)
    dt_full = jax.nn.softplus(dt[:, 0].astype(jnp.float32) +
                              params["dt_bias"][None, :])
    a = -jnp.exp(params["a_log"])
    state, y = ssd_decode_step(cache["state"], xs.reshape(b, h, p), dt_full,
                               a, bmat.reshape(b, g, s), cmat.reshape(b, g, s),
                               params["d_skip"])
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = _gated_norm(params["norm_scale"], y, z, cfg.norm_eps)
    y = y @ params["out_proj"].astype(x.dtype)
    return y, {"conv": window[:, 1:], "state": state}
