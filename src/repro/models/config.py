"""Architecture configuration — one frozen dataclass drives every model in
the zoo (dense / MoE / hybrid / SSM / enc-dec / prefix-VLM / TNN).

``pattern`` is a tuple of (mixer, ffn) pairs tiled across layers; layers are
scanned over whole pattern periods (homogeneous pytrees) with any remainder
unrolled. ``mixer_override`` injects the paper's TNO variants as the token
mixer of *any* architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

MIXERS = ("attention", "local", "mamba", "tno", "ski", "fd")
FFNS = ("dense", "moe", "none")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab: int = 0
    # per-layer structure: tiled (mixer, ffn) pairs
    pattern: Tuple[Tuple[str, str], ...] = (("attention", "dense"),)
    kind: str = "decoder"           # decoder | encdec | prefix_vlm
    enc_layers: int = 0             # encdec only
    n_prefix: int = 0               # prefix_vlm stub patch count
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int = 0                 # sliding window for "local" mixer
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_impl: str = "capacity"      # capacity (GShard; backend-honest
                                    # memory) | ragged (dropless TPU path)
    moe_capacity_factor: float = 1.25
    # SSM (mamba2 / jamba)
    ssm_state: int = 0
    ssm_groups: int = 1
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssd_chunk: int = 128
    # paper technique injection
    mixer_override: str = ""        # "" | tno | ski | fd
    tno_rank: int = 64
    tno_filter: int = 32
    tno_lam: float = 0.99
    tno_rpe_hidden: int = 64
    tno_rpe_layers: int = 3
    tno_rpe_act: str = "relu"
    # numerics / structure
    act: str = "silu"
    norm_eps: float = 1e-6
    dtype: str = "float32"          # activation/compute dtype
    param_dtype: str = "float32"
    vocab_pad_multiple: int = 256
    scan_layers: bool = True
    remat: str = "none"             # none | full | dots
    attn_chunk: int = 1024          # flash q-chunk
    loss_chunk: int = 2048          # CE seq-chunking (0 = off): bounds the
                                    # logits working set to (b, chunk, V)
    unroll_inner: bool = False      # unroll inner chunk loops (attention
                                    # q-chunks / CE / MoE): FLOP-neutral;
                                    # used by the dry-run cost probes so
                                    # XLA cost_analysis (which counts each
                                    # while body ONCE) reports exact FLOPs
    notes: str = ""

    # ------------------------------------------------------------ derived
    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def layers_spec(self):
        """Per-layer (mixer, ffn), honoring mixer_override for seq mixers."""
        out = []
        for i in range(self.n_layers):
            mixer, ffn = self.pattern[i % len(self.pattern)]
            if self.mixer_override and mixer in ("attention", "local"):
                mixer = self.mixer_override
            out.append((mixer, ffn))
        return tuple(out)

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_scan_blocks(self) -> int:
        return self.n_layers // self.period if self.scan_layers else 0

    @property
    def n_tail_layers(self) -> int:
        return self.n_layers - self.n_scan_blocks * self.period

    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> dict:
        """Analytic parameter counts (used for 6ND roofline MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_padded
        per_layer_total = 0
        per_layer_active = 0
        for mixer, ffn in self.layers_spec:
            p = 0
            if mixer in ("attention", "local"):
                p += d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
                p += self.n_heads * self.head_dim * d
            elif mixer == "mamba":
                di, g, s = self.d_inner, self.ssm_groups, self.ssm_state
                h = self.ssm_heads
                p += d * (2 * di + 2 * g * s + h)      # in_proj
                p += self.conv_width * (di + 2 * g * s)  # conv
                p += di * d                             # out_proj
            elif mixer in ("tno", "ski", "fd"):
                p += 3 * d * d                          # GTU u/v/o
            a = p
            if ffn == "dense":
                p += 3 * d * f
                a = p
            elif ffn == "moe":
                p += d * self.n_experts                 # router
                p += self.n_experts * 3 * d * f
                a += d * self.n_experts + self.top_k * 3 * d * f
            else:
                a = p
            per_layer_total += p
            per_layer_active += a
        emb = 2 * v * d
        return {
            "total": per_layer_total + emb,
            "active": per_layer_active + emb,
            "embedding": emb,
        }


def tile_pattern(*pairs, repeat=1):
    return tuple(pairs) * repeat
