"""GQA attention: RoPE, optional QKV bias, sliding-window & prefix-LM masks,
flash-style q-chunked softmax for training/prefill, and a seq-sharded
(flash-decoding) cache path for serving."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.context import Ctx, shard
from repro.nn.params import KeyGen, boxed


def attn_init(key, cfg: ArchConfig, *, cross: bool = False):
    kg = KeyGen(key)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": boxed(kg(), (d, h * hd), ("embed", "heads"), "lecun", dt),
        "wk": boxed(kg(), (d, kvh * hd), ("embed", "kv_proj"), "lecun", dt),
        "wv": boxed(kg(), (d, kvh * hd), ("embed", "kv_proj"), "lecun", dt),
        "wo": boxed(kg(), (h * hd, d), ("heads", "embed"), "lecun", dt),
    }
    if cfg.qkv_bias:
        p["bq"] = boxed(kg(), (h * hd,), ("heads",), "zeros", dt)
        p["bk"] = boxed(kg(), (kvh * hd,), ("kv_proj",), "zeros", dt)
        p["bv"] = boxed(kg(), (kvh * hd,), ("kv_proj",), "zeros", dt)
    return p


# ------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (b, s, h, hd); positions: (s,) or (b, s)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
        ang = ang[None, :, None, :]                      # (1, s, 1, half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- masking
def mask_for(kind: str, q_pos, k_pos, *, window: int = 0, prefix: int = 0):
    """Boolean (…, q, k) mask. kinds: causal | local | prefix | full."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if kind == "full":
        return jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    causal = kp <= qp
    if kind == "causal":
        return causal
    if kind == "local":
        return causal & (qp - kp < window)
    if kind == "prefix":
        return causal | (kp < prefix)
    raise ValueError(kind)


# --------------------------------------------------- core attention (train)
def _sdpa_chunk(q, k, v, mask, scale):
    """q (b,h,qc,hd), k/v (b,h,s,hd) full-head; mask (b,1,qc,s) or (qc,s).

    GQA k/v are repeated to full heads by the caller: identical FLOPs, and
    every tensor then carries the same `heads`-over-`model` sharding. (The
    grouped (kvh, g) einsum forces GSPMD into involuntary full resharding
    whenever kvh < the TP extent.)"""
    logits = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask.ndim == 2:
        mask = mask[None, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqs,bhsd->bhqd", probs, v.astype(jnp.float32))


def attention(q, k, v, *, mask_kind: str, window: int = 0, prefix: int = 0,
              q_offset: int = 0, chunk: int = 1024, ctx: Ctx = Ctx(),
              unroll: bool = False):
    """q: (b, sq, h, hd); k, v: (b, sk, kvh, hd). q-chunked flash-style.

    Memory per step is O(b·h·chunk·sk) instead of O(b·h·sq·sk)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    if kvh != h:                      # GQA: repeat kv to full heads
        k = jnp.repeat(k, h // kvh, axis=2)
        v = jnp.repeat(v, h // kvh, axis=2)
        k = shard(ctx, k, "batch", "seq_any", "heads", "head_dim")
        v = shard(ctx, v, "batch", "seq_any", "heads", "head_dim")
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qt = jnp.moveaxis(q, 2, 1)        # (b, h, sq, hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    k_pos = jnp.arange(sk)

    qc = min(chunk, sq)
    if sq % qc != 0:
        qc = sq                        # fallback: no chunking
    nq = sq // qc

    # flash-style backward: each q-chunk is checkpointed so its (qc, sk)
    # logits/probs are RECOMPUTED in the backward pass. Without this the
    # scan stacks per-chunk probs as residuals — (nq, b, h, qc, sk) =
    # the full O(n²) attention matrix, 67 × 4.3 GiB buffers at jamba
    # train_4k (found in the dry-run buffer dump; EXPERIMENTS §Perf).
    def chunk_compute(qi, i):
        q_pos = q_offset + i * qc + jnp.arange(qc)
        m = mask_for(mask_kind, q_pos, k_pos, window=window, prefix=prefix)
        return _sdpa_chunk(qi, kt, vt, m, scale)

    chunk_compute = jax.checkpoint(chunk_compute)

    def body(carry, i):
        qi = jax.lax.dynamic_slice_in_dim(qt, i * qc, qc, axis=2)
        return carry, chunk_compute(qi, i)

    if nq == 1:
        q_pos = q_offset + jnp.arange(sq)
        m = mask_for(mask_kind, q_pos, k_pos, window=window, prefix=prefix)
        out = _sdpa_chunk(qt, kt, vt, m, scale)
    else:
        _, chunks = jax.lax.scan(body, None, jnp.arange(nq),
                                 unroll=nq if unroll else 1)
        out = jnp.reshape(jnp.moveaxis(chunks, 0, 2), (b, h, sq, hd))
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (b, sq, h, hd)


def attn_apply(params, cfg: ArchConfig, ctx: Ctx, x, *, mask_kind="causal",
               prefix: int = 0, kv_src=None, positions=None):
    """Full attention sublayer on (b, s, d). kv_src: cross-attention source."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_src is None else kv_src
    q = x @ params["wq"].astype(x.dtype)
    k = src @ params["wk"].astype(x.dtype)
    v = src @ params["wv"].astype(x.dtype)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, src.shape[1], kvh, hd)
    v = v.reshape(b, src.shape[1], kvh, hd)
    q = shard(ctx, q, "batch", "seq_any", "heads", "head_dim")
    # explicit kv gather point: seq arrives `model`-sharded from the SP
    # residual stream; kv is small (kvh ≤ h) so we gather it here, before
    # the repeat, instead of letting GSPMD pick a transition inside SDPA.
    k = shard(ctx, k, "batch", "seq_any", "kv_heads", "head_dim")
    v = shard(ctx, v, "batch", "seq_any", "kv_heads", "head_dim")
    if positions is None:
        positions = jnp.arange(s)
    if kv_src is None:                      # self-attention: rotate both
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, jnp.arange(k.shape[1]), cfg.rope_theta)
    o = attention(q, k, v, mask_kind=mask_kind, window=cfg.window,
                  prefix=prefix, chunk=cfg.attn_chunk, ctx=ctx,
                  unroll=cfg.unroll_inner)
    o = shard(ctx, o, "batch", "seq_any", "heads", "head_dim")
    return o.reshape(b, s, h * hd) @ params["wo"].astype(x.dtype)


# -------------------------------------------------------------- decode path
def decode_cache_init(cfg: ArchConfig, batch: int, max_len: int, dtype):
    hd, kvh = cfg.head_dim, cfg.n_kv_heads
    return {
        "k": jnp.zeros((batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((batch, max_len, kvh, hd), dtype),
    }


def attn_decode(params, cfg: ArchConfig, ctx: Ctx, x, cache, cur_len,
                *, mask_kind="causal", window: int = 0):
    """One-token decode. x: (b, 1, d); cache k/v (b, S, kvh, hd) seq-sharded.

    ``cur_len`` — traced int32 scalar (lockstep decode) or (b,) per-slot
    positions (ragged continuous batching: per-row RoPE angle, per-row KV
    write position, per-row causal/local validity). The scalar case is the
    vector case broadcast, so lockstep and ragged are bit-identical per
    row. Returns (y (b,1,d), new_cache). Flash-decoding: the cache stays
    sharded over `model` on the sequence axis; the softmax reduction
    crosses shards (psum inserted by GSPMD)."""
    b, _, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, 1, h, hd)
    k_new = (x @ params["wk"].astype(x.dtype)).reshape(b, 1, kvh, hd)
    v_new = (x @ params["wv"].astype(x.dtype)).reshape(b, 1, kvh, hd)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype).reshape(1, 1, h, hd)
        k_new = k_new + params["bk"].astype(x.dtype).reshape(1, 1, kvh, hd)
        v_new = v_new + params["bv"].astype(x.dtype).reshape(1, 1, kvh, hd)
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (b,))
    pos = cur[:, None]                                     # (b, 1)
    q = rope(q, pos, cfg.rope_theta)
    k_new = rope(k_new, pos, cfg.rope_theta)
    sk = cache["k"].shape[1]
    k_pos = jnp.arange(sk)
    wsel = (k_pos[None, :] == cur[:, None])[..., None, None]   # (b, S, 1, 1)
    ck = jnp.where(wsel, k_new.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(wsel, v_new.astype(cache["v"].dtype), cache["v"])
    ck = shard(ctx, ck, "batch", "seq_kv", "kv_heads", "head_dim")
    cv = shard(ctx, cv, "batch", "seq_kv", "kv_heads", "head_dim")

    valid = k_pos[None, :] <= cur[:, None]                 # (b, S)
    if mask_kind == "local" and window:
        valid = valid & (cur[:, None] - k_pos[None, :] < window)
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        ck.astype(jnp.float32)) * scale
    logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", probs, cv.astype(jnp.float32))
    o = o.reshape(b, 1, h * hd).astype(x.dtype)
    y = o @ params["wo"].astype(x.dtype)
    return y, {"k": ck, "v": cv}
