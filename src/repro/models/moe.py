"""Mixture-of-Experts FFN: top-k routing, sort-based dispatch, grouped GEMM
via ``jax.lax.ragged_dot`` (no capacity dropping, no one-hot dispatch
matmul — HLO FLOPs stay ≈ active FLOPs, which the roofline §Roofline
MODEL_FLOPS/HLO ratio checks).

Distribution: tokens are DP-sharded and every expert's FFN is TP-sharded
over `model` (experts-as-TP; at 8-40 experts on a 16-wide axis this beats
all-to-all EP — analysis in EXPERIMENTS §Perf). The grouped GEMM runs
inside ``shard_map`` because GSPMD cannot infer shardings through
ragged_dot's group_sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.context import Ctx
from repro.nn.layers import ACTS
from repro.nn.params import KeyGen, boxed


def moe_init(key, cfg: ArchConfig):
    kg = KeyGen(key)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "router": boxed(kg(), (d, e), ("embed", "expert"), "lecun", jnp.float32),
        "w_gate": boxed(kg(), (e, d, f), ("expert", "embed", "ffn"), "lecun", dt),
        "w_up": boxed(kg(), (e, d, f), ("expert", "embed", "ffn"), "lecun", dt),
        "w_down": boxed(kg(), (e, f, d), ("expert", "ffn", "embed"), "lecun", dt),
    }


def _route(x2d, router, top_k):
    """x2d: (T, d) -> (weights (T,k), ids (T,k), aux_loss)."""
    # keep the matmul in activation dtype so dL/dx2d through the router
    # path stays bf16 (fp32 here doubles every live (T, d) cotangent);
    # the softmax still runs in fp32.
    logits = (x2d @ router.astype(x2d.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)  # renormalise
    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    e = router.shape[-1]
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(ce.sum(), 1.0)
    aux = e * jnp.sum(me * ce)
    return w, ids, aux


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def ragged_matmul(x, w, gs, dx_psum=(), dw_psum=()):
    """Grouped GEMM y[rows of group e] = x_e @ w[e] with a memory-sane
    backward. jax's built-in ragged_dot VJP densifies to an
    (E, tokens, d) tensor - 128 GiB/device at granite train_4k scale,
    found via the dry-run buffer dump (EXPERIMENTS par.Perf). Here both
    cotangents stay ragged:

        dx = ragged_dot(dy, w^T)                      (same primitive)
        dw = ragged_dot_general(x, dy)  with the ragged dim CONTRACTING
             -> grouped (E, d, f) output, no densification.
    """
    return jax.lax.ragged_dot(x, w, gs)


def _ragged_matmul_fwd(x, w, gs, dx_psum, dw_psum):
    return jax.lax.ragged_dot(x, w, gs), (x, w, gs)


def _ragged_matmul_bwd(dx_psum, dw_psum, res, dy):
    x, w, gs = res
    dx = jax.lax.ragged_dot(dy, jnp.swapaxes(w, 1, 2), gs)
    dn = jax.lax.RaggedDotDimensionNumbers(
        dot_dimension_numbers=(((0,), (0,)), ((), ())),
        lhs_ragged_dimensions=[0], rhs_group_dimensions=[])
    dw = jax.lax.ragged_dot_general(x, dy, gs, dn)
    # under shard_map each cotangent must carry the primal's varying set:
    # dx sums the per-TP-shard contributions (x was model-replicated);
    # dw sums over token shards (w was data-replicated).
    if dx_psum:
        dx = jax.lax.psum(dx, dx_psum)
    if dw_psum:
        dw = jax.lax.psum(dw, dw_psum)
    import numpy as _np
    dgs = _np.zeros(gs.shape, jax.dtypes.float0)
    return dx.astype(x.dtype), dw.astype(w.dtype), dgs


ragged_matmul.defvjp(_ragged_matmul_fwd, _ragged_matmul_bwd)


def _grouped_ffn(xs, w_gate, w_up, w_down, group_sizes, act,
                 data_axes=(), model_axes=()):
    h = ragged_matmul(xs, w_gate, group_sizes, model_axes, data_axes)
    u = ragged_matmul(xs, w_up, group_sizes, model_axes, data_axes)
    h = ACTS[act](h) * u
    # h already varies over model (ffn-sharded): dx needs no model psum
    return ragged_matmul(h, w_down, group_sizes, (), data_axes)


def _moe_local(x2d, router, w_gate, w_up, w_down, *, top_k, act,
               data_axes=(), model_axes=(), impl="capacity",
               capacity_factor=1.25, unroll=False):
    """Single-shard MoE on local tokens. x2d: (T, d) -> (T, d), aux.

    Two dispatch implementations:

    * ``ragged``   — sort + ragged_dot grouped GEMM: dropless, FLOP-exact
      (HLO FLOPs ≈ active FLOPs). The TPU production path. NOT used for
      the CPU dry-run: XLA:CPU lowers ragged_dot through a dense
      (E, tokens, d) mask — a 128 GiB/device artifact of the *host*
      backend, not the algorithm (EXPERIMENTS §Perf).
    * ``capacity`` — GShard-style fixed expert capacity C =
      ceil(T·k/E · cf): scatter to (E, C, d) slots, dense batched GEMMs,
      gather-combine. Standard ops only ⇒ honest memory on every backend;
      cf× FLOPs overhead and tokens beyond capacity are dropped.
    """
    t, d = x2d.shape
    e = router.shape[-1]
    w, ids, aux = _route(x2d, router, top_k)

    if impl == "ragged":
        flat_ids = ids.reshape(-1)                        # (T*k,)
        order = jnp.argsort(flat_ids)
        tok = order // top_k
        xs = x2d[tok]                                     # (T*k, d)
        gs = jnp.zeros((e,), jnp.int32).at[flat_ids].add(1)
        ys = _grouped_ffn(xs.astype(w_gate.dtype), w_gate, w_up, w_down, gs,
                          act, data_axes, model_axes)
        wflat = w.reshape(-1)[order].astype(ys.dtype)
        out = jnp.zeros((t, d), ys.dtype).at[tok].add(ys * wflat[:, None])
        return out, aux

    # ---- capacity dispatch, token-chunked
    # Chunking bounds the (E·C, d) dispatch buffers to one chunk's worth
    # (0.25 GiB vs 4 GiB/device at granite train_4k scale) and remat
    # frees them between chunks in backward. FLOPs are unchanged.
    chunk = 8192
    nck = t // chunk if (t % chunk == 0 and t > chunk) else 1
    ck = t // nck

    def chunk_moe(xc, wc, idc):
        tkc = ck * top_k
        cap = max(int(-(-tkc * capacity_factor // e)), 4)  # ceil, ≥4
        flat_ids = idc.reshape(-1)                         # (ck·k,)
        onehot = jax.nn.one_hot(flat_ids, e, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot          # slots before me
        pos = jnp.sum(pos * onehot, axis=1)
        keep = pos < cap
        slot = jnp.where(keep, flat_ids * cap + pos, e * cap)
        xe = jnp.zeros((e * cap + 1, d), w_gate.dtype)
        xe = xe.at[slot].add(
            jnp.repeat(xc, top_k, axis=0).astype(w_gate.dtype))
        xeg = xe[:-1].reshape(e, cap, d)
        h = jnp.einsum("ecd,edf->ecf", xeg, w_gate)
        u = jnp.einsum("ecd,edf->ecf", xeg, w_up)
        h = ACTS[act](h) * u
        ye = jnp.einsum("ecf,efd->ecd", h, w_down)
        ye = jnp.concatenate(
            [ye.reshape(e * cap, d), jnp.zeros((1, d), ye.dtype)], axis=0)
        gathered = ye[slot]                                # (ck·k, d)
        wflat = (wc.reshape(-1) * keep).astype(gathered.dtype)
        return jnp.sum((gathered * wflat[:, None]).reshape(ck, top_k, d),
                       axis=1)

    if nck == 1:
        return chunk_moe(x2d, w, ids), aux
    body = jax.checkpoint(chunk_moe)
    xs = x2d.reshape(nck, ck, d)
    ws = w.reshape(nck, ck, top_k)
    idss = ids.reshape(nck, ck, top_k)

    def scan_body(carry, args):
        return carry, body(*args)

    _, out = jax.lax.scan(scan_body, (), (xs, ws, idss),
                          unroll=nck if unroll else 1)
    return out.reshape(t, d), aux



# --------------------------------------------------- expert-parallel MoE
def _ep_moe(x2d, router, w_gate, w_up, w_down, *, top_k, act,
            capacity_factor, model_axis, data_axes, e_total):
    """Expert parallelism: each `model` shard owns E/TP full experts;
    tokens stay sharded over BOTH (data, model) — no sequence gather at
    all (the per-layer (T, d) gathered buffers this removes were the
    jamba train_4k memory driver, par. Perf) — and travel via two
    all-to-alls with per-destination capacity buffers (GShard)."""
    tl, d = x2d.shape                       # local tokens
    tp = jax.lax.axis_size(model_axis)
    e_loc = e_total // tp
    w, ids, aux = _route(x2d, router, top_k)
    flat_ids = ids.reshape(-1)              # (tl*k,) global expert ids
    cap = max(int(-(-tl * top_k * capacity_factor // e_total)), 4)
    onehot = jax.nn.one_hot(flat_ids, e_total, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=1)
    keep = pos < cap
    slot = jnp.where(keep, flat_ids * cap + pos, e_total * cap)
    send = jnp.zeros((e_total * cap + 1, d), w_gate.dtype)
    send = send.at[slot].add(
        jnp.repeat(x2d, top_k, axis=0).astype(w_gate.dtype))
    send = send[:-1].reshape(tp, e_loc * cap, d)
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0,
                              tiled=False)          # (tp, e_loc*cap, d)
    xe = jnp.moveaxis(recv.reshape(tp, e_loc, cap, d), 1, 0)
    xe = xe.reshape(e_loc, tp * cap, d)
    h = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xe, w_up)
    ye = jnp.einsum("ecf,efd->ecd", ACTS[act](h) * u, w_down)
    ye = jnp.moveaxis(ye.reshape(e_loc, tp, cap, d), 1, 0)
    back = jax.lax.all_to_all(ye.reshape(tp, e_loc * cap, d), model_axis,
                              split_axis=0, concat_axis=0, tiled=False)
    ye_flat = jnp.concatenate(
        [back.reshape(e_total * cap, d), jnp.zeros((1, d), back.dtype)], 0)
    gathered = ye_flat[slot]
    wflat = (w.reshape(-1) * keep).astype(gathered.dtype)
    out = jnp.sum((gathered * wflat[:, None]).reshape(tl, top_k, d), axis=1)
    if data_axes:
        aux = jax.lax.pmean(aux, data_axes)
    aux = jax.lax.pmean(aux, model_axis)
    return out, aux


def moe_apply(params, cfg: ArchConfig, ctx: Ctx, x):
    """x: (b, s, d) -> (b, s, d). Stores aux loss on ctx-free side channel
    (returned as second value)."""
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    kw = dict(top_k=cfg.top_k, act=cfg.act, impl=cfg.moe_impl,
              capacity_factor=cfg.moe_capacity_factor,
              unroll=cfg.unroll_inner)
    if ctx.mesh is None or ctx.mesh.empty:
        out, aux = _moe_local(x2d, params["router"], params["w_gate"],
                              params["w_up"], params["w_down"], **kw)
    elif (cfg.moe_impl == "ep"
          and cfg.n_experts % ctx.mesh.shape[ctx.model_axis] == 0
          and s % ctx.mesh.shape[ctx.model_axis] == 0):
        dp = tuple(ctx.data_axes)
        mp = ctx.model_axis
        fn = functools.partial(
            _ep_moe, top_k=cfg.top_k, act=cfg.act,
            capacity_factor=cfg.moe_capacity_factor, model_axis=mp,
            data_axes=dp, e_total=cfg.n_experts)
        def shard_fn(x3, r, wg, wu, wd):
            o, a = fn(x3.reshape(-1, d), r, wg, wu, wd)
            return o.reshape(x3.shape), a    # keep (b, s, d) shard layout

        out, aux = jax.shard_map(
            shard_fn, mesh=ctx.mesh,
            in_specs=(P(dp, mp, None), P(None, None), P(mp, None, None),
                      P(mp, None, None), P(mp, None, None)),
            out_specs=(P(dp, mp, None), P()),
        )(x, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])
        return out.astype(x.dtype), aux
    else:
        dp = tuple(ctx.data_axes)
        mp = ctx.model_axis
        import numpy as _np
        dp_size = int(_np.prod([ctx.mesh.shape[a] for a in dp])) if dp else 1
        if (b * s) % max(dp_size, 1) != 0 or dp_size <= 1:
            dp = ()          # tiny decode batches: replicate tokens, TP only
        tok_spec = P(dp, None) if dp else P(None, None)
        fn = functools.partial(_shard_moe, model_axis=mp, data_axes=dp, **kw)
        out, aux = jax.shard_map(
            fn, mesh=ctx.mesh,
            in_specs=(tok_spec, P(None, None), P(None, None, mp),
                      P(None, None, mp), P(None, mp, None)),
            out_specs=(tok_spec, P()),
        )(x2d, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])
    return out.reshape(b, s, d).astype(x.dtype), aux


def _shard_moe(x2d, router, w_gate, w_up, w_down, *, top_k, act,
               model_axis, data_axes, impl, capacity_factor, unroll):
    out, aux = _moe_local(x2d, router, w_gate, w_up, w_down,
                          top_k=top_k, act=act,
                          data_axes=tuple(data_axes),
                          model_axes=(model_axis,),
                          impl=impl, capacity_factor=capacity_factor,
                          unroll=unroll)
    out = jax.lax.psum(out, model_axis)
    # aux varies only over the data axes (router weights are replicated
    # over `model`); averaging over `model` would psum an invariant value,
    # which the shard_map varying-axes checker rejects.
    if data_axes:
        aux = jax.lax.pmean(aux, data_axes)
    return out, aux
