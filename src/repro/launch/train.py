"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires StepBuilder (jit'd train_step with NamedShardings) + data pipeline +
fault-tolerant Trainer runtime. On this CPU container it runs real training
at smoke scale (--smoke); on a TPU fleet the same file is the per-host
entrypoint (jax.distributed.initialize is called when JAX_COORDINATOR is
set).

XLA flags recorded here for the TPU target (collective/compute overlap is
XLA's latency-hiding scheduler; we enable aggressive async collectives):

    --xla_tpu_enable_async_collective_fusion=true
    --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
    --xla_tpu_overlap_compute_collective_tc=true
    --xla_enable_async_all_gather=true
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import StepBuilder
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig

TPU_XLA_FLAGS = (
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
    "--xla_enable_async_all_gather=true")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="reduce the config to CPU scale")
    ap.add_argument("--mixer", default="",
                    choices=["", "tno", "ski", "fd"],
                    help="override the token mixer with a paper variant")
    ap.add_argument("--data", default="synthetic",
                    choices=["synthetic", "bytes", "lra_match"])
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--metrics-file", default=None, metavar="PATH",
                    help="dump the obs metrics registry on exit "
                         "(.json = JSON dump, anything else = Prometheus "
                         "text exposition); also installs the registry as "
                         "the process default so kernel dispatch / compile "
                         "watchdog counters land in it (same contract as "
                         "launch/serve.py)")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="stream train_step span events to PATH as JSONL "
                         "and write a Chrome trace_event export "
                         "(PATH + '.chrome.json') on exit")
    args = ap.parse_args(argv)

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()           # multi-host fleet entry

    from repro.obs import metrics as obs_metrics
    from repro.obs import tracing as obs_tracing
    reg = None
    if args.metrics_file is not None:
        reg = obs_metrics.Registry()
        # process default too: backend dispatch counters, the StepBuilder
        # compile watchdog, and the Trainer's own counters all report
        # into the same dump (parity with launch/serve.py)
        obs_metrics.set_default_registry(reg)
    tracer = (obs_tracing.Tracer(args.trace_file)
              if args.trace_file is not None else None)
    if tracer is not None:
        obs_tracing.set_default_tracer(tracer)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if args.mixer:
        import dataclasses
        cfg = dataclasses.replace(cfg, mixer_override=args.mixer)

    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    opt_cfg = adamw.OptConfig(lr=args.lr, warmup_steps=args.warmup,
                              total_steps=args.steps)
    sb = StepBuilder(cfg, mesh, opt_cfg=opt_cfg)

    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed, kind=args.data,
        path=args.data_path,
        host_id=jax.process_index(), num_hosts=jax.process_count())

    state_sh = sb.state_shardings()
    # compile watchdog over the trainer's jit entry point: exactly one
    # trace is expected for the whole run (the batch/seq shapes are
    # fixed); a retrace mid-run means shape churn and shows up as
    # repro_compiles_total{fn="train.train_step"} > 1 plus a warning
    from repro.obs import compilewatch as obs_compile
    watch = obs_compile.CompileWatch(prefix="train.")
    watch.expect("train_step", 1)
    train_step = watch.wrap("train_step", sb.make_train_step(),
                            in_shardings=(state_sh, None),
                            out_shardings=(state_sh, None))
    if tracer is not None:
        import itertools
        inner_step, counter = train_step, itertools.count()

        def train_step(state, batch):
            i = next(counter)
            tracer.begin("train_step", step=i)
            out = inner_step(state, batch)
            # sync before ending the span so the duration is device time,
            # not dispatch time (the Trainer syncs on the loss right
            # after anyway — this costs nothing extra)
            jax.block_until_ready(out[1])
            tracer.end("train_step", step=i)
            return out

    from jax.sharding import NamedSharding, PartitionSpec as P

    def put_batch(host_batch):
        def put(v):
            v = np.asarray(v)
            sh = NamedSharding(
                mesh, P(sb.rules.data_axes, *([None] * (v.ndim - 1))))
            return jax.device_put(v, sh)
        return {k: put(v) for k, v in host_batch.items()}

    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every)
    trainer = Trainer(tcfg, train_step, data_cfg, put_batch=put_batch)

    with mesh:
        state = sb.init_state(jax.random.PRNGKey(args.seed))
        state = jax.device_put(state, state_sh)
        state, start = trainer.try_restore(state, shardings=state_sh)
        t0 = time.time()
        state, end = trainer.run(state, start)
        dt = time.time() - t0
    steps_done = max(end - start, 1)
    print(f"[train] {steps_done} steps in {dt:.1f}s "
          f"({steps_done / dt:.2f} it/s); final metrics: "
          f"{ {k: float(v) for k, v in trainer.metrics_history[-1].items()} }")
    if watch.count("train_step") > 1:
        print(f"[train] WARNING: train_step retraced "
              f"{watch.count('train_step')}x (expected 1 compile)")
    if tracer is not None:
        tracer.close()
        chrome = args.trace_file + ".chrome.json"
        obs_tracing.write_chrome(tracer.events, chrome)
        print(f"[train] trace: {args.trace_file} (JSONL), "
              f"{chrome} (Perfetto)")
    if reg is not None and args.metrics_file is not None:
        if args.metrics_file.endswith(".json"):
            reg.dump_json(args.metrics_file)
        else:
            reg.dump_prometheus(args.metrics_file)
        print(f"[train] metrics: {args.metrics_file}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
