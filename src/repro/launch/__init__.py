# launch: mesh construction, dry-run, train/serve entrypoints.
# NOTE: dryrun must be imported first in its own process (it sets XLA_FLAGS
# before jax initialises); never import repro.launch.dryrun from library code.
