"""Serving launcher: batched prefill + decode loop.

``python -m repro.launch.serve --arch <id> --smoke --prompt-len 32
--gen-len 32 --batch 4`` runs a real generate loop on CPU; on TPU the same
file serves with the production mesh (KV caches sequence-sharded over
`model`, batch over `data` — flash-decoding layout, DESIGN §5).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import StepBuilder
from repro.models import serving


def generate(sb: StepBuilder, params, prompt, gen_len: int, *,
             temperature: float = 0.0, seed: int = 0,
             chunked_prefill: bool | None = None,
             max_len: int | None = None):
    """prompt: (b, p) int32. Greedy (or sampled) decode of gen_len tokens.

    Prefill: FD-streaming archs consume the prompt in C-token blocks
    through the overlap-save machinery (serving.decode_chunk — one rfft
    per block instead of C sequential steps); any remainder, and every
    other mixer family, is teacher-forced token-by-token. ``None`` (the
    default) auto-detects; False forces token-by-token.

    ``max_len`` sizes the decode cache (default: exactly p + gen_len).
    The FD/TNO kernel realisation depends on the cache length (the RPE
    spectrum is evaluated on the rfft grid of that length), so comparing
    against the continuous-batching engine token-for-token requires the
    same length bucket — pass the engine's max_len here."""
    cfg = sb.cfg
    b, p = prompt.shape
    if max_len is None:
        max_len = p + gen_len
    elif max_len < p + gen_len:
        raise ValueError(f"max_len={max_len} < prompt {p} + gen {gen_len}")
    cache = serving.init_cache(cfg, b, max_len, params=params)
    step = sb.serve_step_jit()

    key = jax.random.PRNGKey(seed)
    out = [prompt]

    def pick(logits):
        nonlocal key
        if temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(
                sub, logits[:, -1] / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        return jnp.minimum(nxt, cfg.vocab - 1).astype(jnp.int32)[:, None]

    pos = 0
    logits = None
    supported = serving.supports_chunked_prefill(cfg, cache)
    if chunked_prefill and not supported:
        # an explicit True must not silently run the wrong machinery
        # (non-streaming cache, or non-fd layers decode_chunk can't serve)
        raise ValueError(
            "chunked_prefill=True but the arch/cache does not support it "
            f"(arch {cfg.name}: all mixers must be streaming fd layers)")
    if chunked_prefill is None:
        chunked_prefill = supported
    if chunked_prefill:
        c = serving.stream_block_of(cache)
        chunk_step = sb.chunk_step_jit()
        while pos + c <= p:                       # whole prompt blocks
            logits, cache = chunk_step(
                params, {"tokens": prompt[:, pos:pos + c]}, cache,
                jnp.int32(pos))
            pos += c
    end = p + gen_len
    while pos < end - 1:
        if pos < p:
            tok = prompt[:, pos:pos + 1]          # teacher-forced prefill
        else:
            tok = pick(logits)
            out.append(tok)
        logits, cache = step(params, {"tokens": tok}, cache, jnp.int32(pos))
        pos += 1
    if gen_len > 0:
        out.append(pick(logits))
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy decode (the default); > 0 samples — "
                         "both modes work solo and with --engine "
                         "(per-slot RNG lanes)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="engine mode: truncate sampling to the k most "
                         "likely tokens (0 = full distribution; requires "
                         "--temperature > 0)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine: --batch requests "
                         "through S decode slots (repro.serving_engine)")
    ap.add_argument("--slots", type=int, default=None,
                    help="engine decode slots (default REPRO_ENGINE_SLOTS)")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="engine mode: seeded FaultInjector chaos run "
                         "(deterministic prefill/decode/callback faults; "
                         "faulted requests end in explicit error outcomes, "
                         "the rest are unaffected)")
    ap.add_argument("--deadline", type=float, default=None, metavar="SEC",
                    help="engine mode: per-request TTL in seconds "
                         "(watchdog evicts expired slots)")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="engine mode: bounded request queue "
                         "(admission rejects with QueueFull when full)")
    ap.add_argument("--metrics-file", default=None, metavar="PATH",
                    help="dump the obs metrics registry on exit "
                         "(.json = JSON dump, anything else = Prometheus "
                         "text exposition); also installs the registry as "
                         "the process default so kernel dispatch counters "
                         "land in it")
    ap.add_argument("--trace-file", default=None, metavar="PATH",
                    help="engine mode: stream request span events to PATH "
                         "as JSONL and write a Chrome trace_event export "
                         "(PATH + '.chrome.json', Perfetto-loadable) on "
                         "exit")
    args = ap.parse_args(argv)
    if not args.engine and (args.chaos is not None
                            or args.deadline is not None
                            or args.queue_cap is not None):
        ap.error("--chaos/--deadline/--queue-cap require --engine "
                 "(the supervised scheduler owns those knobs)")
    if args.trace_file is not None and not args.engine:
        ap.error("--trace-file requires --engine (request spans are "
                 "emitted by the supervised scheduler)")
    if args.temperature < 0:
        ap.error(f"--temperature {args.temperature} must be >= 0")
    if args.top_k < 0:
        ap.error(f"--top-k {args.top_k} must be >= 0")
    if args.top_k > 0 and args.temperature <= 0:
        # greedy decode ignores top-k; a silently inert knob is worse
        # than a loud one
        ap.error("--top-k requires --temperature > 0 "
                 "(greedy decode never consults it)")
    if args.top_k > 0 and not args.engine:
        ap.error("--top-k requires --engine (the solo path samples the "
                 "full distribution)")

    from repro.obs import metrics as obs_metrics
    from repro.obs import tracing as obs_tracing
    reg = None
    if args.metrics_file is not None:
        reg = obs_metrics.Registry()
        # process default too: backend dispatch counters and any engine
        # built without an explicit registry report into the same dump
        obs_metrics.set_default_registry(reg)
    tracer = (obs_tracing.Tracer(args.trace_file)
              if args.trace_file is not None else None)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    sb = StepBuilder(cfg, mesh)

    with mesh:
        from repro.nn.params import unbox
        from repro.models.transformer import init_model
        params, _ = unbox(init_model(jax.random.PRNGKey(args.seed), cfg))
        rng = np.random.default_rng(args.seed)
        prompt = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)
        if args.engine:
            from repro.serving_engine import (Engine, FaultInjector, Request,
                                              Scheduler)
            eng = Engine(cfg, params, slots=args.slots,
                         max_len=args.prompt_len + args.gen_len,
                         temperature=args.temperature, top_k=args.top_k)
            injector = None
            if args.chaos is not None:
                injector = FaultInjector(seed=args.chaos, rates={
                    "prefill": 0.15, "decode": 0.02, "callback": 0.1})
            sched = Scheduler(eng, injector=injector,
                              default_deadline=args.deadline,
                              queue_cap=args.queue_cap,
                              metrics=reg, tracer=tracer,
                              log=print if args.chaos is not None else None)
            for i in range(args.batch):
                sched.submit(Request(uid=f"req{i}",
                                     prompt=np.asarray(prompt[i]),
                                     max_new=args.gen_len,
                                     seed=args.seed + i))
            t0 = time.time()
            results, _ = sched.run()
            dt = time.time() - t0
            n_new = sum(len(v) for v in results.values())
            by_status = {}
            for out in sched.outcomes.values():
                by_status[out.status] = by_status.get(out.status, 0) + 1
            ok_uid = next((u for u, o in sched.outcomes.items()
                           if o.status == "ok"), None)
            mode = ("greedy" if args.temperature == 0 else
                    f"T={args.temperature}"
                    + (f"/top{args.top_k}" if args.top_k else ""))
            print(f"[serve] engine({eng.slots} slots, {mode}) generated "
                  f"{n_new} tokens in {dt:.2f}s ({n_new / dt:.1f} tok/s); "
                  f"steps={sched.steps} prefills={sched.prefills} "
                  f"(packed={sched.packed_prefills}) "
                  f"retries={sched.retries}; outcomes={by_status}; "
                  f"sample: "
                  f"{results[ok_uid][:16] if ok_uid else '(none ok)'}")
            if args.chaos is not None and injector is not None:
                print(f"[serve] chaos(seed={args.chaos}): "
                      f"{injector.fired} faults fired; log={injector.log}")
            if tracer is not None:
                tracer.close()
                chrome = args.trace_file + ".chrome.json"
                obs_tracing.write_chrome(tracer.events, chrome)
                print(f"[serve] trace: {args.trace_file} (JSONL), "
                      f"{chrome} (Perfetto)")
            _dump_metrics(reg, args.metrics_file)
            return 0
        t0 = time.time()
        toks = generate(sb, params, prompt, args.gen_len,
                        temperature=args.temperature, seed=args.seed)
        toks.block_until_ready()
        dt = time.time() - t0
    n_new = args.batch * args.gen_len
    print(f"[serve] generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s); sample row: {np.asarray(toks[0])[:16]}")
    _dump_metrics(reg, args.metrics_file)
    return 0


def _dump_metrics(reg, path):
    if reg is None or path is None:
        return
    if path.endswith(".json"):
        reg.dump_json(path)
    else:
        reg.dump_prometheus(path)
    print(f"[serve] metrics: {path}")


if __name__ == "__main__":
    raise SystemExit(main())
