"""Serving launcher: batched prefill + decode loop.

``python -m repro.launch.serve --arch <id> --smoke --prompt-len 32
--gen-len 32 --batch 4`` runs a real generate loop on CPU; on TPU the same
file serves with the production mesh (KV caches sequence-sharded over
`model`, batch over `data` — flash-decoding layout, DESIGN §5).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import StepBuilder
from repro.models import serving


def generate(sb: StepBuilder, params, prompt, gen_len: int, *,
             temperature: float = 0.0, seed: int = 0):
    """prompt: (b, p) int32. Greedy (or sampled) decode of gen_len tokens.

    Prefill fills the caches by running decode steps over the prompt
    (simple and correct for every mixer family; a chunked prefill path is
    the serving-optimizing extension documented in DESIGN)."""
    cfg = sb.cfg
    b, p = prompt.shape
    max_len = p + gen_len
    cache = serving.init_cache(cfg, b, max_len)
    step = jax.jit(sb.make_serve_step())

    key = jax.random.PRNGKey(seed)
    tok = prompt[:, :1]
    out = [prompt]
    logits = None
    for t in range(max_len - 1):
        logits, cache = step(params, {"tokens": tok}, cache, jnp.int32(t))
        if t + 1 < p:
            tok = prompt[:, t + 1:t + 2]          # teacher-forced prefill
        else:
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, -1] / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1)
            nxt = jnp.minimum(nxt, cfg.vocab - 1).astype(jnp.int32)
            tok = nxt[:, None]
            out.append(tok)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    sb = StepBuilder(cfg, mesh)

    with mesh:
        from repro.nn.params import unbox
        from repro.models.transformer import init_model
        params, _ = unbox(init_model(jax.random.PRNGKey(args.seed), cfg))
        rng = np.random.default_rng(args.seed)
        prompt = jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
            jnp.int32)
        t0 = time.time()
        toks = generate(sb, params, prompt, args.gen_len,
                        temperature=args.temperature, seed=args.seed)
        toks.block_until_ready()
        dt = time.time() - t0
    n_new = args.batch * args.gen_len
    print(f"[serve] generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s); sample row: {np.asarray(toks[0])[:16]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
