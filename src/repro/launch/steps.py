"""jit-able train_step / serve_step builders + ShapeDtypeStruct input specs.

This is the seam between the model zoo and the distribution layer: a
``StepBuilder`` binds (ArchConfig, mesh, sharding rules) and produces

* ``init_state()``       — params (+ optimizer) with NamedShardings
* ``train_step``         — loss/grad/optimizer update, jit-able
* ``serve_step``         — one-token decode against a KV/state cache
* ``input_specs(shape)`` — ShapeDtypeStructs for every model input of an
  assigned (arch × shape) cell: no allocation, weak-type-correct,
  shardable — exactly what ``jax.jit(...).lower()`` wants for the
  multi-pod dry-run.

Shape grammar (assignment): ``train_*`` lowers train_step on (tokens,
labels); ``prefill_*`` lowers the forward (logits only); ``decode_*`` /
``long_*`` lower serve_step with a KV cache of seq_len.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models import serving
from repro.models.config import ArchConfig
from repro.models.context import Ctx
from repro.models.transformer import forward, init_model, loss_fn
from repro.nn.params import unbox
from repro.optim import adamw
from repro.parallel.sharding import ShardingRules, rules_for_arch, spec_for


# --------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# archs whose every mixer is full attention — long_500k is skipped for them
FULL_ATTENTION_ONLY = {
    "grok-1-314b", "granite-moe-3b-a800m", "phi3-medium-14b", "qwen2-72b",
    "stablelm-3b", "paligemma-3b", "whisper-medium",
}


def cell_is_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch in FULL_ATTENTION_ONLY:
        return False
    return True


# ------------------------------------------------------------ StepBuilder
class StepBuilder:
    def __init__(self, cfg: ArchConfig, mesh: Optional[Mesh] = None, *,
                 rules: Optional[ShardingRules] = None,
                 opt_cfg: Optional[adamw.OptConfig] = None,
                 use_pallas: Optional[bool] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.rules = rules or (rules_for_arch(cfg, mesh) if mesh else None)
        self.opt_cfg = opt_cfg or adamw.OptConfig()
        data_axes = self.rules.data_axes if self.rules else ("data",)
        # Sequence-parallel residual stream for training/prefill (Megatron
        # SP): saved layer inputs are `model`-sharded on seq, which is what
        # lets 70B+ train_4k fit HBM under layer-scan remat (DESIGN §5).
        self.ctx = Ctx(mesh=mesh, data_axes=data_axes, use_pallas=use_pallas,
                       seq_shard_resid=mesh is not None)
        self._axes_tree = None
        self._jit_steps: Dict[Any, Any] = {}
        # compile watchdog over the memoised steps (ISSUE 10): one
        # executable per (kind, shape) key — a second trace of the same
        # key means the memoisation broke
        from repro.obs import compilewatch as obs_compile
        self.compile_watch = obs_compile.CompileWatch(prefix="steps.")

    # ------------------------------------------------------------ params
    def abstract_params(self):
        """(ShapeDtypeStruct tree, axes tree) via eval_shape — no
        allocation. The logical-axes tree is static metadata, captured
        through a side channel during the abstract trace."""
        store = {}

        def f(k):
            params, axes = unbox(init_model(k, self.cfg))
            store["axes"] = axes
            return params

        vals = jax.eval_shape(f, jax.random.PRNGKey(0))
        return vals, store["axes"]

    def param_shardings(self):
        vals, axes = self.abstract_params()
        mesh, rules = self.mesh, self.rules

        def f(a, v):
            return NamedSharding(mesh, spec_for(mesh, rules, a, v.shape))
        is_axes = lambda x: isinstance(x, tuple) and all(
            s is None or isinstance(s, str) for s in x)
        return jax.tree.map(f, axes, vals, is_leaf=is_axes)

    def state_shardings(self):
        """Shardings for (params, opt_state): moments mirror params."""
        ps = self.param_shardings()
        scalar = NamedSharding(self.mesh, P())
        err = (jax.tree.map(lambda s: s, ps) if self.opt_cfg.compress_grads
               else jax.tree.map(lambda _: scalar, ps))
        return {"params": ps,
                "opt": adamw.OptState(scalar, ps, ps, err)}

    def init_state(self, key):
        params, _ = unbox(init_model(key, self.cfg))
        opt = adamw.init(self.opt_cfg, params)
        return {"params": params, "opt": opt}

    # ------------------------------------------------------------- steps
    def make_train_step(self):
        cfg, ctx, ocfg = self.cfg, self.ctx, self.opt_cfg

        def train_step(state, batch):
            def lf(p):
                return loss_fn(p, cfg, ctx, batch)
            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
                state["params"])
            opt, params, opt_metrics = adamw.step(
                ocfg, state["opt"], grads, state["params"])
            metrics = dict(metrics, loss=loss, **opt_metrics)
            return {"params": params, "opt": opt}, metrics

        return train_step

    def make_forward(self):
        cfg, ctx = self.cfg, self.ctx

        def fwd(params, batch):
            logits, _ = forward(params, cfg, ctx, batch)
            return logits
        return fwd

    def _mesh_sizes(self):
        data = self.rules.data_axes
        dsz = int(np.prod([self.mesh.shape[a] for a in data])) if self.mesh else 1
        msz = self.mesh.shape.get(self.rules.model_axis, 1) if self.mesh else 1
        return data, dsz, msz

    def serve_ctx(self, shape: Optional[ShapeSpec] = None) -> Ctx:
        """Decode context; for batch-1 long-context cells the idle data
        axes fold into the KV-seq sharding (256-way over a 512k cache)."""
        ctx = dataclasses.replace(self.ctx, decode=True,
                                  seq_shard_resid=False)
        if shape is None or self.mesh is None:
            return ctx
        data, dsz, msz = self._mesh_sizes()
        if shape.global_batch % max(dsz, 1) != 0:
            seq_ax = (tuple(data) + (self.rules.model_axis,)
                      if shape.seq_len % (dsz * msz) == 0
                      else (self.rules.model_axis,))
            ctx = dataclasses.replace(ctx, data_axes=(), seq_kv_axes=seq_ax)
        return ctx

    def make_serve_step(self, shape: Optional[ShapeSpec] = None):
        cfg = self.cfg
        ctx = self.serve_ctx(shape)

        def serve_step(params, batch, cache, cur_len):
            return serving.decode_step(params, cfg, ctx, batch, cache, cur_len)
        return serve_step

    def make_chunk_step(self, shape: Optional[ShapeSpec] = None):
        """C-token chunked-prefill step (FD streaming archs — see
        serving.supports_chunked_prefill); same signature as serve_step
        with (b, C) tokens."""
        cfg = self.cfg
        ctx = self.serve_ctx(shape)

        def chunk_step(params, batch, cache, cur_len):
            return serving.decode_chunk(params, cfg, ctx, batch, cache,
                                        cur_len)
        return chunk_step

    def serve_step_jit(self, shape: Optional[ShapeSpec] = None):
        """Memoised ``jax.jit`` of :meth:`make_serve_step` — repeated
        ``generate`` calls on one StepBuilder reuse the compiled step
        instead of retracing per request (sequential serving used to pay
        a full trace+compile per generation)."""
        key = ("serve", shape.name if shape else None)
        if key not in self._jit_steps:
            name = f"serve:{shape.name if shape else 'default'}"
            self.compile_watch.expect(name, 1)
            self._jit_steps[key] = self.compile_watch.wrap(
                name, self.make_serve_step(shape))
        return self._jit_steps[key]

    def chunk_step_jit(self, shape: Optional[ShapeSpec] = None):
        """Memoised ``jax.jit`` of :meth:`make_chunk_step`."""
        key = ("chunk", shape.name if shape else None)
        if key not in self._jit_steps:
            name = f"chunk:{shape.name if shape else 'default'}"
            self.compile_watch.expect(name, 1)
            self._jit_steps[key] = self.compile_watch.wrap(
                name, self.make_chunk_step(shape))
        return self._jit_steps[key]

    # ------------------------------------------------------- input specs
    def batch_sharding(self):
        data = (self.rules.data_axes if self.rules else ("data",))
        return NamedSharding(self.mesh, P(data, None)) if self.mesh else None

    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        """ShapeDtypeStructs for the cell's inputs (+ cache for decode)."""
        cfg = self.cfg
        b, n = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        adt = jnp.dtype(cfg.dtype)
        if shape.kind in ("train", "prefill"):
            specs = {"tokens": jax.ShapeDtypeStruct((b, n), i32)}
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, n), i32)
            if cfg.kind == "prefix_vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (b, cfg.n_prefix, cfg.d_model), adt)
            if cfg.kind == "encdec":
                specs["enc_embed"] = jax.ShapeDtypeStruct(
                    (b, n, cfg.d_model), adt)
            return specs
        # decode: one new token against a seq_len cache
        batch = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.kind == "encdec":
            batch["enc_out"] = jax.ShapeDtypeStruct(
                (b, min(n, 4096), cfg.d_model), adt)
        cache = jax.eval_shape(
            lambda: serving.init_cache(cfg, b, n, jnp.dtype(cfg.dtype)))
        return {"batch": batch, "cache": cache}

    def input_shardings(self, shape: ShapeSpec, specs):
        """NamedShardings matching input_specs' structure. Every axis is
        divisibility-guarded: a dim that the mesh extent does not divide is
        replicated (the batch-1 long-context cells exercise this)."""
        mesh = self.mesh
        data, dsz, msz = self._mesh_sizes()
        model = self.rules.model_axis
        batch_ax = data if shape.global_batch % max(dsz, 1) == 0 else None
        sctx = self.serve_ctx(shape)
        kv_ax = sctx.seq_kv_axes            # ("model",) or data+model

        def guard(ax, dim):
            if ax is None:
                return None
            names = (ax,) if isinstance(ax, str) else tuple(ax)
            size = int(np.prod([mesh.shape[a] for a in names]))
            return ax if dim % size == 0 else None

        def tok_like(s):
            # batch over data; seq unsharded (FFT / full-seq mixers)
            spec = [guard(batch_ax, s.shape[0])] + [None] * (len(s.shape) - 1)
            return NamedSharding(mesh, P(*spec))

        if shape.kind in ("train", "prefill"):
            return jax.tree.map(tok_like, specs)

        def cache_shard(path, s):
            names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            leaf = names[-1] if names else ""
            nd = len(s.shape)
            if leaf in ("k", "v"):          # (…, b, S, kvh, hd): seq-shard
                spec = [None] * (nd - 4) + [
                    guard(batch_ax, s.shape[nd - 4]),
                    guard(kv_ax, s.shape[nd - 3]), None, None]
            elif leaf == "hist":            # (…, b, S, d): seq-shard
                spec = [None] * (nd - 3) + [
                    guard(batch_ax, s.shape[nd - 3]),
                    guard(kv_ax, s.shape[nd - 2]), None]
            elif leaf == "conv":            # (…, b, w, conv_dim)
                spec = [None] * (nd - 3) + [
                    guard(batch_ax, s.shape[nd - 3]), None,
                    guard(model, s.shape[nd - 1])]
            elif leaf == "state":           # (…, b, h, p, s)
                spec = [None] * (nd - 4) + [
                    guard(batch_ax, s.shape[nd - 4]),
                    guard(model, s.shape[nd - 3]), None, None]
            else:
                spec = [None] * nd
            return NamedSharding(mesh, P(*spec))

        batch_sh = jax.tree.map(tok_like, specs["batch"])
        cache_sh = jax.tree_util.tree_map_with_path(cache_shard, specs["cache"])
        return {"batch": batch_sh, "cache": cache_sh}
