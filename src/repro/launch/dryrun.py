import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware. For every (arch × shape × mesh) cell this lowers + compiles the
real train/serve step against ShapeDtypeStruct inputs on 512 placeholder
CPU devices, then records

* ``memory_analysis()``  — bytes/device (proves the cell fits HBM),
* ``cost_analysis()``    — HLO FLOPs & bytes (roofline compute/memory terms),
* collective bytes       — parsed from compiled HLO text per collective op
                           (roofline collective term).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--out dir/]
"""
import argparse
import dataclasses
import json
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import SHAPES, StepBuilder, cell_is_applicable
from repro.optim import adamw

ASSIGNED = [
    "jamba-1.5-large-398b", "grok-1-314b", "granite-moe-3b-a800m",
    "phi3-medium-14b", "qwen2-72b", "gemma3-4b", "stablelm-3b",
    "paligemma-3b", "whisper-medium", "mamba2-2.7b",
]

# ------------------------------------------------- collective-bytes parser
_COLL_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4, "s16": 2,
    "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def _bytes_of_shape_str(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        b = _DTYPE_BYTES.get(dt, 2 if dt.startswith("f8") else 4)
        total += n * b
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in compiled HLO,
    bucketed by op kind. (Output bytes ≈ operand bytes for AG/AR/RS at the
    full-tensor granularity we report; all-to-all moves its full shape.)"""
    out = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"^[%\w.-]+\s*=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter|"
            r"all-to-all|collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2).lower()
        nbytes = _bytes_of_shape_str(shape_str)
        out[kind] = out.get(kind, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


# ------------------------------------------------------------ cell runner
def _opt_cfg_for(arch: str) -> adamw.OptConfig:
    # bf16 moments for the ≥300B archs: the production choice that makes
    # optimizer state fit 16 GB/chip HBM (DESIGN §5).
    big = {"jamba-1.5-large-398b", "grok-1-314b"}
    return adamw.OptConfig(
        moments_dtype="bfloat16" if arch in big else "float32")


def _lower_cell(cfg, shape, mesh, opt_cfg):
    """Lower the cell's step for one concrete config. Shared by the full
    compile (coherence + memory proof) and the unrolled cost probes."""
    sb = StepBuilder(cfg, mesh, opt_cfg=opt_cfg)
    specs = sb.input_specs(shape)
    if shape.kind == "train":
        params, axes = sb.abstract_params()
        state_sh = sb.state_shardings()
        state_abs = jax.eval_shape(
            lambda: {"params": params,
                     "opt": adamw.init(sb.opt_cfg, params)})
        in_sh = sb.input_shardings(shape, specs)
        step = sb.make_train_step()
        lowered = jax.jit(
            step,
            in_shardings=(state_sh, in_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        ).lower(state_abs, specs)
    elif shape.kind == "prefill":
        params, _ = sb.abstract_params()
        in_sh = sb.input_shardings(shape, specs)
        fwd = sb.make_forward()
        lowered = jax.jit(
            fwd, in_shardings=(sb.param_shardings(), in_sh),
        ).lower(params, specs)
    else:  # decode
        params, _ = sb.abstract_params()
        in_sh = sb.input_shardings(shape, specs)
        serve = sb.make_serve_step(shape)
        lowered = jax.jit(
            serve,
            in_shardings=(sb.param_shardings(), in_sh["batch"],
                          in_sh["cache"], NamedSharding(mesh, P())),
            out_shardings=(None, in_sh["cache"]),
            donate_argnums=(2,),
        ).lower(params, specs["batch"], specs["cache"],
                jax.ShapeDtypeStruct((), jnp.int32))

    return lowered


def _measure(lowered):
    """compile + extract (per-device) costs. XLA cost_analysis reports
    PER-DEVICE numbers post-SPMD, and counts each while-loop (scan) body
    ONCE — both verified empirically; the probe extrapolation below
    corrects the loop undercount."""
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1)),
        "hlo_bytes": float(cost.get("bytes accessed", -1)),
        "collective_bytes": coll,
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
        },
    }


def _probe_cfg(cfg, mult: int):
    """Unrolled small-depth clone for exact cost accounting."""
    kw = dict(n_layers=cfg.period * mult, scan_layers=False,
              unroll_inner=True, name=f"{cfg.name}-probe{mult}")
    if cfg.kind == "encdec":
        kw["enc_layers"] = mult
    return dataclasses.replace(cfg, **kw)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, probes: bool = True) -> dict:
    """Full-config lower+compile (coherence + memory proof) plus, when
    ``probes``, two unrolled shallow compiles whose cost delta gives the
    exact per-layer-period FLOPs/bytes/collective bytes; the cell's
    roofline numbers are X1 + (L/period - 1) · (X2 - X1) — linear in depth
    because every period is an identical block."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    opt_cfg = _opt_cfg_for(arch)
    n_dev = mesh.size

    t0 = time.time()
    lowered = _lower_cell(cfg, shape, mesh, opt_cfg)
    t_lower = round(time.time() - t0, 1)
    full = _measure(lowered)

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev, "kind": shape.kind, "lower_s": t_lower, **full,
    }

    if probes:
        p1 = _measure(_lower_cell(_probe_cfg(cfg, 1), shape, mesh, opt_cfg))
        p2 = _measure(_lower_cell(_probe_cfg(cfg, 2), shape, mesh, opt_cfg))
        mult = cfg.n_layers / cfg.period - 1.0
        extr = {}
        for key in ("flops", "hlo_bytes"):
            extr[key] = p1[key] + mult * (p2[key] - p1[key])
        c1 = p1["collective_bytes"].get("total", 0)
        c2 = p2["collective_bytes"].get("total", 0)
        extr["collective_bytes_total"] = c1 + mult * (c2 - c1)
        extr["per_period"] = {
            "flops": p2["flops"] - p1["flops"],
            "hlo_bytes": p2["hlo_bytes"] - p1["hlo_bytes"],
            "collective_bytes": c2 - c1,
        }
        result["probe"] = {"p1": p1, "p2": p2, "extrapolated": extr}

    if verbose:
        gb = 1 << 30
        tmp = result["memory"]["temp_size"] or 0
        arg = result["memory"]["argument_size"] or 0
        ex = result.get("probe", {}).get("extrapolated", {})
        print(f"[dryrun] {arch} × {shape_name} × {result['mesh']}: "
              f"lower {t_lower}s compile {result['compile_s']}s | "
              f"per-dev FLOPs {ex.get('flops', result['flops']):.3e} "
              f"bytes {ex.get('hlo_bytes', result['hlo_bytes']):.3e} "
              f"coll {ex.get('collective_bytes_total', 0):.3e} | "
              f"args {arg / gb:.2f} GiB temp {tmp / gb:.2f} GiB")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every applicable (arch × shape) cell")
    ap.add_argument("--out", default=None, help="write JSON result(s)")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the unrolled cost probes (multi-pod pass: "
                    "the roofline table is single-pod only)")
    args = ap.parse_args(argv)
    probes = not (args.no_probes or args.multi_pod)

    def _flush(results):
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    results = []
    if args.all:
        for arch in ASSIGNED:
            for shape in SHAPES:
                if not cell_is_applicable(arch, shape):
                    print(f"[dryrun] SKIP {arch} × {shape} (inapplicable)")
                    continue
                try:
                    results.append(run_cell(arch, shape,
                                            multi_pod=args.multi_pod,
                                            probes=probes))
                except Exception as e:     # record + continue the queue
                    print(f"[dryrun] FAIL {arch} × {shape}: {e!r}")
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if args.multi_pod else "16x16",
                                    "error": repr(e)})
                _flush(results)
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        if not cell_is_applicable(args.arch, args.shape):
            print(f"[dryrun] SKIP {args.arch} × {args.shape} (inapplicable)")
            return 0
        results.append(run_cell(args.arch, args.shape,
                                multi_pod=args.multi_pod, probes=probes))

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
