"""Production meshes (DESIGN §5). Functions, not module constants, so
importing this module never touches jax device state."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis
    carries only the gradient all-reduce (lowest-frequency collective on
    the lowest-bandwidth links)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh for tests/elastic-restore experiments."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh() -> Mesh:
    """1-device mesh for CPU smoke runs (axes present, extent 1)."""
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def data_axes_of(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)
