"""Logical-axis sharding rules: map parameter logical axis names (attached
at init by ``nn.params.boxed``) to mesh axes, MaxText-style.

The default rule table realises FSDP×TP (DESIGN §5):

* FSDP ("zero-3") over the composed ``("pod", "data")`` axes on the
  d_model/"embed" dimension of every weight — parameters are *sharded at
  rest* across the data-parallel axes and all-gathered layer-by-layer by
  GSPMD on use (the all-gather is overlapped by the XLA latency-hiding
  scheduler on TPU).
* TP over ``model`` on heads/ffn/vocab/expert-ffn/tno-channel dims.

Rules are (logical name) -> mesh axis or None. Arch families override
individual entries via ``ShardingRules(overrides=...)`` — e.g. SSM inner
projections TP-shard on "ssm_inner"; whisper MHA keeps kv_proj unsharded.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

AxisVal = Union[str, Tuple[str, ...], None]

# Default logical-name -> mesh-axis rules. "fsdp" is substituted with the
# composed data axes of the active mesh (("data",) or ("pod", "data")).
DEFAULT_RULES: Mapping[str, AxisVal] = {
    # weight matrices
    "embed": "fsdp",          # d_model dim: FSDP-sharded at rest
    "embed_tp": "model",      # embedding table d_model dim: TP (gather by id)
    "heads": "model",         # q heads / fused h*hd projections
    "kv_proj": "model",       # k/v projections (kv=8 divides 16? no -> None set per-arch)
    "mlp": "model",
    "ffn": "model",
    "vocab": "model",
    "expert": None,           # router logits dim (tiny)
    "ssm_inner": "model",     # mamba inner projections
    "ssm_heads": None,
    "tno_channel": "model",   # per-channel Toeplitz mixers: TP across channels
    "rpe_hidden": None,       # RPE MLP hidden (tiny)
    "layers": None,           # scanned-layer leading dim
    None: None,
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Rule table bound to a mesh's axis names."""

    data_axes: Tuple[str, ...] = ("data",)   # FSDP axes (composed)
    model_axis: str = "model"
    overrides: Tuple[Tuple[str, AxisVal], ...] = ()

    def resolve(self, logical: Optional[str]) -> AxisVal:
        table = dict(DEFAULT_RULES)
        table.update(dict(self.overrides))
        v = table.get(logical, None)
        if v == "fsdp":
            return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        if v == "model":
            return self.model_axis
        return v


def _axes_divisible(mesh: Mesh, axis: AxisVal, dim: int) -> bool:
    if axis is None:
        return True
    names = (axis,) if isinstance(axis, str) else axis
    size = int(np.prod([mesh.shape[a] for a in names]))
    return dim % size == 0


def spec_for(mesh: Mesh, rules: ShardingRules, axes: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
    """PartitionSpec for one parameter; drops any axis whose mesh-extent
    does not divide the dim (falls back to replication on that dim)."""
    used = set()
    spec = []
    for name, dim in zip(axes, shape):
        ax = rules.resolve(name)
        if ax is not None:
            names = (ax,) if isinstance(ax, str) else tuple(ax)
            if any(a in used for a in names) or not _axes_divisible(mesh, ax, dim):
                ax = None
            else:
                used.update(names)
        spec.append(ax)
    return P(*spec)


def tree_shardings(mesh: Mesh, rules: ShardingRules, axes_tree, shape_tree):
    """Twin trees (axes, ShapeDtypeStruct or array) -> tree of NamedSharding."""
    def f(axes, arr):
        return NamedSharding(mesh, spec_for(mesh, rules, axes, arr.shape))
    return jax.tree.map(f, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            a is None or isinstance(a, str) for a in x))


def rules_for_arch(cfg, mesh: Mesh) -> ShardingRules:
    """Arch-family rule overrides (DESIGN §5 table)."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    ov = []
    model = mesh.shape.get("model", 1)
    # kv projections: TP only if kv_heads*head_dim divides cleanly AND
    # kv_heads >= model extent would keep head granularity; otherwise
    # replicate kv and shard only q (standard GQA practice at kv<TP).
    if cfg.n_kv_heads and cfg.n_kv_heads < model:
        ov.append(("kv_proj", None))
    return ShardingRules(data_axes=data_axes, overrides=tuple(ov))
