from repro.parallel.sharding import (DEFAULT_RULES, ShardingRules,
                                     rules_for_arch, spec_for, tree_shardings)
