from repro.data.pipeline import DataConfig, batch_at, iterate
