"""Deterministic, resumable, shardable data pipeline.

Design goals for 1000+-node training (DESIGN §5):

* **Stateless, per-row indexing** — row ``r`` of global batch ``i`` is a
  pure function of (seed, i, r). Restart-at-step-k needs no iterator state
  in the checkpoint, only ``k``; *re-sharding onto a different host count
  reproduces the identical global batch* (elastic restore invariant,
  tested). Every host materialises only its own rows.
* **Two sources**: a synthetic Zipf-Markov corpus (offline container —
  stands in for wikitext; local bigram structure + a long-range copy
  channel so models have both signals to learn) and a byte-level reader
  for real text files.
* **LRA-style long-range matching task** for the paper's bidirectional
  experiments (classification that requires cross-sequence interaction).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"       # synthetic | bytes | lra_match
    path: Optional[str] = None    # bytes kind
    host_id: int = 0
    num_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _rng(cfg: DataConfig, step: int, row: int, salt: int) -> np.random.Generator:
    return np.random.default_rng([cfg.seed, step, row, salt])


# --------------------------------------------------------------- synthetic
def _zipf_probs(v: int) -> np.ndarray:
    p = 1.0 / np.arange(1, v + 1)
    return p / p.sum()


def _zipf_markov_row(cfg: DataConfig, step: int, row: int) -> np.ndarray:
    """Zipf marginals + fixed successor map: next = succ(prev) w.p. 0.5,
    fresh Zipf draw otherwise; position t >= n/2 copies t - n/2 w.p. 0.1
    (a long-range signal the TNN's global mixing can exploit)."""
    rng = _rng(cfg, step, row, 0)
    n, v = cfg.seq_len + 1, cfg.vocab
    zipf = _zipf_probs(v)
    draws = rng.choice(v, size=n, p=zipf).astype(np.int32)
    mix = rng.random(n)
    succ = (np.arange(v) * 7919 + 13) % v
    toks = np.empty(n, np.int32)
    toks[0] = draws[0]
    half = cfg.seq_len // 2
    for t in range(1, n):
        toks[t] = succ[toks[t - 1]] if mix[t] < 0.5 else draws[t]
        if t >= half and mix[t] > 0.9:
            toks[t] = toks[t - half]
    return toks


# ------------------------------------------------------------------ bytes
class _ByteCorpus:
    _cache: dict = {}

    @classmethod
    def get(cls, path: str) -> np.ndarray:
        if path not in cls._cache:
            with open(path, "rb") as f:
                cls._cache[path] = np.frombuffer(f.read(), np.uint8)
        return cls._cache[path]


def _bytes_row(cfg: DataConfig, step: int, row: int) -> np.ndarray:
    data = _ByteCorpus.get(cfg.path)
    rng = _rng(cfg, step, row, 1)
    n = cfg.seq_len + 1
    s = int(rng.integers(0, max(len(data) - n, 1)))
    out = data[s:s + n].astype(np.int32)
    if len(out) < n:                       # tiny corpus: wrap
        out = np.resize(out, n)
    return out


# -------------------------------------------------------- LRA-style tasks
def _lra_match_row(cfg: DataConfig, step: int, row: int):
    """label = do the sentinels at positions 1 and n-2 match? Requires
    interaction across ~the whole sequence. Returns (tokens, label)."""
    rng = _rng(cfg, step, row, 2)
    n, v = cfg.seq_len, cfg.vocab
    toks = rng.integers(0, max(v - 2, 1), size=n, dtype=np.int32)
    half_v = max(v // 2, 2)
    sent = int(rng.integers(0, half_v))
    match = bool(rng.random() < 0.5)
    other = (sent + 1 + int(rng.integers(0, half_v - 1))) % half_v
    toks[1] = sent
    toks[n - 2] = sent if match else other
    return toks, int(match)


# ------------------------------------------------------------------ public
def batch_at(cfg: DataConfig, step: int) -> dict:
    """Host-local shard of global batch ``step`` — pure in (cfg, step)."""
    hb = cfg.host_batch
    rows = range(cfg.host_id * hb, (cfg.host_id + 1) * hb)
    if cfg.kind == "lra_match":
        pairs = [_lra_match_row(cfg, step, r) for r in rows]
        toks = np.stack([p[0] for p in pairs])
        lab = np.array([p[1] for p in pairs], np.int32)
        labels = np.broadcast_to(lab[:, None], toks.shape).copy()
        return {"tokens": toks, "labels": labels}
    gen = _zipf_markov_row if cfg.kind == "synthetic" else _bytes_row
    toks = np.stack([gen(cfg, step, r) for r in rows])
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
