"""Relative positional encoders (paper §3.1-3.3).

Three families:

* ``MLPRPE`` — the original TNN RPE: an MLP mapping a scalar relative
  position (or frequency, for FD-TNO) to d channel values. Activation is
  configurable because the paper's Theorems 2-4 tie the activation's
  smoothness to the implied time-domain decay class (GeLU > SiLU > ReLU).
* ``InterpRPE`` — the paper's SKI replacement: d learned piecewise-linear
  functions on [-1, 1] (Prop. 1 shows the ReLU MLP is exactly this class),
  pinned to 0 at x=0, evaluated through the inverse time warp
  ``x(t) = sign(t) * lambda^|t|`` so extrapolation in t becomes
  interpolation in x.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.nn.layers import mlp_apply, mlp_init
from repro.nn.params import KeyGen, boxed


# ----------------------------------------------------------------- MLP RPE
@dataclasses.dataclass(frozen=True)
class MLPRPEConfig:
    d_out: int              # channels (2*d for bidirectional FD-TNO)
    d_hidden: int = 64
    n_layers: int = 3
    act: str = "relu"
    use_layernorm: bool = True


def mlp_rpe_init(key, cfg: MLPRPEConfig):
    return mlp_init(key, 1, cfg.d_hidden, cfg.d_out, cfg.n_layers,
                    use_layernorm=cfg.use_layernorm)


def mlp_rpe_apply(params, cfg: MLPRPEConfig, pos: jax.Array) -> jax.Array:
    """pos: (m,) scalar positions -> (m, d_out)."""
    return mlp_apply(params, pos[:, None].astype(jnp.float32), act=cfg.act)


# ------------------------------------------------------------- interp RPE
@dataclasses.dataclass(frozen=True)
class InterpRPEConfig:
    d_out: int
    grid_size: int = 129     # odd => grid contains x = 0 exactly


def interp_rpe_init(key, cfg: InterpRPEConfig):
    kg = KeyGen(key)
    # values at uniform grid on [-1, 1]; pinning to 0 at x=0 is enforced in
    # apply by subtracting the interpolated value at 0.
    vals = boxed(kg(), (cfg.d_out, cfg.grid_size), ("tno_channel", None),
                 "normal", scale=0.02)
    return {"vals": vals}


def piecewise_linear_eval(vals: jax.Array, x: jax.Array) -> jax.Array:
    """vals: (d, g) node values on uniform grid over [-1,1]; x: (m,) query
    points in [-1, 1]. Returns (m, d). Clamps outside the grid."""
    g = vals.shape[-1]
    xf = (jnp.clip(x, -1.0, 1.0) + 1.0) * 0.5 * (g - 1)
    lo = jnp.clip(jnp.floor(xf).astype(jnp.int32), 0, g - 2)
    frac = (xf - lo.astype(xf.dtype))[:, None]
    vlo = vals[:, lo].T  # (m, d)
    vhi = vals[:, lo + 1].T
    return vlo * (1.0 - frac) + vhi * frac


def interp_rpe_apply(params, cfg: InterpRPEConfig, x: jax.Array) -> jax.Array:
    """x: (m,) warped positions in [-1,1] -> (m, d) with RPE(0) == 0."""
    vals = params["vals"].value if hasattr(params["vals"], "value") else params["vals"]
    v = piecewise_linear_eval(vals, x)
    v0 = piecewise_linear_eval(vals, jnp.zeros((1,), x.dtype))
    return v - v0


# --------------------------------------------------------- inverse time warp
def inverse_time_warp(t: jax.Array, lam: float) -> jax.Array:
    """x(t) = sign(t) * lambda^|t|, lambda in (0,1). Maps Z -> [-1, 1],
    x(0) = 0; far lags cluster near 0, near lags near +-1 (paper §3.2.2)."""
    t = t.astype(jnp.float32)
    return jnp.sign(t) * jnp.power(lam, jnp.abs(t))


def decay_bias(t: jax.Array, lam: float) -> jax.Array:
    """Original TNN decay bias lambda^|t| (eliminated by this paper's
    variants; kept for the faithful baseline)."""
    return jnp.power(lam, jnp.abs(t.astype(jnp.float32)))
