"""TNN sequence-modeling block (paper Fig. 3): GTU (token+channel mix via
TNO) followed by GLU (channel mix), pre-norm residual."""
from __future__ import annotations

import dataclasses

import jax

from repro.core.tno import TNOConfig, tno_apply, tno_init, tno_plan
from repro.nn.layers import dense, dense_init, rmsnorm, rmsnorm_init
from repro.nn.params import KeyGen


@dataclasses.dataclass(frozen=True)
class TNNBlockConfig:
    d_model: int
    tno: TNOConfig = None          # type: ignore[assignment]
    expand: int = 1                # GTU expansion
    glu_expand: int = 1            # GLU hidden expansion
    act: str = "silu"


def gtu_init(key, cfg: TNNBlockConfig):
    kg = KeyGen(key)
    de = cfg.d_model * cfg.expand
    return {
        "wu": dense_init(kg(), cfg.d_model, de, axes=("embed", "tno_channel")),
        "wv": dense_init(kg(), cfg.d_model, de, axes=("embed", "tno_channel")),
        "wo": dense_init(kg(), de, cfg.d_model, axes=("tno_channel", "embed")),
        "tno": tno_init(kg(), cfg.tno),
    }


def gtu_apply(params, cfg: TNNBlockConfig, x: jax.Array) -> jax.Array:
    from repro.nn.layers import ACTS
    act = ACTS[cfg.act]
    u = act(dense(params["wu"], x))
    v = act(dense(params["wv"], x))
    # gram coefficients / kernel spectrum once per forward, not per op
    plan = tno_plan(params["tno"], cfg.tno, x.shape[1])
    o = tno_apply(params["tno"], cfg.tno, u, plan=plan) * v
    return dense(params["wo"], o)


def glu_init(key, cfg: TNNBlockConfig):
    kg = KeyGen(key)
    dh = cfg.d_model * cfg.glu_expand
    return {
        "w1": dense_init(kg(), cfg.d_model, dh, axes=("embed", "mlp")),
        "w2": dense_init(kg(), cfg.d_model, dh, axes=("embed", "mlp")),
        "w3": dense_init(kg(), dh, cfg.d_model, axes=("mlp", "embed")),
    }


def glu_apply(params, cfg: TNNBlockConfig, x: jax.Array) -> jax.Array:
    from repro.nn.layers import ACTS
    act = ACTS[cfg.act]
    return dense(params["w3"], act(dense(params["w1"], x)) * dense(params["w2"], x))


def tnn_block_init(key, cfg: TNNBlockConfig):
    kg = KeyGen(key)
    return {
        "norm1": rmsnorm_init(kg(), cfg.d_model),
        "gtu": gtu_init(kg(), cfg),
        "norm2": rmsnorm_init(kg(), cfg.d_model),
        "glu": glu_init(kg(), cfg),
    }


def tnn_block_apply(params, cfg: TNNBlockConfig, x: jax.Array) -> jax.Array:
    x = x + gtu_apply(params["gtu"], cfg, rmsnorm(params["norm1"], x))
    x = x + glu_apply(params["glu"], cfg, rmsnorm(params["norm2"], x))
    return x
