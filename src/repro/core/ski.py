"""Sparse + low-rank TNO via asymmetric SKI (paper §3.2, Algorithm 1).

``T ≈ T_sparse + W A W^T`` where

* ``T_sparse`` (m non-zero diagonals) acts as a per-channel short 1-D conv;
* ``A`` is the r x r inducing-point Gram matrix of the warped-interp kernel
  ``k_l(t) = RPE_l(sign(t) λ^|t|)`` — itself Toeplitz because inducing
  points are uniform, so its action is an O(r log r) FFT matvec (we use a
  direct small matmul below r=512: MXU-friendlier, see DESIGN §3);
* ``W`` is the banded linear-interpolation matrix (≤2 non-zeros/row),
  applied in O(n) (Pallas kernel on TPU; scatter/gather oracle elsewhere).

Total: O(n + r log r) — the paper's mathematical complexity, which their
PyTorch implementation could not reach (sparse-tensor reshape overhead);
the TPU port does (DESIGN §3 item 1).

Execution pipeline (default, ``SKIConfig.fused=True``): the **two-pass
fused** form — pass 1 ``interp_reduce`` (z = Wᵀx), pass 2 one kernel
fusing the Gram contraction, the interp expansion and the short conv
with a single output write (kernels/ski_fused.py) — exposed as a single
differentiable op whose Pallas backward is itself kernel launches
(kernels/ski_vjp.py), so training takes the same path as inference.
How the Gram is applied is ``backend.ski_rank_variant``'s call (PR 3):

* ``dense``    (r ≤ 512, Gram under 64 MB) — ``ops.ski_fused_tno``, the
  whole (d, r, r) Gram VMEM-resident per d-tile;
* ``windowed`` (≤ 4096) — ``ops.ski_fused_tno_coef``, the O(n) banded-W
  kernel streaming (bw, bw) Toeplitz band blocks from the (d, 2r-1)
  coefficients (the dense Gram is never materialised);
* ``fft``      (beyond) — same op, Gram applied by a length-2r
  rfft/irfft circulant matvec between the two passes.

The 4-kernel unfused form remains as the ``fused=False`` benchmark
baseline; its Pallas ops are individually custom-VJP'd.

Forward-invariant pieces (inducing geometry, warped lag grid, Gram
coefficients / dense Gram) are grouped in a :func:`ski_plan`, built once
per layer per forward by core/block.py — not rebuilt per op — and the
param-independent grids are additionally memoised process-wide.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import toeplitz
from repro.core.rpe import (InterpRPEConfig, interp_rpe_apply,
                            interp_rpe_init)
from repro.kernels import backend, ops
from repro.nn.params import KeyGen, boxed


@dataclasses.dataclass(frozen=True)
class SKIConfig:
    d: int                    # channels
    rank: int = 64            # r inducing points
    filter_size: int = 32     # m sparse diagonals
    lam: float = 0.99         # inverse-time-warp decay
    grid_size: int = 129      # interp-RPE grid nodes on [-1,1]
    use_pallas: bool | None = None
    fused: bool = True        # two-pass fused pipeline (False: 4 kernels)


@functools.lru_cache(maxsize=128)
def _make_inducing_host(n: int, r: int):
    """Host-numpy body of :func:`make_inducing`.

    Cached as HOST numpy, not jax.Arrays: an lru_cache keyed only on
    (n, r) that holds device buffers pins them to whatever backend was
    active at first call — stale (or dead) buffers leak across
    backend/device switches (the PR 3 fix of core/fd._omega_grid, applied
    here too). Callers device_put via jnp.asarray, free under jit."""
    h = (n - 1) / (r - 1)
    f = np.arange(n, dtype=np.float32) / np.float32(h)
    lo = np.clip(np.floor(f).astype(np.int32), 0, r - 2)
    # clamp: fp32 rounding of the irrational spacing h can push the
    # boundary weight a few ulp outside [0, 1]
    w_lo = np.clip((1.0 - (f - lo.astype(np.float32))).astype(np.float32),
                   np.float32(0.0), np.float32(1.0))
    return lo, w_lo, h


def make_inducing(n: int, r: int):
    """Uniform inducing points on [0, n-1]; returns (idx_lo, w_lo, h).
    Memoised (host-side): the geometry depends only on (n, r), so all
    layers of a model (and every forward) share one copy instead of
    rebuilding it per block."""
    lo, w_lo, h = _make_inducing_host(int(n), int(r))
    return jnp.asarray(lo), jnp.asarray(w_lo), h


@functools.lru_cache(maxsize=128)
def _warped_lag_grid_host(r: int, h: float, lam: float) -> np.ndarray:
    """Host-numpy warped lags x(t) = sign(t) λ^|t| at lags -(r-1)h..(r-1)h
    — param-independent, shared across layers/forwards. Same host-cache
    policy as :func:`_make_inducing_host` (no pinned device buffers)."""
    lag = np.arange(-(r - 1), r, dtype=np.float32) * np.float32(h)
    return (np.sign(lag) *
            np.power(np.float32(lam), np.abs(lag))).astype(np.float32)


def _warped_lag_grid(r: int, h: float, lam: float) -> jax.Array:
    """Device view of the cached host grid (matches
    rpe.inverse_time_warp on the same lags)."""
    return jnp.asarray(_warped_lag_grid_host(int(r), float(h), float(lam)))


def ski_init(key, cfg: SKIConfig):
    kg = KeyGen(key)
    rpe = interp_rpe_init(kg(), InterpRPEConfig(cfg.d, cfg.grid_size))
    filt = boxed(kg(), (cfg.d, cfg.filter_size), ("tno_channel", None),
                 "normal", scale=0.02)
    return {"rpe": rpe, "filt": filt}


def inducing_gram_coeffs(params, cfg: SKIConfig, r: int, h: float):
    """(d, 2r-1) Toeplitz coefficients of A at warped inducing lags."""
    x = _warped_lag_grid(int(r), float(h), float(cfg.lam))
    vals = interp_rpe_apply(params["rpe"], InterpRPEConfig(cfg.d, cfg.grid_size), x)
    return vals.T  # (d, 2r-1)


def fused_eligible(cfg: SKIConfig, r: int) -> bool:
    """Dense-Gram eligibility (kept for back-compat; the full policy is
    backend.ski_rank_variant — with the large-rank variants every rank is
    fused-eligible, this only says whether the *dense* kernel serves it)."""
    return cfg.fused and backend.ski_rank_variant(r, cfg.d) == "dense"


def ski_plan(params, cfg: SKIConfig, n: int, causal: bool = False,
             variant: str | None = None) -> dict:
    """Precompute everything that is invariant across ops within a forward:
    inducing geometry, Gram coefficients, the Gram variant decision, and
    (dense variant only) the dense per-channel Gram. Built once per layer
    per forward (core/block.py); serving can additionally reuse it across
    decode steps of equal n.

    ``variant`` — optional override of ``backend.ski_rank_variant``
    ("dense" | "windowed" | "fft"); used by the variant-parity tests and
    the large-r benchmark to pin a strategy at a rank the policy would
    route elsewhere. The override is UNCHECKED: forcing "dense" builds
    the (d, r, r) Gram regardless of the byte budget (that is the point —
    the benchmark times the dense arm past the policy ceiling), so the
    caller owns the memory math at large r.
    """
    r = min(cfg.rank, n)
    idx_lo, w_lo, h = make_inducing(n, r)
    a_coef = inducing_gram_coeffs(params, cfg, r, h)            # (d, 2r-1)
    if causal:
        a_coef = toeplitz.causal_mask_coeffs(a_coef, r)
    if variant is None:
        variant = backend.ski_rank_variant(r, cfg.d) if cfg.fused \
            else "unfused"
    plan = {"r": r, "h": h, "idx_lo": idx_lo, "w_lo": w_lo,
            "causal": causal, "a_coef": a_coef, "variant": variant}
    if variant == "dense":
        plan["a_dense"] = toeplitz.dense_toeplitz(a_coef, r)    # (d, r, r)
    return plan


def ski_tno_apply(params, cfg: SKIConfig, x: jax.Array,
                  causal: bool = False, plan: dict | None = None) -> jax.Array:
    """x: (b, n, d) -> (b, n, d). Bidirectional by default (paper trains
    SKI only bidirectionally; the causal flag exists for the Appendix-B
    negative-result benchmark via core.causal_ski).

    ``plan`` — optional precomputed :func:`ski_plan` (must have been built
    with the same ``causal`` flag); computed here when absent.
    """
    b, n, d = x.shape
    if plan is None:
        plan = ski_plan(params, cfg, n, causal)
    # a stale plan (wrong masking or sequence length) silently computes a
    # different operator — reject it here rather than return wrong numbers
    if plan["causal"] != causal or plan["idx_lo"].shape[0] != n:
        raise ValueError(
            f"plan mismatch: built for causal={plan['causal']}, "
            f"n={plan['idx_lo'].shape[0]}; called with causal={causal}, n={n}")
    r, idx_lo, w_lo = plan["r"], plan["idx_lo"], plan["w_lo"]
    variant = plan.get("variant",
                       "dense" if "a_dense" in plan else "unfused")

    if variant == "dense" and "a_dense" in plan:
        # two-pass fused pipeline as ONE differentiable op: on the Pallas
        # path this is the custom-VJP kernel pair (kernels/ski_vjp.py), so
        # jax.grad through a TNN block trains at kernel speed instead of
        # silently requiring the reference (ROADMAP "Compiled-TPU status")
        y = ops.ski_fused_tno(x, plan["a_dense"], params["filt"],
                              idx_lo, w_lo, r, causal,
                              use_pallas=cfg.use_pallas)
        return y.astype(x.dtype)

    if variant in ("windowed", "fft"):
        # large-rank fused pipeline (PR 3): same two-pass structure, Gram
        # in coefficient form — streamed band blocks (windowed) or a
        # circulant rfft/irfft between the passes (fft); one differentiable
        # op either way, so large-rank training stays on the kernel path
        y = ops.ski_fused_tno_coef(x, plan["a_coef"], params["filt"],
                                   idx_lo, w_lo, r, causal, variant,
                                   use_pallas=cfg.use_pallas)
        return y.astype(x.dtype)

    # unfused 4-kernel fallback (fused disabled): FFT Gram matvec
    # (each Pallas op here carries its own custom VJP, so this path is
    # trainable too)
    z = ops.interp_reduce(x, idx_lo, w_lo, r, use_pallas=cfg.use_pallas)
    y_sparse = ops.short_conv(x, params["filt"], causal,
                              use_pallas=cfg.use_pallas)
    zt = jnp.swapaxes(z, 1, 2)                                 # (b, d, r)
    zt = toeplitz.toeplitz_matvec(plan["a_coef"][None], zt)    # A z
    z2 = jnp.swapaxes(zt, 1, 2)                                # (b, r, d)
    y_low = ops.interp_expand(z2, idx_lo, w_lo, use_pallas=cfg.use_pallas)
    return (y_sparse + y_low).astype(x.dtype)


def ski_dense_oracle(params, cfg: SKIConfig, n: int) -> jax.Array:
    """Materialise T_sparse + W A W^T as dense (d, n, n) — tests only."""
    from repro.kernels.ref import dense_interp_matrix
    r = min(cfg.rank, n)
    idx_lo, w_lo, h = make_inducing(n, r)
    w = dense_interp_matrix(idx_lo, w_lo, r)                   # (n, r)
    a_coef = inducing_gram_coeffs(params, cfg, r, h)
    a = toeplitz.dense_toeplitz(a_coef, r)                     # (d, r, r)
    t_low = jnp.einsum("nr,drs,ms->dnm", w, a, w)
    # sparse part as a banded matrix
    m = cfg.filter_size
    left = m // 2
    filt = params["filt"]
    i = jnp.arange(n)
    lag = i[:, None] - i[None, :]
    k_idx = lag + left                                         # tap index
    valid = (k_idx >= 0) & (k_idx < m)
    t_sp = jnp.where(valid[None], filt[:, jnp.clip(k_idx, 0, m - 1)], 0.0)
    return t_low + t_sp
