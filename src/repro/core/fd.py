"""Frequency-domain TNO (paper §3.3, Algorithm 2).

Causal: the RPE MLP models the *real part* of the kernel's DTFT sampled at
ω_m = mπ/n (m = 0..n, the rfft grid of a length-2n signal); the imaginary
part comes from the discrete Hilbert transform, making the time-domain
kernel exactly causal. No explicit decay bias: the activation's smoothness
fixes the decay class (Theorems 2-4).

Bidirectional: model the complex response directly (2x RPE width), pinning
the imaginary part to zero at ω ∈ {0, π} so the time kernel is real; one
fewer FFT than the baseline TNO.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hilbert import causal_spectrum
from repro.core.rpe import MLPRPEConfig, mlp_rpe_apply, mlp_rpe_init
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class FDConfig:
    d: int
    causal: bool = True
    rpe_hidden: int = 64
    rpe_layers: int = 3
    rpe_act: str = "relu"     # decay class knob (Thms 2-4)
    use_layernorm: bool = True
    # "linear": paper-faithful omega input. "cos": beyond-paper periodic
    # feature map omega -> cos(omega) - the even/periodic extension of a
    # linear-omega MLP has derivative kinks at omega in {0, pi} that force
    # ~1/m^2 kernel decay REGARDLESS of activation smoothness (breaking
    # Thms 2-4's hypothesis); cos makes khat smooth as a *periodic*
    # function so the activation's decay class actually binds (DESIGN
    # par.7; tested in test_paper_core).
    feature: str = "linear"
    use_pallas: bool | None = None   # causal path backend (ops.fd_tno)


def _rpe_cfg(cfg: FDConfig) -> MLPRPEConfig:
    width = cfg.d if cfg.causal else 2 * cfg.d
    return MLPRPEConfig(width, cfg.rpe_hidden, cfg.rpe_layers, cfg.rpe_act,
                        cfg.use_layernorm)


def fd_init(key, cfg: FDConfig):
    return {"rpe": mlp_rpe_init(key, _rpe_cfg(cfg))}


@functools.lru_cache(maxsize=64)
def _omega_grid_host(n: int, feature: str) -> np.ndarray:
    """rfft frequency grid (param-independent): memoised so all FD layers
    of a model share one copy instead of rebuilding it per block.

    Cached as HOST numpy, not a jax.Array: an lru_cache keyed only on
    (n, feature) that holds device buffers pins them to whatever backend
    was active at first call — stale (or dead) buffers leak across
    backend/device switches (e.g. CPU-built grid reused after a TPU
    device_put policy change). Callers device_put via jnp.asarray, which
    is free under jit (the numpy constant is staged per-backend).
    """
    omega = np.arange(n + 1, dtype=np.float32) / n        # omega/pi in [0,1]
    return np.cos(np.pi * omega, dtype=np.float32) if feature == "cos" \
        else omega


def _omega_grid(n: int, feature: str) -> jax.Array:
    """Device view of the cached host grid (see _omega_grid_host)."""
    return jnp.asarray(_omega_grid_host(n, feature))


def kernel_spectrum_real(params, cfg: FDConfig, n: int) -> jax.Array:
    """(d, n+1) *raw* real frequency response on the rfft grid — the RPE
    output before the Hilbert completion. Causal configs only: this is
    the parameter-side input of the fused op ``ops.fd_tno``, which owns
    the Hilbert step (so the causal-spectrum construction runs inside the
    differentiable kernel pipeline, not in the plan)."""
    if not cfg.causal:
        raise ValueError("kernel_spectrum_real is causal-only; "
                         "bidirectional models the complex response")
    omega = _omega_grid(int(n), cfg.feature)
    return mlp_rpe_apply(params["rpe"], _rpe_cfg(cfg), omega).T


def kernel_spectrum(params, cfg: FDConfig, n: int) -> jax.Array:
    """Evaluate the (d, n+1) complex frequency response on the rfft grid.

    Evaluating with a finer grid (larger n) extrapolates to longer
    sequences — in frequency, resolution scales with signal length, so
    length extrapolation is grid refinement, not model extrapolation.
    """
    if cfg.causal:
        return causal_spectrum(kernel_spectrum_real(params, cfg, n))
    omega = _omega_grid(int(n), cfg.feature)
    out = mlp_rpe_apply(params["rpe"], _rpe_cfg(cfg), omega)  # (n+1, width)
    re, im = out[:, : cfg.d].T, out[:, cfg.d:].T              # (d, n+1)
    # real-valued time kernel: imag must vanish at DC and Nyquist
    mask = jnp.ones((n + 1,), jnp.float32).at[0].set(0.0).at[n].set(0.0)
    return re + 1j * (im * mask)


def fd_tno_apply(params, cfg: FDConfig, x: jax.Array,
                 khat: jax.Array | None = None,
                 khat_real: jax.Array | None = None) -> jax.Array:
    """x: (b, n, d) -> (b, n, d) via one rfft/irfft pair on x only.

    Causal configs route through the single differentiable op
    ``ops.fd_tno`` (Hilbert completion + spectral multiply + FFT staging
    — the Pallas path carries custom-VJP backward kernels,
    kernels/fd_fused.py). ``khat_real`` — optional precomputed
    :func:`kernel_spectrum_real` (tno_plan). Bidirectional configs (or an
    explicitly supplied complex ``khat``) use the legacy jnp multiply.
    """
    b, n, d = x.shape
    if cfg.causal and khat is None:
        if khat_real is None:
            khat_real = kernel_spectrum_real(params, cfg, n)  # (d, n+1)
        return ops.fd_tno(x, khat_real, use_pallas=cfg.use_pallas)
    if khat is None:
        khat = kernel_spectrum(params, cfg, n)                # (d, n+1)
    xhat = jnp.fft.rfft(x.astype(jnp.float32), n=2 * n, axis=1)  # (b,n+1,d)
    y = jnp.fft.irfft(xhat * khat.T[None], n=2 * n, axis=1)[:, :n]
    return y.astype(x.dtype)


def fd_kernel_time(params, cfg: FDConfig, n: int) -> jax.Array:
    """Time-domain kernel (d, 2n): lags 0..n then -(n-1)..-1 (circular
    layout). Used by tests (causality ⇒ zeros at negative lags) and by the
    decay-class experiments (Appendix E.3 reproduction)."""
    khat = kernel_spectrum(params, cfg, n)
    return jnp.fft.irfft(khat, n=2 * n, axis=-1)
