"""Toeplitz Neural Operator — baseline (Qin et al. 2023) + unified dispatch.

The baseline TNO is the paper's *floor*: an MLP RPE evaluated at all 2n-1
relative positions, multiplied by the decay bias λ^|t|, applied per channel
with the O(n log n) FFT Toeplitz matvec. ``TNOConfig.variant`` selects the
paper's accelerated variants (ski / fd) behind one interface so any model
in the zoo can swap its token mixer.

Every variant is differentiable end-to-end on whichever backend dispatch
selects: the ski variant routes through ``ops.ski_fused_tno`` whose Pallas
path carries custom-VJP backward kernels (kernels/ski_vjp.py), so
``jax.grad`` of a TNN block never silently falls back to the jnp
reference. The plan (:func:`tno_plan`) is built inside the traced forward,
so parameter gradients flow through the Gram/RPE precomputation as usual.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import fd, ski, toeplitz
from repro.core.rpe import (MLPRPEConfig, decay_bias, mlp_rpe_apply,
                            mlp_rpe_init)


@dataclasses.dataclass(frozen=True)
class TNOConfig:
    d: int
    variant: str = "tno"        # tno | ski | fd
    causal: bool = True
    lam: float = 0.99           # decay bias (tno) / time warp (ski)
    use_decay: bool = True      # baseline decay bias on/off
    # MLP RPE (tno & fd variants)
    rpe_hidden: int = 64
    rpe_layers: int = 3
    rpe_act: str = "relu"
    # SKI
    rank: int = 64
    filter_size: int = 32
    grid_size: int = 129
    use_pallas: bool | None = None
    fused: bool = True          # SKI: two-pass fused pipeline

    def fd_cfg(self) -> fd.FDConfig:
        return fd.FDConfig(self.d, self.causal, self.rpe_hidden,
                           self.rpe_layers, self.rpe_act,
                           use_pallas=self.use_pallas)

    def ski_cfg(self) -> ski.SKIConfig:
        return ski.SKIConfig(self.d, self.rank, self.filter_size, self.lam,
                             self.grid_size, self.use_pallas, self.fused)

    def mlp_cfg(self) -> MLPRPEConfig:
        return MLPRPEConfig(self.d, self.rpe_hidden, self.rpe_layers,
                            self.rpe_act)


def tno_init(key, cfg: TNOConfig):
    if cfg.variant == "tno":
        return {"rpe": mlp_rpe_init(key, cfg.mlp_cfg())}
    if cfg.variant == "fd":
        return fd.fd_init(key, cfg.fd_cfg())
    if cfg.variant == "ski":
        return ski.ski_init(key, cfg.ski_cfg())
    raise ValueError(cfg.variant)


def baseline_coeffs(params, cfg: TNOConfig, n: int) -> jax.Array:
    """(d, 2n-1) Toeplitz coefficients: λ^|t| · RPE(t)."""
    t = toeplitz.lags(n).astype(jnp.float32)
    vals = mlp_rpe_apply(params["rpe"], cfg.mlp_cfg(), t / n)  # (2n-1, d)
    if cfg.use_decay:
        vals = vals * decay_bias(t, cfg.lam)[:, None]
    coef = vals.T
    if cfg.causal:
        coef = toeplitz.causal_mask_coeffs(coef, n)
    return coef


def tno_plan(params, cfg: TNOConfig, n: int) -> dict:
    """Variant-specific forward-invariant precomputation: the SKI inducing
    geometry + Gram, the FD kernel spectrum, or the baseline coefficient
    vector. Built once per layer per forward (core/block.py) so the RPE /
    spectrum evaluation is not repeated per op — serving reuses it across
    decode steps of equal n."""
    if cfg.variant == "fd":
        fcfg = cfg.fd_cfg()
        if fcfg.causal:
            # raw real response: the Hilbert completion happens inside the
            # fused op (ops.fd_tno), so grads flow through it on the
            # kernel path rather than through plan precomputation
            return {"khat_real": fd.kernel_spectrum_real(params, fcfg, n)}
        return {"khat": fd.kernel_spectrum(params, fcfg, n)}
    if cfg.variant == "ski":
        return ski.ski_plan(params, cfg.ski_cfg(), n, causal=cfg.causal)
    return {"coef": baseline_coeffs(params, cfg, n)}


def tno_apply(params, cfg: TNOConfig, x: jax.Array,
              plan: dict | None = None) -> jax.Array:
    """Unified TNO: x (b, n, d) -> (b, n, d). ``plan`` — optional
    :func:`tno_plan` for the same (params, cfg, n)."""
    if cfg.variant == "fd":
        return fd.fd_tno_apply(params, cfg.fd_cfg(), x,
                               khat=plan.get("khat") if plan else None,
                               khat_real=plan.get("khat_real") if plan
                               else None)
    if cfg.variant == "ski":
        return ski.ski_tno_apply(params, cfg.ski_cfg(), x, causal=cfg.causal,
                                 plan=plan)
    # baseline
    n = x.shape[1]
    coef = plan["coef"] if plan else baseline_coeffs(params, cfg, n)
    xt = jnp.swapaxes(x, 1, 2)                       # (b, d, n)
    yt = toeplitz.toeplitz_matvec(coef[None], xt)
    return jnp.swapaxes(yt, 1, 2).astype(x.dtype)


def tno_dense_oracle(params, cfg: TNOConfig, n: int) -> jax.Array:
    """Dense (d, n, n) Toeplitz matrices — tests only."""
    return toeplitz.dense_toeplitz(baseline_coeffs(params, cfg, n), n)
