"""Discrete Hilbert transform & causal spectrum construction (paper §3.3.1).

The causal FD-TNO models only the *real* part ``khat`` of a kernel's
frequency response on the rfft grid ``w_m = m*pi/n`` (m = 0..n, i.e. the
rfft bins of a length-2n real signal) and recovers the imaginary part with
a discrete Hilbert transform:  ``khat_causal = khat - i * H{khat}``.

Identity used throughout: for a length-N DFT, ``u - i*H{u}`` is exactly the
spectrum of the one-sided (causal) window of ``irfft(u)`` — i.e. the
analytic-signal construction applied in the frequency variable. We provide
both the paper's convolution form (Definition 1, for tests) and the
FFT form (Algorithm 2's "via the rFFT and irFFT", for production).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hilbert_kernel(n_taps: int) -> jax.Array:
    """Paper Definition 1: h[l] = 0 (l even), 2/(pi*l) (l odd); lags -n..n."""
    l = jnp.arange(-n_taps, n_taps + 1)
    odd = (l % 2) != 0
    return jnp.where(odd, 2.0 / (jnp.pi * jnp.where(odd, l, 1)), 0.0)


def discrete_hilbert_conv(u: jax.Array) -> jax.Array:
    """H{u} by direct convolution with the periodised Definition-1 kernel —
    O(n^2) oracle used only in tests.

    For an M-periodic sequence (M even) the periodisation of the paper's
    h[l] = 2/(pi l) (odd l) has the closed form (2/M)·cot(pi l / M) for odd
    l and 0 for even l; as M -> inf it recovers 2/(pi l).
    """
    m = u.shape[-1]
    l = jnp.arange(m)
    odd = (l % 2) != 0
    h_per = jnp.where(odd, (2.0 / m) / jnp.tan(jnp.pi * jnp.where(l > 0, l, 1) / m), 0.0)
    idx = (jnp.arange(m)[:, None] - jnp.arange(m)[None, :]) % m
    return jnp.einsum("...j,kj->...k", u.astype(jnp.float32), h_per[idx])


def _dft_sign(m: int) -> jax.Array:
    """sign(+freq)=+1, sign(-freq)=-1, 0 at DC and (if even) Nyquist."""
    f = jnp.fft.fftfreq(m)
    return jnp.sign(f).at[0].set(0.0)


def discrete_hilbert(u: jax.Array) -> jax.Array:
    """FFT-based discrete Hilbert transform of a periodic sequence (axis -1)."""
    m = u.shape[-1]
    sgn = _dft_sign(m)
    spec = jnp.fft.fft(u.astype(jnp.float32), axis=-1)
    return jnp.fft.ifft(spec * (-1j) * sgn, axis=-1).real.astype(u.dtype)


def causal_spectrum(khat_real: jax.Array) -> jax.Array:
    """khat_real: (..., n+1) real samples on the rfft grid of a length-2n
    signal. Returns complex (..., n+1) ``khat - i*H{khat}`` whose irfft is
    (exactly) a causal length-2n kernel supported on lags 0..n.

    Implemented by the equivalent one-sided time-window (2 real FFTs), which
    is the numerically-exact form of Algorithm 2's Hilbert step.
    """
    npts = khat_real.shape[-1] - 1
    two_n = 2 * npts
    k_time = jnp.fft.irfft(khat_real.astype(jnp.float32), n=two_n, axis=-1)
    # analytic-signal window in the lag variable: keep lag 0 and lag n as-is,
    # double lags 1..n-1, zero lags n+1..2n-1 (negative lags).
    w = jnp.concatenate([
        jnp.ones((1,)), 2.0 * jnp.ones((npts - 1,)), jnp.ones((1,)),
        jnp.zeros((npts - 1,)),
    ])
    k_causal = k_time * w
    return jnp.fft.rfft(k_causal, n=two_n, axis=-1)


def causal_spectrum_via_hilbert(khat_real: jax.Array) -> jax.Array:
    """Literal paper form: khat - i * H{khat} with H over the even-symmetric
    extension of the rfft-grid samples. Matches :func:`causal_spectrum`.
    """
    npts = khat_real.shape[-1] - 1
    # even-symmetric periodic extension over the full 2n DFT grid
    body = khat_real[..., 1:-1]
    full = jnp.concatenate([khat_real, body[..., ::-1]], axis=-1)  # (.., 2n)
    h = discrete_hilbert(full)
    spec = full - 1j * h
    return spec[..., : npts + 1]
