"""Toeplitz matrix actions via circulant embedding + FFT.

Conventions
-----------
A length-n Toeplitz matrix ``T_ij = t[i - j]`` is parametrised by its
coefficients at lags ``-(n-1) .. (n-1)``. We store them as an array
``t`` of shape (..., 2n-1) with ``t[..., k]`` holding lag ``k - (n-1)``
(i.e. index 0 is the most-negative lag, index n-1 is lag 0).

``toeplitz_matvec`` embeds T in a 2n circulant and uses a real FFT:
O(n log n), exactly the TNN fast path of Qin et al. 2023 that this paper
accelerates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lags(n: int) -> jax.Array:
    """Integer lags -(n-1)..(n-1) matching the coefficient layout."""
    return jnp.arange(-(n - 1), n)


def dense_toeplitz(t: jax.Array, n: int) -> jax.Array:
    """Materialise the (..., n, n) Toeplitz matrix (oracle / small r only)."""
    assert t.shape[-1] == 2 * n - 1
    i = jnp.arange(n)
    idx = (i[:, None] - i[None, :]) + (n - 1)  # lag -> coefficient index
    return t[..., idx]


def _circulant_coeffs(t: jax.Array, n: int) -> jax.Array:
    """(..., 2n-1) lag layout -> (..., 2n) circulant first column."""
    # c[k] = t(lag k) for k=0..n-1 ; c[n] = 0 (pad) ; c[2n-k] = t(lag -k)
    pos = t[..., n - 1:]                       # lags 0..n-1
    neg = t[..., : n - 1]                      # lags -(n-1)..-1 (ascending)
    pad = jnp.zeros(t.shape[:-1] + (1,), t.dtype)
    return jnp.concatenate([pos, pad, neg], axis=-1)


def toeplitz_matvec(t: jax.Array, x: jax.Array) -> jax.Array:
    """y[..., i] = sum_j t[i-j] x[..., j] via length-2n rFFT.

    t: (..., 2n-1) broadcastable against x's batch dims; x: (..., n).
    """
    n = x.shape[-1]
    assert t.shape[-1] == 2 * n - 1, (t.shape, x.shape)
    c = _circulant_coeffs(t, n)
    fc = jnp.fft.rfft(c.astype(jnp.float32), axis=-1)
    fx = jnp.fft.rfft(x.astype(jnp.float32), n=2 * n, axis=-1)
    y = jnp.fft.irfft(fc * fx, n=2 * n, axis=-1)[..., :n]
    return y.astype(x.dtype)


def toeplitz_matvec_causal(t_causal: jax.Array, x: jax.Array) -> jax.Array:
    """Causal Toeplitz action: t_causal (..., n) holds lags 0..n-1."""
    n = x.shape[-1]
    assert t_causal.shape[-1] == n
    neg = jnp.zeros(t_causal.shape[:-1] + (n - 1,), t_causal.dtype)
    t = jnp.concatenate([neg, t_causal], axis=-1)
    return toeplitz_matvec(t, x)


def causal_mask_coeffs(t: jax.Array, n: int) -> jax.Array:
    """Zero the negative-lag coefficients (causal masking of T)."""
    mask = (lags(n) >= 0).astype(t.dtype)
    return t * mask
