"""Appendix-B negative result: causal masking negates SKI's benefits.

The causally-masked low-rank action x'_i = [W A]_i^T s_i with the
cumulative sums s_i = Σ_{j≤i} w_j x_j requires O(n r) work *and* an
(b, n, r, d) intermediate. On TPU the serial cumsum maps to
``associative_scan`` (log-depth) but the O(n r d) memory/work loss vs
O(n + r log r) stands — we implement it to *benchmark the negative
result* (bench_appendix_b), exactly as the paper argues for GPUs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import toeplitz
from repro.core.ski import SKIConfig, inducing_gram_coeffs, make_inducing
from repro.kernels.ref import dense_interp_matrix


def causal_ski_lowrank(params, cfg: SKIConfig, x: jax.Array) -> jax.Array:
    """Causally-masked W A W^T action via cumulative sums. x: (b, n, d)."""
    b, n, d = x.shape
    r = min(cfg.rank, n)
    idx_lo, w_lo, h = make_inducing(n, r)
    w = dense_interp_matrix(idx_lo, w_lo, r)                    # (n, r)
    a_coef = inducing_gram_coeffs(params, cfg, r, h)            # (d, 2r-1)
    a = toeplitz.dense_toeplitz(a_coef, r)                      # (d, r, r)

    # s_i = sum_{j<=i} w_j x_j  -> (b, n, r, d) intermediate (the blow-up)
    wx = w[None, :, :, None] * x[:, :, None, :].astype(jnp.float32)
    s = jnp.cumsum(wx, axis=1)
    # y_i = (A^T w_i)^T s_i  per channel
    wa = jnp.einsum("nr,drs->nds", w, a)                        # (n, d, r)
    y = jnp.einsum("nds,bnsd->bnd", wa, s)
    return y.astype(x.dtype)
