"""Host-side scheduler: request queue, slot admission, token streaming.

Drives an :class:`~repro.serving_engine.engine.Engine` with the classic
continuous-batching loop (MaxText/JetStream offline_inference shape):

    while work:
        if free slot and queued request:   # greedy prefill-first
            prefix, first, p = engine.prefill(request)   # C-block chunked
            state = engine.insert(state, prefix, p, first, slot)
        else:
            state, tokens = engine.generate(state)       # all slots, 1 step
        stream tokens to per-request callbacks; evict EOS/max-len slots,
        recycle them for the queue

Admission is *greedy prefill-first*: whenever a slot is free and a
request is queued, the scheduler prefills and inserts before taking the
next decode step, so the batch refills as soon as capacity exists —
decode steps then amortise the model over every live request. Eviction
is immediate: a slot is released the step its request finishes (EOS hit
or ``max_new`` tokens emitted), and the freed slot admits the next
queued request on the following loop iteration.

The per-step host sync (one (S,) token transfer) is what streams tokens
to callbacks; a production deployment would move detokenisation to a
separate thread against an async transfer (the MaxText detokenize-thread
pattern) — on CPU the sync is noise next to the model step.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.serving_engine.engine import Engine


@dataclasses.dataclass
class Request:
    uid: str
    prompt: np.ndarray            # (p,) int32 prompt tokens
    max_new: int                  # generation budget (tokens)
    eos_id: Optional[int] = None  # stop token (None = run to max_new)
    on_token: Optional[Callable[[str, int], None]] = None  # streaming cb


class Scheduler:
    def __init__(self, engine: Engine):
        self.engine = engine
        self.queue: deque = deque()
        self.results: Dict[str, List[int]] = {}
        self.steps = 0                # decode steps taken (stats)
        self.prefills = 0

    def submit(self, req: Request) -> None:
        """Queue a request; rejects loudly when prompt + generation could
        not fit a slot (an over-capacity run would clamp cache writes and
        corrupt the slot's ring/KV rows mid-generation)."""
        p = int(np.asarray(req.prompt).shape[-1])
        if req.max_new < 1:
            raise ValueError(f"request {req.uid}: max_new must be >= 1")
        cap = self.engine.capacity
        # positions written: p prompt + (max_new - 1) fed-back tokens
        # (the final sampled token is emitted but never fed)
        if cap is not None and p + req.max_new - 1 > cap:
            raise ValueError(
                f"request {req.uid}: prompt {p} + max_new {req.max_new} "
                f"exceeds slot capacity {cap} "
                f"(Engine(max_len={self.engine.max_len}))")
        if req.uid in self.results:
            # a reused uid would merge token lists and trip the budget
            # check early, silently truncating the later request
            raise ValueError(f"request uid {req.uid!r} already submitted")
        self.queue.append(req)
        self.results[req.uid] = []

    # ------------------------------------------------------------ internals
    def _emit(self, req: Request, token: int) -> bool:
        """Record/stream one token; returns True when the request is done
        (EOS or budget exhausted)."""
        self.results[req.uid].append(token)
        if req.on_token is not None:
            req.on_token(req.uid, token)
        done = len(self.results[req.uid]) >= req.max_new
        if req.eos_id is not None and token == req.eos_id:
            done = True
        return done

    # --------------------------------------------------------------- run
    def run(self, state=None):
        """Drain the queue; returns ({uid: [generated tokens]}, state).
        Reentrant: pass the returned state back in to keep serving."""
        eng = self.engine
        if state is None:
            state = eng.init_state()
        free = list(range(eng.slots))[::-1]     # pop() admits slot 0 first
        slot_req: Dict[int, Request] = {}

        while self.queue or slot_req:
            if self.queue and free:             # greedy prefill-first
                req = self.queue.popleft()
                slot = free.pop()
                prefix, first, plen = eng.prefill(req.prompt)
                self.prefills += 1
                tok = int(first)
                if self._emit(req, tok):        # 1-token request: done
                    free.append(slot)
                    continue
                state = eng.insert(state, prefix, plen, tok, slot)
                slot_req[slot] = req
                continue
            state, toks = eng.generate(state)
            self.steps += 1
            toks_h = np.asarray(toks)           # host sync: stream point
            for slot in sorted(slot_req):
                req = slot_req[slot]
                if self._emit(req, int(toks_h[slot])):
                    state = eng.release(state, slot)
                    del slot_req[slot]
                    free.append(slot)
        return self.results, state
