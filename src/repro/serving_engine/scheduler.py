"""Host-side supervised scheduler: queue, admission, isolation, deadlines.

Drives an :class:`~repro.serving_engine.engine.Engine` with the classic
continuous-batching loop (MaxText/JetStream offline_inference shape):

    while work:
        watchdog: evict expired slots, drop expired queued requests
        if free slots and queued requests:  # greedy prefill-first
            pack ≤ prefill_pack requests → ONE padded batch prefill
            scatter each row into its slot (engine.insert_from)
        else:
            state, tokens, ok = engine.generate(state)   # all slots, 1 step
        record tokens; hand callbacks to the detokenise worker thread;
        evict EOS/max-len/non-finite slots, recycle them for the queue

PR 7 makes admission *batched* and detokenisation *asynchronous*: up to
``prefill_pack`` queued prompts are packed into one bucketed prefill
executable per step (``engine.prefill_packed``; prompts that fall off
the bucket ladder, or a pack of one, use the sequential path), and
``on_token`` callbacks run on a background worker thread draining a
bounded token queue, so host-side detokenisation overlaps the next
jitted decode step instead of serialising with it. Ordering is
preserved (single worker, FIFO), callback exceptions still detach the
callback (now on the worker), and the queue is drained at every
snapshot, whenever deadlines are armed (watchdog determinism), and
before ``run`` returns — so every PR 6 fault-tolerance observable is
settled when it is read.

PR 6 makes the loop a *supervisor* (the serving twin of the trainer's
1000-node posture): one bad request can no longer take down the other
S - 1 in-flight generations.

* **Request isolation** — a prefill/insert/emit failure fails only that
  request: its :class:`Outcome` records ``status="error"`` with the
  message, the slot goes back to the free list, the loop continues.
  Transient errors (``RuntimeError``, which includes XLA runtime errors
  and :class:`~repro.serving_engine.faults.InjectedFault`) are retried
  with exponential backoff up to ``max_retries``; a raising ``on_token``
  callback is **detached** (never unwinds the loop) and noted on the
  outcome.
* **Non-finite guard** — ``engine.generate`` quarantines slots whose
  logits went non-finite; the scheduler records an error outcome and
  recycles the slot instead of streaming garbage.
* **Deadlines** — per-request TTL (``Request.deadline`` seconds, or the
  scheduler's ``default_deadline``); a step-loop watchdog evicts expired
  slots and drops expired queued requests with ``status="expired"``.
* **Backpressure** — ``queue_cap`` bounds the queue; ``admission``
  policy is ``"reject"`` (raise :class:`QueueFull`) or ``"block"``
  (``submit`` waits until ``run`` — in another thread — drains a spot).
* **Preemption + snapshot/restore** — SIGTERM/SIGINT (same handler
  shape as ``runtime.Trainer``) finishes the current step, writes a
  final snapshot (``snapshot_dir``) and returns; a new process calls
  :meth:`try_restore` and ``run()`` resumes with token-exact
  continuation. Periodic snapshots every ``snapshot_every`` decode
  steps; a *failing* snapshot write is counted and logged, never fatal.
* **Fault injection** — an optional
  :class:`~repro.serving_engine.faults.FaultInjector` fires at the
  prefill / decode / callback / snapshot boundaries so every failure
  mode above is CI-exercised deterministically.

``run()`` still returns ``({uid: [tokens]}, state)``; per-request status
lives in ``scheduler.outcomes`` (``Outcome.tokens`` aliases the same
list as ``results[uid]``).
"""
from __future__ import annotations

import dataclasses
import os
import queue as queue_mod
import signal
import threading
import time
import zlib
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.obs import profiling as obs_prof
from repro.obs import tracing as obs_tracing
from repro.serving_engine.engine import Engine

#: terminal request states; anything else is pending/in-flight
TERMINAL = ("ok", "error", "expired")

_ENV_PACK = "REPRO_PREFILL_PACK"
_ENV_DETOK = "REPRO_DETOK_ASYNC"


def default_prefill_pack() -> int:
    v = os.environ.get(_ENV_PACK)
    if v is None or v == "":
        return 4
    p = int(v)
    if p < 1:
        raise ValueError(f"{_ENV_PACK}={p} must be >= 1")
    return p


def default_detok_async() -> bool:
    v = os.environ.get(_ENV_DETOK)
    if v is None or v == "":
        return True
    return v.strip().lower() not in ("0", "false", "off", "no")


class QueueFull(RuntimeError):
    """submit() under admission="reject" with a full bounded queue."""


class EngineStepError(RuntimeError):
    """The batched decode step failed persistently (retries exhausted).

    In-flight requests have been failed with explicit error outcomes and
    their slots released; the *queue is left intact*, so a fresh
    ``run()`` (new engine state) serves the remaining requests."""


@dataclasses.dataclass
class Request:
    uid: str
    prompt: np.ndarray            # (p,) int32 prompt tokens
    max_new: int                  # generation budget (tokens)
    eos_id: Optional[int] = None  # stop token (None = run to max_new)
    on_token: Optional[Callable[[str, int], None]] = None  # streaming cb
    deadline: Optional[float] = None  # TTL seconds from submit (None = ∞)
    seed: Optional[int] = None    # sampling seed (None = derived from uid)

    def resolved_seed(self) -> int:
        """Effective sampling seed: explicit, else a stable uid hash so
        two requests with the same prompt still sample distinct streams
        (and a snapshot-resumed request replays the same one)."""
        if self.seed is not None:
            return int(self.seed)
        return zlib.crc32(self.uid.encode()) & 0x7FFFFFFF


@dataclasses.dataclass
class Outcome:
    """Per-request terminal record. ``tokens`` aliases ``results[uid]``."""
    uid: str
    status: str = "pending"             # pending | ok | error | expired
    tokens: List[int] = dataclasses.field(default_factory=list)
    error: Optional[str] = None         # set when status in {error}
    callback_error: Optional[str] = None  # callback detached mid-stream


def _errmsg(e: BaseException) -> str:
    return f"{type(e).__name__}: {e}"


class _DetokWorker:
    """Background detokenise/callback pipeline (the JetThread role in
    MaxText's offline inference): a single daemon thread drains a
    bounded FIFO of (request, token) pairs and invokes ``on_token``
    callbacks off the decode hot loop.

    * **Ordering** — one worker, one FIFO: callbacks fire in exactly the
      emit order, same as the old synchronous path.
    * **Backpressure** — the queue is bounded; when callbacks fall
      behind, ``put`` blocks the scheduler loop instead of buffering
      unboundedly.
    * **Detach-on-raise** — a raising callback (or injected callback
      fault) is detached on the worker: ``req.on_token`` is cleared so
      queued/later tokens for that request are skipped, and the outcome
      records ``callback_error`` — identical observables to PR 6's
      synchronous isolation boundary.
    * **drain()** — blocks until every queued callback has completed;
      the scheduler drains before watchdog reads when deadlines are
      armed (callbacks may advance an injected clock), before every
      snapshot, and when ``run`` returns, so outcomes are settled at
      each synchronisation point.
    """

    _STOP = object()

    def __init__(self, sched: "Scheduler", cap: int):
        self._sched = sched
        self._q: queue_mod.Queue = queue_mod.Queue(maxsize=cap)
        self._thread: Optional[threading.Thread] = None

    def start(self):
        self._thread = threading.Thread(
            target=self._loop, name="detok-worker", daemon=True)
        self._thread.start()

    def put(self, req: Request, token: int):
        self._q.put((req, token))       # blocks when full: backpressure

    def drain(self):
        self._q.join()

    def stop(self):
        if self._thread is None:
            return
        self._q.put(self._STOP)
        self._thread.join()
        self._thread = None

    def _loop(self):
        sched = self._sched
        while True:
            item = self._q.get()
            try:
                if item is self._STOP:
                    return
                req, token = item
                if req.on_token is None:    # detached mid-queue: skip
                    continue
                try:
                    if sched.injector is not None:
                        sched.injector.callback(req.uid)
                    req.on_token(req.uid, token)
                except Exception as e:  # noqa: BLE001 — isolation boundary
                    req.on_token = None
                    sched.outcomes[req.uid].callback_error = _errmsg(e)
                    sched._m_cb_errors.inc()
                    sched._ti("callback_detached", req.uid,
                              error=_errmsg(e))
                    sched.log(f"[scheduler] request {req.uid}: on_token "
                              f"raised, callback detached ({_errmsg(e)})")
            finally:
                self._q.task_done()


class Scheduler:
    def __init__(self, engine: Engine, *,
                 queue_cap: Optional[int] = None,
                 admission: str = "reject",
                 default_deadline: Optional[float] = None,
                 max_retries: int = 2,
                 backoff_base: float = 0.05,
                 injector=None,
                 snapshot_dir: Optional[str] = None,
                 snapshot_every: int = 0,
                 prefill_pack: Optional[int] = None,
                 detok_async: Optional[bool] = None,
                 detok_cap: int = 1024,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 log: Optional[Callable[[str], None]] = None,
                 metrics=None,
                 tracer: Optional[obs_tracing.Tracer] = None,
                 mem_sample_every: Optional[int] = None):
        if admission not in ("reject", "block"):
            raise ValueError(f"admission={admission!r}: "
                             "expected 'reject' or 'block'")
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap={queue_cap} must be >= 1")
        if detok_cap < 1:
            raise ValueError(f"detok_cap={detok_cap} must be >= 1")
        self.engine = engine
        self.queue: deque = deque()
        self.queue_cap = queue_cap
        self.admission = admission
        self.default_deadline = default_deadline
        self.max_retries = int(max_retries)
        self.backoff_base = float(backoff_base)
        self.injector = injector
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = int(snapshot_every)
        self.prefill_pack = (default_prefill_pack() if prefill_pack is None
                             else int(prefill_pack))
        if self.prefill_pack < 1:
            raise ValueError(
                f"prefill_pack={self.prefill_pack} must be >= 1")
        self.detok_async = (default_detok_async() if detok_async is None
                            else bool(detok_async))
        self.detok_cap = int(detok_cap)
        self._detok: Optional[_DetokWorker] = None
        self.clock = clock
        self.sleep = sleep
        # supervision messages route through the one obs logger by
        # default (REPRO_LOG_LEVEL; quiet under pytest) — an explicit
        # ``log=`` callable still wins, e.g. tests capturing lines
        self.log = log or obs_log.get_logger("scheduler").info
        # ---- observability (ISSUE 9): metrics registry + span tracer.
        # Explicit objects win; else the process defaults (a no-op
        # registry unless REPRO_METRICS, a tracer only under
        # REPRO_TRACE_FILE) — the un-instrumented hot path pays one
        # no-op call per site, no device syncs ever.
        self.metrics = (metrics if metrics is not None
                        else obs_metrics.default_registry())
        self.tracer = (tracer if tracer is not None
                       else obs_tracing.default_tracer())
        # one cached flag gates the per-token path (TTFT/TPOT + instants)
        self._obs_on = (self.tracer is not None or
                        not isinstance(self.metrics,
                                       obs_metrics.NullRegistry))
        # periodic HBM/live-buffer gauges (ISSUE 10 tentpole §3b):
        # every N decode steps sample live device bytes + DecodeState
        # cache/fd-stream bytes. 0 = off (the default; the sample walks
        # the cache tree on the host, so it stays opt-in).
        if mem_sample_every is None:
            from repro.obs import devstats as obs_devstats
            mem_sample_every = (obs_devstats.mem_sample_every()
                                if self._obs_on else 0)
        self.mem_sample_every = int(mem_sample_every)
        m = self.metrics
        self._m_submitted = m.counter(
            "repro_requests_submitted_total", "requests accepted by submit()")
        self._m_rejected = m.counter(
            "repro_requests_rejected_total",
            "submissions refused before queuing", ("reason",))
        self._m_finished = m.counter(
            "repro_requests_finished_total",
            "terminal request outcomes", ("status",))
        self._m_retries = m.counter(
            "repro_retries_total", "transient-fault retries", ("site",))
        self._m_evictions = m.counter(
            "repro_evictions_total", "slot/queue evictions", ("reason",))
        self._m_steps = m.counter(
            "repro_decode_steps_total", "batched decode steps taken")
        self._m_prefills = m.counter(
            "repro_prefills_total", "per-request prefills", ("mode",))
        self._m_packed_waves = m.counter(
            "repro_packed_prefill_waves_total",
            "packed admission batches run")
        self._m_snapshots = m.counter(
            "repro_snapshots_total", "snapshot writes", ("result",))
        self._m_cb_errors = m.counter(
            "repro_callback_errors_total", "on_token callbacks detached")
        self._m_queue_depth = m.gauge(
            "repro_queue_depth", "requests waiting for admission")
        self._m_slots_active = m.gauge(
            "repro_slots_active", "slots holding in-flight requests")
        self._m_detok_depth = m.gauge(
            "repro_detok_queue_depth",
            "tokens waiting for the detokenise worker")
        self._m_ttft = m.histogram(
            "repro_ttft_seconds", "submit -> first token recorded")
        self._m_tpot = m.histogram(
            "repro_tpot_seconds", "inter-token gap per request")
        self._m_step_s = m.histogram(
            "repro_decode_step_seconds",
            "one batched decode step, host wall incl. token sync")
        self._m_prefill_s = m.histogram(
            "repro_prefill_seconds", "admission wave wall time")
        self._m_snap_s = m.histogram(
            "repro_snapshot_seconds", "snapshot write wall time")
        self._t_submit: Dict[str, float] = {}   # uid -> submit clock()
        self._t_last: Dict[str, float] = {}     # uid -> last token clock()
        self._span_open: Dict[str, List[str]] = {}  # uid -> open child spans
        if injector is not None:
            injector.bind(self.metrics, self.tracer)
        self.results: Dict[str, List[int]] = {}
        self.outcomes: Dict[str, Outcome] = {}
        self._deadlines: Dict[str, float] = {}   # uid -> absolute clock()
        self._lock = threading.Lock()
        self._not_full = threading.Condition(self._lock)
        self.steps = 0                # decode steps taken (stats)
        self.prefills = 0
        self.packed_prefills = 0      # packed admission batches run
        self.retries = 0              # transient-fault retries performed
        self.evictions = 0            # deadline/non-finite evictions
        self.snapshot_errors = 0
        self.preempted = False
        self._resume = None           # set by try_restore()

    # ------------------------------------------------------- observability
    def _tb(self, name, uid=None, **attrs):
        if self.tracer is not None:
            self.tracer.begin(name, uid, **attrs)

    def _te(self, name, uid=None, **attrs):
        if self.tracer is not None:
            self.tracer.end(name, uid, **attrs)

    def _ti(self, name, uid=None, **attrs):
        if self.tracer is not None:
            self.tracer.instant(name, uid, **attrs)

    def _open_span(self, uid: str, name: str, **attrs):
        self._span_open.setdefault(uid, []).append(name)
        self._tb(name, uid, **attrs)

    def _close_span(self, uid: str, name: str, **attrs):
        opened = self._span_open.get(uid)
        if opened and name in opened:
            opened.remove(name)
            self._te(name, uid, **attrs)

    def _close_request(self, uid: str, status: str):
        """End any still-open child spans (innermost first), then the
        ``request`` span with its terminal status — the single point that
        guarantees every submitted request leaves a complete span tree."""
        for name in reversed(self._span_open.pop(uid, [])):
            self._te(name, uid)
        self._te("request", uid, status=status)

    def _observe_counters(self, slots_active: Optional[int] = None):
        """Refresh the global gauge/counter tracks (cheap host reads)."""
        self._m_queue_depth.set(len(self.queue))
        if self._detok is not None:
            self._m_detok_depth.set(self._detok._q.qsize())
        if slots_active is not None:
            self._m_slots_active.set(slots_active)
        if self.tracer is not None:
            self.tracer.counter("queue_depth", len(self.queue))
            if slots_active is not None:
                self.tracer.counter("slots_active", slots_active)

    def _ensure_request_spans(self, slot_req: Dict[int, Request]):
        """(Re-)begin request spans for pending work entering ``run()``.
        Fresh submissions opened theirs in :meth:`submit`; requests
        carried across a preemption (same-process re-run or a
        :meth:`try_restore` in a new process) are re-begun with
        ``resumed=True`` — restored in-flight requests get an immediate
        queue B+E pair so every request span satisfies the
        :func:`~repro.obs.tracing.validate_spans` contract."""
        if self.tracer is None:
            return
        with self._lock:
            queued = list(self.queue)
        for req in queued:
            if req.uid not in self._span_open:
                self._tb("request", req.uid, resumed=True)
                self._open_span(req.uid, "queue", resumed=True)
        for slot in sorted(slot_req):
            uid = slot_req[slot].uid
            if uid not in self._span_open:
                self._tb("request", uid, resumed=True)
                self._tb("queue", uid, resumed=True)
                self._te("queue", uid)
                self._open_span(uid, "decode", slot=slot, resumed=True)

    # ----------------------------------------------------------- admission
    def submit(self, req: Request, *, timeout: Optional[float] = None) -> None:
        """Queue a request. Rejects loudly when prompt + generation could
        not fit a slot (an over-capacity run would clamp cache writes and
        corrupt the slot's ring/KV rows mid-generation). With a bounded
        queue, ``admission="reject"`` raises :class:`QueueFull` when
        full; ``"block"`` waits until ``run()`` (in another thread)
        drains a spot (or ``timeout`` seconds elapse — then QueueFull)."""
        p = int(np.asarray(req.prompt).shape[-1])
        if req.max_new < 1:
            self._m_rejected.labels(reason="bad_request").inc()
            raise ValueError(f"request {req.uid}: max_new must be >= 1")
        cap = self.engine.capacity
        # positions written: p prompt + (max_new - 1) fed-back tokens
        # (the final sampled token is emitted but never fed)
        if cap is not None and p + req.max_new - 1 > cap:
            self._m_rejected.labels(reason="over_capacity").inc()
            raise ValueError(
                f"request {req.uid}: prompt {p} + max_new {req.max_new} "
                f"exceeds slot capacity {cap} "
                f"(Engine(max_len={self.engine.max_len}))")
        if req.uid in self.results:
            # a reused uid — including one from an already-completed run —
            # would merge token lists and trip the budget check early,
            # silently truncating the later request
            self._m_rejected.labels(reason="duplicate_uid").inc()
            raise ValueError(f"request uid {req.uid!r} already submitted")
        with self._not_full:
            if self.queue_cap is not None:
                if self.admission == "reject":
                    if len(self.queue) >= self.queue_cap:
                        self._m_rejected.labels(reason="queue_full").inc()
                        raise QueueFull(
                            f"request {req.uid}: queue at capacity "
                            f"{self.queue_cap} (admission='reject')")
                else:                                   # block
                    deadline = (None if timeout is None
                                else self.clock() + timeout)
                    while len(self.queue) >= self.queue_cap:
                        remaining = (None if deadline is None
                                     else deadline - self.clock())
                        if remaining is not None and remaining <= 0:
                            self._m_rejected.labels(
                                reason="queue_full").inc()
                            raise QueueFull(
                                f"request {req.uid}: queue still full "
                                f"after {timeout}s (admission='block')")
                        self._not_full.wait(remaining)
            self.queue.append(req)
            self.results[req.uid] = []
            self.outcomes[req.uid] = Outcome(uid=req.uid,
                                             tokens=self.results[req.uid])
            ttl = (req.deadline if req.deadline is not None
                   else self.default_deadline)
            if ttl is not None:
                self._deadlines[req.uid] = self.clock() + float(ttl)
        self._m_submitted.inc()
        self._t_submit[req.uid] = self.clock()
        self._tb("request", req.uid, prompt_len=p, max_new=req.max_new)
        self._open_span(req.uid, "queue")
        self._observe_counters()

    def _pop_request(self) -> Optional[Request]:
        with self._not_full:
            if not self.queue:
                return None
            req = self.queue.popleft()
            self._not_full.notify()
        self._close_span(req.uid, "queue")
        self._observe_counters()
        return req

    def _pop_up_to(self, n: int) -> List[Request]:
        """Pop at most n queued requests (FIFO) for one admission wave."""
        out: List[Request] = []
        with self._not_full:
            while self.queue and len(out) < n:
                out.append(self.queue.popleft())
                self._not_full.notify()
        for req in out:
            self._close_span(req.uid, "queue")
        if out:
            self._observe_counters()
        return out

    # ------------------------------------------------------------ signals
    def _install_signals(self):
        self._old_handlers = {}
        if threading.current_thread() is not threading.main_thread():
            return                         # signals only land on main

        def handler(signum, frame):
            self.preempted = True
            self.log(f"[scheduler] signal {signum}: "
                     "snapshot-and-exit requested")
        for s in (signal.SIGTERM, signal.SIGINT):
            self._old_handlers[s] = signal.signal(s, handler)

    def _restore_signals(self):
        for s, h in getattr(self, "_old_handlers", {}).items():
            signal.signal(s, h)

    def preempt(self):
        """Programmatic preemption: finish the current step, snapshot
        (when configured), return from ``run``."""
        self.preempted = True

    # ----------------------------------------------------------- outcomes
    def _finish(self, uid: str, status: str, error: Optional[str] = None):
        out = self.outcomes[uid]
        out.status = status
        if error is not None:
            out.error = error
        self._deadlines.pop(uid, None)
        self._m_finished.labels(status=status).inc()
        self._close_request(uid, status)
        self._t_submit.pop(uid, None)
        self._t_last.pop(uid, None)
        if status != "ok":
            self.log(f"[scheduler] request {uid}: {status}"
                     + (f" ({error})" if error else ""))

    def _emit(self, req: Request, token: int) -> bool:
        """Record/stream one token; returns True when the request is done
        (EOS or budget exhausted). Bookkeeping (results, done check) is
        synchronous; the ``on_token`` callback is handed to the detok
        worker when one is live, else invoked inline. A raising callback
        (or an injected callback fault) is detached and noted — never
        unwinds the loop."""
        self.results[req.uid].append(token)
        if self._obs_on:
            now = self.clock()
            if len(self.results[req.uid]) == 1:
                t0 = self._t_submit.get(req.uid)
                if t0 is not None:
                    self._m_ttft.observe(now - t0)
                self._ti("first_token", req.uid)
            else:
                prev = self._t_last.get(req.uid)
                if prev is not None:
                    self._m_tpot.observe(now - prev)
                self._ti("token", req.uid)
            self._t_last[req.uid] = now
        if req.on_token is not None:
            if self._detok is not None:
                self._detok.put(req, token)
            else:
                try:
                    if self.injector is not None:
                        self.injector.callback(req.uid)
                    req.on_token(req.uid, token)
                except Exception as e:  # noqa: BLE001 — isolation boundary
                    req.on_token = None
                    self.outcomes[req.uid].callback_error = _errmsg(e)
                    self._m_cb_errors.inc()
                    self._ti("callback_detached", req.uid, error=_errmsg(e))
                    self.log(f"[scheduler] request {req.uid}: on_token "
                             f"raised, callback detached ({_errmsg(e)})")
        done = len(self.results[req.uid]) >= req.max_new
        if req.eos_id is not None and token == req.eos_id:
            done = True
        return done

    def _drain_detok(self):
        if self._detok is not None:
            self._detok.drain()

    # ----------------------------------------------------------- watchdog
    def _expire_queue(self, now: float):
        """Drop queued requests whose deadline passed before admission."""
        with self._not_full:
            if not self._deadlines:
                return
            keep = deque()
            for req in self.queue:
                dl = self._deadlines.get(req.uid)
                if dl is not None and now > dl:
                    self._ti("expired", req.uid, where="queue")
                    self._m_evictions.labels(reason="deadline").inc()
                    self._finish(req.uid, "expired",
                                 "deadline exceeded while queued")
                    self.evictions += 1
                    self._not_full.notify()
                else:
                    keep.append(req)
            self.queue = keep

    def _expire_slots(self, now: float, state, slot_req: Dict[int, Request],
                      free: List[int]):
        for slot in sorted(slot_req):
            req = slot_req[slot]
            dl = self._deadlines.get(req.uid)
            if dl is not None and now > dl:
                self._ti("expired", req.uid, where="slot", slot=slot)
                self._m_evictions.labels(reason="deadline").inc()
                self._finish(
                    req.uid, "expired",
                    f"deadline exceeded after "
                    f"{len(self.results[req.uid])} tokens")
                self.evictions += 1
                state = self.engine.release(state, slot)
                del slot_req[slot]
                free.append(slot)
        return state

    # ------------------------------------------------------------ retries
    def _backoff(self, attempt: int, *, site: str = "other",
                 uid: Optional[str] = None):
        self.retries += 1
        self._m_retries.labels(site=site).inc()
        self._ti("retry", uid, site=site, attempt=attempt)
        if self.backoff_base > 0:
            self.sleep(self.backoff_base * (2 ** attempt))

    def _prefill_with_retry(self, req: Request):
        """Transient (RuntimeError-family) prefill failures retry with
        exponential backoff; anything else — and retry exhaustion —
        propagates to the caller's isolation boundary."""
        for attempt in range(self.max_retries + 1):
            try:
                if self.injector is not None:
                    self.injector.prefill(req.uid)
                return self.engine.prefill(req.prompt,
                                           seed=req.resolved_seed())
            except RuntimeError as e:
                if attempt >= self.max_retries:
                    raise
                self.log(f"[scheduler] prefill {req.uid} attempt {attempt} "
                         f"failed ({_errmsg(e)}); retrying")
                self._backoff(attempt, site="prefill", uid=req.uid)

    def _admit(self, req: Request, state, slot_req: Dict[int, Request],
               free: List[int]):
        """Prefill + insert one request; failures fail only this request
        (error outcome, slot back on the free list). A failing or
        1-token request's open ``prefill`` span is closed by
        ``_finish`` → ``_close_request``."""
        slot = free.pop()
        self._open_span(req.uid, "prefill")
        try:
            prefix, first, plen = self._prefill_with_retry(req)
        except Exception as e:          # noqa: BLE001 — isolation boundary
            self._finish(req.uid, "error", f"prefill failed: {_errmsg(e)}")
            free.append(slot)
            return state
        self.prefills += 1
        self._m_prefills.labels(mode="single").inc()
        tok = int(first)
        if self._emit(req, tok):        # 1-token request: done
            self._finish(req.uid, "ok")
            free.append(slot)
            return state
        try:
            state = self.engine.insert(state, prefix, plen, tok, slot,
                                       seed=req.resolved_seed())
        except Exception as e:          # noqa: BLE001 — isolation boundary
            self._finish(req.uid, "error", f"insert failed: {_errmsg(e)}")
            free.append(slot)
            return state
        self._close_span(req.uid, "prefill")
        self._open_span(req.uid, "decode", slot=slot)
        slot_req[slot] = req
        return state

    def _gate_with_retry(self, req: Request) -> bool:
        """Run only the injector's prefill gate for one request of a
        packed batch (the engine call is shared — per-uid faults must
        still fail per-request). Returns False (error outcome recorded)
        when the gate fails persistently."""
        if self.injector is None:
            return True
        for attempt in range(self.max_retries + 1):
            try:
                self.injector.prefill(req.uid)
                return True
            except RuntimeError as e:
                if attempt >= self.max_retries:
                    self._finish(req.uid, "error",
                                 f"prefill failed: {_errmsg(e)}")
                    return False
                self.log(f"[scheduler] prefill {req.uid} attempt {attempt} "
                         f"failed ({_errmsg(e)}); retrying")
                self._backoff(attempt, site="prefill", uid=req.uid)
        return False                     # unreachable

    def _admit_packed(self, reqs: List[Request], state,
                      slot_req: Dict[int, Request], free: List[int]):
        """Admit several requests through ONE packed batch prefill.
        Per-request isolation is preserved: the injector gate runs (and
        retries) per uid before the shared engine call; a persistent
        engine-side failure fails only the packed survivors; insert
        failures fail only their own row."""
        survivors = [r for r in reqs if self._gate_with_retry(r)]
        if not survivors:
            return state
        for r in survivors:
            self._open_span(r.uid, "prefill", packed=True)
        prompts = [r.prompt for r in survivors]
        seeds = [r.resolved_seed() for r in survivors]
        packed = None
        for attempt in range(self.max_retries + 1):
            try:
                packed, first, plens = self.engine.prefill_packed(
                    prompts, seeds)
                break
            except RuntimeError as e:
                if attempt >= self.max_retries:
                    for r in survivors:
                        self._finish(r.uid, "error",
                                     f"prefill failed: {_errmsg(e)}")
                    return state
                self.log(f"[scheduler] packed prefill ({len(survivors)} "
                         f"reqs) attempt {attempt} failed ({_errmsg(e)}); "
                         "retrying")
                self._backoff(attempt, site="prefill")
            except Exception as e:      # noqa: BLE001 — isolation boundary
                for r in survivors:
                    self._finish(r.uid, "error",
                                 f"prefill failed: {_errmsg(e)}")
                return state
        self.packed_prefills += 1
        self._m_packed_waves.inc()
        first_h = np.asarray(first)      # host sync: first-token stream
        for row, req in enumerate(survivors):
            self.prefills += 1
            self._m_prefills.labels(mode="packed").inc()
            tok = int(first_h[row])
            if self._emit(req, tok):     # 1-token request: done
                self._finish(req.uid, "ok")
                continue
            slot = free.pop()
            try:
                state = self.engine.insert_from(
                    state, packed, row, plens[row], tok, slot,
                    seed=seeds[row])
            except Exception as e:      # noqa: BLE001 — isolation boundary
                self._finish(req.uid, "error",
                             f"insert failed: {_errmsg(e)}")
                free.append(slot)
                continue
            self._close_span(req.uid, "prefill")
            self._open_span(req.uid, "decode", slot=slot)
            slot_req[slot] = req
        return state

    def _admit_batch(self, reqs: List[Request], state,
                     slot_req: Dict[int, Request], free: List[int]):
        """Route a wave of admissions: prompts on the bucket ladder go
        through the packed path together; off-ladder prompts (and a
        wave of one) use the sequential b=1 path."""
        t0 = self.clock()
        with obs_prof.annotation("prefill_wave"):
            packable: List[Request] = []
            rest: List[Request] = []
            for r in reqs:
                p = int(np.asarray(r.prompt).shape[-1])
                (packable if self.engine.bucket_for(p) is not None
                 else rest).append(r)
            if len(packable) >= 2:
                state = self._admit_packed(packable, state, slot_req, free)
            else:
                rest = reqs
            for req in rest:
                state = self._admit(req, state, slot_req, free)
        self._m_prefill_s.observe(self.clock() - t0)
        return state

    def _generate_with_retry(self, state, slot_req: Dict[int, Request],
                             free: List[int]):
        """One batched decode step with transient-fault retry. The engine
        step is pure (no donation), so a failed call leaves ``state``
        intact and the retry replays the identical step. On exhaustion
        every in-flight request gets an explicit error outcome, slots are
        released, the queue is left intact, and EngineStepError raises —
        a fresh run() serves the remainder."""
        last_err: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            try:
                if self.injector is not None:
                    bad = self.injector.decode(self.steps)
                    if bad is not None:
                        state = self.engine.poison_slot(state, bad)
                return self.engine.generate(state)
            except RuntimeError as e:
                last_err = e
                if attempt >= self.max_retries:
                    break
                self.log(f"[scheduler] decode step {self.steps} attempt "
                         f"{attempt} failed ({_errmsg(e)}); retrying")
                self._backoff(attempt, site="decode")
        for slot in sorted(slot_req):
            req = slot_req[slot]
            self._finish(req.uid, "error",
                         f"engine step failed: {_errmsg(last_err)}")
            state = self.engine.release(state, slot)
            free.append(slot)
        slot_req.clear()
        raise EngineStepError(
            f"decode step {self.steps} failed after "
            f"{self.max_retries + 1} attempts") from last_err

    # ----------------------------------------------------------- snapshot
    def _snapshot(self, state, slot_req: Dict[int, Request],
                  free: List[int], *, final: bool = False):
        """Best-effort: a failing snapshot write is counted and logged,
        never fatal to serving (the previous committed snapshot stays
        valid — manifest saves are atomic)."""
        if self.snapshot_dir is None:
            return
        from repro.serving_engine import snapshot as snap
        # settle in-flight callbacks first: a snapshot must capture
        # callback_error/detach outcomes that are already "emitted"
        self._drain_detok()
        t0 = self.clock()
        self._tb("snapshot", step=self.steps, final=final)
        result = "ok"
        try:
            if self.injector is not None:
                self.injector.snapshot(self.steps)
            snap.save_snapshot(self.snapshot_dir, self, state, slot_req,
                               free, metrics=self.metrics)
        except Exception as e:          # noqa: BLE001 — isolation boundary
            result = "error"
            self.snapshot_errors += 1
            self.log(f"[scheduler] snapshot"
                     f"{' (final)' if final else ''} failed: {_errmsg(e)}")
        self._m_snapshots.labels(result=result).inc()
        self._m_snap_s.observe(self.clock() - t0)
        self._te("snapshot", result=result)

    def try_restore(self, *, callbacks: Optional[Dict] = None) -> bool:
        """Load the latest committed snapshot from ``snapshot_dir`` into
        this (fresh) scheduler; the next ``run()`` resumes token-exact.
        ``callbacks`` re-attaches ``on_token`` closures by uid (they
        cannot be serialized). Returns False when there is no snapshot."""
        from repro.serving_engine import snapshot as snap
        if self.snapshot_dir is None:
            return False
        loaded = snap.load_snapshot(self.snapshot_dir, self.engine)
        if loaded is None:
            return False
        extra = loaded["extra"]
        self.steps = int(extra["steps"])
        self.prefills = int(extra["prefills"])
        self.results = {uid: [int(t) for t in toks]
                        for uid, toks in extra["results"].items()}
        self.outcomes = {}
        for uid, o in extra["outcomes"].items():
            self.outcomes[uid] = Outcome(
                uid=uid, status=o["status"],
                tokens=self.results.setdefault(uid, []),
                error=o["error"], callback_error=o["callback_error"])
        now = self.clock()
        self._deadlines = {uid: now + float(rem)
                           for uid, rem in extra["deadline_remaining"].items()}
        with self._not_full:
            self.queue = deque(snap.meta_request(m, callbacks)
                               for m in extra["queue"])
        slot_req = {int(slot): snap.meta_request(m, callbacks)
                    for slot, m in extra["slot_req"]}
        self._resume = {
            "state": loaded["state"],
            "slot_req": slot_req,
            "free": [int(s) for s in extra["free"]],
        }
        self.log(f"[scheduler] restored snapshot at step {self.steps}: "
                 f"{len(slot_req)} in-flight, {len(self.queue)} queued")
        return True

    # --------------------------------------------------------------- run
    def run(self, state=None, *, stop: Optional[Callable[[], bool]] = None,
            idle_sleep: float = 0.002):
        """Drain the queue; returns ({uid: [generated tokens]}, state).
        Reentrant: pass the returned state back in to keep serving. When
        preempted (SIGTERM/SIGINT or :meth:`preempt`) it snapshots and
        returns early with ``self.preempted`` set. With ``stop`` given,
        an empty queue idles (sleeping ``idle_sleep`` between polls)
        instead of returning, until ``stop()`` is truthy — the
        online-serving mode used by the latency benchmark's open-loop
        arrival process."""
        eng = self.engine
        resume, self._resume = self._resume, None
        if resume is not None:
            if state is None:
                state = resume["state"]
            free = resume["free"]
            slot_req = resume["slot_req"]
        else:
            if state is None:
                state = eng.init_state()
            free = list(range(eng.slots))[::-1]  # pop() admits slot 0 first
            slot_req = {}
        self.preempted = False
        # per-drain cache for sample_memory's pytree byte sums: the
        # decode cache is fixed-shape for the whole drain, so only the
        # live-array total is re-measured at each sampling step
        self._mem_reuse: dict = {}
        self._install_signals()
        if self.detok_async and self._detok is None:
            self._detok = _DetokWorker(self, self.detok_cap)
            self._detok.start()
        self._ensure_request_spans(slot_req)
        prof = obs_prof.session("serve")     # no-op unless REPRO_PROFILE_DIR
        prof.__enter__()
        try:
            while True:
                with self._lock:
                    has_queue = bool(self.queue)
                if self.preempted:
                    break
                if not (has_queue or slot_req):
                    if stop is None or stop():
                        break
                    self.sleep(idle_sleep)           # idle: await arrivals
                    continue
                if self._deadlines:
                    # callbacks may advance an injected clock — settle
                    # them before the watchdog reads it
                    self._drain_detok()
                now = self.clock()
                self._expire_queue(now)              # watchdog: queue TTLs
                state = self._expire_slots(now, state, slot_req, free)
                if free:                             # greedy prefill-first
                    wave = self._pop_up_to(min(len(free),
                                               self.prefill_pack))
                    if wave:
                        state = self._admit_batch(wave, state, slot_req,
                                                  free)
                        continue
                if not slot_req:
                    continue     # everything expired/errored; re-check queue
                t_step = self.clock()
                self._tb("step", step=self.steps)
                try:
                    with obs_prof.annotation("decode_step"):
                        state, toks, ok = self._generate_with_retry(
                            state, slot_req, free)
                    self.steps += 1
                    self._m_steps.inc()
                    toks_h = np.asarray(toks)   # host sync: stream point
                    ok_h = np.asarray(ok)
                finally:
                    # close the step span on EngineStepError too — a
                    # persistent decode failure must not dangle spans
                    self._m_step_s.observe(self.clock() - t_step)
                    self._te("step")
                for slot in sorted(slot_req):
                    req = slot_req[slot]
                    if not ok_h[slot]:
                        # quarantined on device; recycle the slot
                        self._ti("quarantine", req.uid, slot=slot,
                                 step=self.steps - 1)
                        self._m_evictions.labels(reason="nonfinite").inc()
                        self._finish(
                            req.uid, "error",
                            f"non-finite logits at step {self.steps - 1} "
                            f"(slot {slot} quarantined after "
                            f"{len(self.results[req.uid])} tokens)")
                        self.evictions += 1
                        state = eng.release(state, slot)
                        del slot_req[slot]
                        free.append(slot)
                        continue
                    if self._emit(req, int(toks_h[slot])):
                        self._finish(req.uid, "ok")
                        state = eng.release(state, slot)
                        del slot_req[slot]
                        free.append(slot)
                self._observe_counters(len(slot_req))
                if (self.mem_sample_every
                        and self.steps % self.mem_sample_every == 0):
                    from repro.obs import devstats as obs_devstats
                    obs_devstats.sample_memory(self.metrics, state,
                                               reuse=self._mem_reuse)
                if (self.snapshot_every and not self.preempted
                        and self.steps % self.snapshot_every == 0):
                    self._snapshot(state, slot_req, free)
            if self.preempted:
                self._snapshot(state, slot_req, free, final=True)
                # close every open span with a preempted terminus so the
                # trace of this run validates; a later run (or a restore
                # in a new process) re-begins them with resumed=True
                for uid in sorted(self._span_open):
                    self._ti("preempt", uid)
                    self._close_request(uid, "preempted")
        finally:
            if self._detok is not None:
                # settle every in-flight callback before handing results
                # back (streamed == recorded is a PR 6 observable)
                self._detok.drain()
                self._detok.stop()
                self._detok = None
            self._restore_signals()
            prof.__exit__(None, None, None)
            if self.tracer is not None:
                self.tracer.flush()
        return self.results, state
