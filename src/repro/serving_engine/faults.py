"""Deterministic fault injection for the serving engine (chaos harness).

The scheduler calls an injector at its four host-side boundaries —
prefill, decode step, token callback, snapshot write — and the injector
either does nothing, raises :class:`InjectedFault` (a ``RuntimeError``,
so the scheduler's transient-retry machinery sees it exactly like a real
step failure), or, at the decode site, names a slot whose cache row the
engine poisons with NaN so the non-finite guard is exercised end to end
through the real quarantine path rather than a mocked one.

Two modes, freely combined:

* **scripted** — a list of :class:`FaultSpec`; each spec counts its own
  matching visits (site, optionally restricted to one request uid) and
  fires for ``count`` consecutive matches starting at visit ``at``.
  ``count=1`` is a transient fault (one retry survives it); a large
  ``count`` is a persistent fault (retries exhaust, the request or step
  fails for real).
* **seeded** — per-site firing ``rates`` drawn from
  ``np.random.default_rng(seed)`` in visit order: the same seed and the
  same visit sequence always produce the same fault schedule, so a
  seeded chaos run is exactly reproducible.

Every decision is appended to ``self.log`` as ``(site, visit, action,
detail)`` for post-mortem assertions in tests. When the scheduler calls
:meth:`~FaultInjector.bind` with its obs registry/tracer (ISSUE 9), every
firing also increments ``repro_faults_injected_total{site,action,spec}``
and lands in the request trace as a ``fault`` instant tagged with the
site, action, and originating spec — chaos runs are attributable
per-request in the Perfetto timeline.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

SITES = ("prefill", "decode", "callback", "snapshot")


class InjectedFault(RuntimeError):
    """A fault deliberately raised by :class:`FaultInjector`."""


@dataclasses.dataclass
class FaultSpec:
    """One scripted fault: fire on matching visits [at, at + count)."""
    site: str                          # prefill | decode | callback | snapshot
    at: int = 0                        # first matching visit that fires
    uid: Optional[str] = None          # restrict to one request (prefill/callback)
    count: int = 1                     # consecutive firings (1 = transient)
    poison_slot: Optional[int] = None  # decode only: NaN-poison this slot
                                       # instead of raising

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"expected one of {SITES}")
        if self.poison_slot is not None and self.site != "decode":
            raise ValueError("poison_slot is only meaningful at the "
                             "'decode' site")
        if self.count < 1:
            raise ValueError(f"count={self.count} must be >= 1")


class FaultInjector:
    def __init__(self, specs: Sequence[FaultSpec] = (), *,
                 seed: Optional[int] = None,
                 rates: Optional[Dict[str, float]] = None):
        self.specs = list(specs)
        self.rates = dict(rates or {})
        for site in self.rates:
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r} in rates")
        if self.rates and seed is None:
            raise ValueError("seeded mode (rates=...) requires a seed — "
                             "chaos runs must be reproducible")
        self._rng = np.random.default_rng(seed)
        self._hits: List[int] = [0] * len(self.specs)   # per-spec match count
        self._visits: Dict[str, int] = {s: 0 for s in SITES}
        self.fired = 0
        self.log: List[Tuple[str, int, str, str]] = []
        self._tracer = None
        self._m_fired = None
        # the scheduler's detokenise worker hits the callback site from
        # its own thread while the loop thread hits prefill/decode —
        # serialise counter/rng mutation so schedules stay deterministic
        # per site (visit order within a site is still FIFO)
        self._mutex = threading.Lock()

    # ------------------------------------------------------- observability
    def bind(self, metrics=None, tracer=None) -> None:
        """Attach an obs registry / span tracer (the Scheduler calls this
        at construction). Idempotent; either argument may be None."""
        self._tracer = tracer
        if metrics is not None:
            self._m_fired = metrics.counter(
                "repro_faults_injected_total",
                "chaos injector firings", ("site", "action", "spec"))

    def _record(self, site: str, uid: Optional[str], action, spec: str):
        if self._m_fired is not None:
            self._m_fired.labels(site=site, action=action[0],
                                 spec=spec).inc()
        if self._tracer is not None:
            self._tracer.instant("fault", uid, site=site,
                                 action=action[0], spec=spec,
                                 detail=str(action[1]))

    # ------------------------------------------------------------ matching
    def _decide(self, site: str, uid: Optional[str] = None):
        """Returns None, ("raise", msg) or ("poison", slot)."""
        with self._mutex:
            return self._decide_locked(site, uid)

    def _decide_locked(self, site: str, uid: Optional[str] = None):
        visit = self._visits[site]
        self._visits[site] += 1
        action = None
        spec_label = ""
        for i, sp in enumerate(self.specs):
            if sp.site != site or (sp.uid is not None and sp.uid != uid):
                continue
            hit = self._hits[i]
            self._hits[i] += 1
            if action is None and sp.at <= hit < sp.at + sp.count:
                spec_label = f"spec{i}"
                if sp.poison_slot is not None:
                    action = ("poison", sp.poison_slot)
                else:
                    action = ("raise",
                              f"scripted {site} fault (spec {i}, hit {hit})")
        rate = self.rates.get(site, 0.0)
        if rate > 0.0:
            # always draw, even when a scripted spec already fired: the
            # random stream advances once per visit so the schedule only
            # depends on (seed, visit order), never on the scripted plan
            drawn = self._rng.random() < rate
            if action is None and drawn:
                spec_label = "seeded"
                action = ("raise", f"seeded {site} fault (visit {visit})")
        if action is not None:
            self.fired += 1
            self.log.append((site, visit, action[0], str(action[1])))
            self._record(site, uid, action, spec_label)
        return action

    # --------------------------------------------------------------- sites
    def prefill(self, uid: str) -> None:
        act = self._decide("prefill", uid)
        if act is not None:
            raise InjectedFault(f"{act[1]} [uid={uid}]")

    def decode(self, step: int) -> Optional[int]:
        """May raise (transient/persistent step fault) or return a slot
        index for the engine to NaN-poison (non-finite injection)."""
        act = self._decide("decode")
        if act is None:
            return None
        if act[0] == "poison":
            return int(act[1])
        raise InjectedFault(f"{act[1]} [step={step}]")

    def callback(self, uid: str) -> None:
        act = self._decide("callback", uid)
        if act is not None:
            raise InjectedFault(f"{act[1]} [uid={uid}]")

    def snapshot(self, step: int) -> None:
        act = self._decide("snapshot")
        if act is not None:
            raise InjectedFault(f"{act[1]} [step={step}]")
