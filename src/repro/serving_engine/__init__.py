"""Continuous-batching TNN serving engine (PR 5) + fault tolerance (PR 6).

Slot-based decode state + prefill→insert→generate loop over the ragged
(per-slot cur_len) decode path of models/serving.py — see state.py /
engine.py / scheduler.py and README "Serving engine". PR 6 adds the
serving supervisor: request-level error isolation with retry/backoff,
deadlines + bounded-queue backpressure, a non-finite guard with slot
quarantine, engine snapshot/restore for preemption, and a deterministic
FaultInjector chaos harness (faults.py / snapshot.py, README "Fault
tolerance").
"""
from repro.serving_engine.engine import Engine, default_slots
from repro.serving_engine.faults import FaultInjector, FaultSpec, InjectedFault
from repro.serving_engine.scheduler import (EngineStepError, Outcome,
                                            QueueFull, Request, Scheduler)
from repro.serving_engine.snapshot import load_snapshot, save_snapshot
from repro.serving_engine.state import (DecodeState, init_decode_state,
                                        insert, insert_prefix_cache, poison,
                                        release)

__all__ = [
    "Engine", "default_slots", "Request", "Scheduler", "Outcome",
    "QueueFull", "EngineStepError", "FaultInjector", "FaultSpec",
    "InjectedFault", "load_snapshot", "save_snapshot", "DecodeState",
    "init_decode_state", "insert", "insert_prefix_cache", "poison",
    "release",
]
