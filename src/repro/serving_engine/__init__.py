"""Continuous-batching TNN serving engine — see docs/serving.md.

Slot-based decode state + prefill→insert→generate loop over the ragged
(per-slot cur_len) decode path of models/serving.py (PR 5: state.py /
engine.py / scheduler.py). PR 6 adds the serving supervisor:
request-level error isolation with retry/backoff, deadlines +
bounded-queue backpressure, a non-finite guard with slot quarantine,
engine snapshot/restore for preemption, and a deterministic
FaultInjector chaos harness (faults.py / snapshot.py). PR 7 adds the
production front-end: length-bucketed prefill executables, packed batch
admission scattered through insert_from, per-slot PRNG lanes for
temperature/top-k sampling, and an async detokenise worker off the
decode hot loop.
"""
from repro.serving_engine.engine import Engine, default_slots
from repro.serving_engine.faults import FaultInjector, FaultSpec, InjectedFault
from repro.serving_engine.scheduler import (EngineStepError, Outcome,
                                            QueueFull, Request, Scheduler,
                                            default_detok_async,
                                            default_prefill_pack)
from repro.serving_engine.snapshot import load_snapshot, save_snapshot
from repro.serving_engine.state import (DecodeState, init_decode_state,
                                        insert, insert_prefix_cache, poison,
                                        release, select_rows, take_row)

__all__ = [
    "Engine", "default_slots", "Request", "Scheduler", "Outcome",
    "QueueFull", "EngineStepError", "FaultInjector", "FaultSpec",
    "InjectedFault", "load_snapshot", "save_snapshot", "DecodeState",
    "init_decode_state", "insert", "insert_prefix_cache", "poison",
    "release", "select_rows", "take_row", "default_prefill_pack",
    "default_detok_async",
]
