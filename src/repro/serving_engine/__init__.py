"""Continuous-batching TNN serving engine (PR 5).

Slot-based decode state + prefill→insert→generate loop over the ragged
(per-slot cur_len) decode path of models/serving.py — see state.py /
engine.py / scheduler.py and README "Serving engine".
"""
from repro.serving_engine.engine import Engine, default_slots
from repro.serving_engine.scheduler import Request, Scheduler
from repro.serving_engine.state import (DecodeState, init_decode_state,
                                        insert, insert_prefix_cache, release)

__all__ = [
    "Engine", "default_slots", "Request", "Scheduler", "DecodeState",
    "init_decode_state", "insert", "insert_prefix_cache", "release",
]
