"""Continuous-batching inference engine: prefill → insert → generate.

The device-side half of the serving engine (the host-side queue lives in
:mod:`repro.serving_engine.scheduler`). Jit-stable functions over a
:class:`~repro.serving_engine.state.DecodeState` of S slots:

* ``prefill(prompt)`` — run one request's prompt through a **batch-1**
  cache and return ``(prefix_cache, first_token, prompt_len)``. The
  prompt is padded up to a geometric **length bucket** and driven
  through one cached executable per (batch, bucket) pair — a masked
  ``lax.scan`` over chunk/token steps — so serving traffic with ragged
  prompt lengths compiles O(log max_len) prefill programs instead of
  one per distinct length (the MaxText offline-inference shape). FD
  streaming archs consume whole C-token blocks through the overlap-save
  machinery (serving.decode_chunk — PR 4's chunked prefill); the
  remainder, and every other mixer family, is teacher-forced
  token-by-token. Exactly the math of the solo ``launch/serve.generate``
  prefill, so engine output is token-exact against solo decode.
* ``prefill_packed(prompts)`` — the batched variant: pack several
  queued prompts into ONE padded prefill batch (same masked-scan
  executable at batch P), returning a packed cache whose rows
  ``insert_from`` scatters into slots. Greedy packed prefill is
  token-exact vs sequential b=1 prefill (per-row masking + the row-wise
  bitwise stability of batched XLA ops that the whole engine parity
  contract already rests on).
* ``insert(state, prefix, plen, token, slot)`` / ``insert_from(state,
  packed, row, plen, token, slot)`` — tree-map slice-in of a prefix
  cache (or one row of a packed prefill batch) into a free slot without
  touching other slots' rows.
* ``generate(state)`` — ONE batched masked decode_step over all S slots
  at their per-slot positions; advances only active slots. With
  ``temperature == 0`` (default) each slot's next token is the argmax;
  with ``temperature > 0`` it is drawn from the temperature/top-k
  distribution using the slot's private PRNG lane (``DecodeState.rng``),
  seeded at insert from the request seed and split once per advancing
  step — sampled streams are seeded-reproducible and independent of
  slot placement, and the T=0 path is literally the greedy code. With
  the (default-on) non-finite guard it also returns a per-slot ``ok``
  mask and **quarantines** bad slots at the device level: a slot whose
  logits went non-finite (SDC, a poisoned request, an overflowed bf16
  path) is frozen — its position/token do not advance and its active
  bit drops — so garbage is never fed back, and the host scheduler
  records an error outcome and recycles the slot (the next insert
  overwrites the whole row). Mirrors the trainer's NaN guard on the
  serving side.

jit-stability contract: at fixed S, the decode loop never retraces
across steps, inserts, or evictions — positions/slot indices/tokens are
traced scalars and vectors, shapes depend only on (S, max_len, C); the
prefill path traces once per (batch, bucket) pair. ``trace_counts``
exposes the per-function trace counters the contract tests pin. Slot
count defaults to ``REPRO_ENGINE_SLOTS`` (8); ``REPRO_PREFILL_BUCKET0``
(16) sets the smallest bucket and ``REPRO_PREFILL_BUCKETS=0`` falls
back to the PR 5 per-length chunk/token host loop.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import serving
from repro.models.config import ArchConfig
from repro.models.context import Ctx
from repro.serving_engine import state as st

_ENV_SLOTS = "REPRO_ENGINE_SLOTS"
_ENV_BUCKET0 = "REPRO_PREFILL_BUCKET0"
_ENV_BUCKETS = "REPRO_PREFILL_BUCKETS"


def default_slots() -> int:
    v = os.environ.get(_ENV_SLOTS)
    if v is None or v == "":
        return 8
    s = int(v)
    if s < 1:
        raise ValueError(f"{_ENV_SLOTS}={s} must be >= 1")
    return s


def _env_flag(name: str, default: bool) -> bool:
    v = os.environ.get(name)
    if v is None or v == "":
        return default
    return v.strip().lower() not in ("0", "false", "off", "no")


class Engine:
    """Bind (cfg, params, S slots, max_len) and build the jitted step
    functions once. ``temperature == 0`` (default) decodes greedily —
    the parity contract against solo decode is token-exactness;
    ``temperature > 0`` samples per slot from private PRNG lanes
    (optionally top-k truncated)."""

    def __init__(self, cfg: ArchConfig, params, *, slots: int | None = None,
                 max_len: int = 256, ctx: Ctx | None = None, dtype=None,
                 guard_nonfinite: bool = True,
                 temperature: float = 0.0, top_k: int = 0,
                 bucket0: int | None = None,
                 use_buckets: bool | None = None,
                 metrics=None):
        if cfg.kind != "decoder":
            raise NotImplementedError(
                f"serving engine supports decoder archs, got {cfg.kind}")
        if temperature < 0:
            raise ValueError(f"temperature={temperature} must be >= 0")
        if top_k < 0:
            raise ValueError(f"top_k={top_k} must be >= 0")
        self.cfg = cfg
        self.params = params
        self.slots = default_slots() if slots is None else int(slots)
        if self.slots < 1:
            # a 0-slot engine would make the scheduler spin forever on an
            # empty batch instead of ever draining the queue
            raise ValueError(f"slots={self.slots} must be >= 1")
        self.max_len = int(max_len)
        self.guard_nonfinite = bool(guard_nonfinite)
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.ctx = ctx or Ctx(decode=True)
        self.dtype = dtype
        # one reusable batch-1 prefix template: constants (stream kernel
        # spectra, kcoef taps) are realised once, not per request
        self._prefix_template = serving.init_cache(
            cfg, 1, self.max_len, dtype, params=params)
        cap = serving.cache_capacity(self._prefix_template)
        self.capacity = cap          # None = length-unbounded (pure mamba)
        self._chunk_c = (serving.stream_block_of(self._prefix_template)
                         if serving.supports_chunked_prefill(
                             cfg, self._prefix_template) else None)
        self.use_buckets = (_env_flag(_ENV_BUCKETS, True)
                            if use_buckets is None else bool(use_buckets))
        if bucket0 is None:
            bucket0 = int(os.environ.get(_ENV_BUCKET0) or 16)
        self.buckets = self._bucket_ladder(int(bucket0))
        self._templates = {1: self._prefix_template}  # batch → packed tmpl
        # ``trace_counts`` is the test-pinned retrace observable; under an
        # obs registry (explicit, or the REPRO_METRICS process default)
        # the same dict mirrors its increments into
        # ``repro_engine_traces_total{fn=...}`` — one source of truth,
        # two read paths (ISSUE 9 satellite a)
        from repro.obs import metrics as obs_metrics
        reg = metrics if metrics is not None else obs_metrics.default_registry()
        self.metrics = reg
        initial = {"generate": 0, "insert": 0, "insert_from": 0,
                   "decode1": 0, "chunk1": 0, "prefill_bucket": 0}
        if isinstance(reg, obs_metrics.NullRegistry):
            self.trace_counts = dict(initial)
        else:
            self.trace_counts = obs_metrics.MirroredCounts(
                initial,
                reg.counter("repro_engine_traces_total",
                            "jitted engine fn retraces (trace_counts)",
                            ("fn",)),
                "fn")
        # every jit entry point runs under the compile watchdog (ISSUE 10
        # tentpole §3): fresh traces land in repro_compiles_total{fn} + a
        # compile-seconds histogram, and exceeding the declared shape
        # family warns. The `_make` counted wrappers still fire on the
        # same traces, so `trace_counts` stays the test-pinned mirror.
        from repro.obs import compilewatch as obs_compile
        w = self.compile_watch = obs_compile.CompileWatch(
            metrics=reg, prefix="engine.")
        self._generate = w.wrap(
            "generate", self._make("generate", self._generate_fn))
        self._insert = w.wrap(
            "insert", self._make("insert", self._insert_fn))
        self._insert_from = w.wrap(
            "insert_from", self._make("insert_from", self._insert_from_fn))
        self._decode1 = w.wrap(
            "decode1", self._make("decode1", self._decode1_fn))
        self._chunk1 = (w.wrap("chunk1",
                               self._make("chunk1", self._chunk1_fn))
                        if self._chunk_c else None)
        # n_tok (the token-remainder phase length) is static: the
        # C-aligned fast path (n_tok=0, whole-chunk prompts) and the
        # general path (n_tok=C) are separate executables — at most two
        # per (batch, bucket) pair
        self._prefill_bucket = w.wrap(
            "prefill_bucket",
            self._make("prefill_bucket", self._prefill_bucket_fn),
            static_argnums=(5,))
        # retrace budgets: decode1/generate batch over all S slots (one
        # executable each; 2 allows a dtype/donation variant), packed
        # prefill ≤ 2 executables per (batch, bucket). insert/chunk1
        # legitimately trace per prompt length on the unbucketed path,
        # so they are counted but not budgeted.
        w.expect("generate", 2)
        w.expect("decode1", 2)
        w.expect("prefill_bucket",
                 2 * max(len(self.buckets), 1) * max(self.slots, 1))

    # ------------------------------------------------------------ plumbing
    def _make(self, name, fn):
        def counted(*args):
            self.trace_counts[name] += 1
            return fn(*args)
        return counted

    def _bucket_ladder(self, b0: int):
        """Geometric prompt-length buckets b0, 2·b0, … up to capacity.
        For streaming archs every rung is a multiple of the block size C,
        so the packed prefill's chunk phase stays on C-boundaries; the
        top rung rounds capacity UP to a C-multiple — masked rows may
        compute past capacity but those writes are merge-discarded."""
        if b0 < 1:
            raise ValueError(f"prefill bucket0={b0} must be >= 1")
        c = self._chunk_c or 1
        b0 = ((max(b0, c) + c - 1) // c) * c
        cap = self.capacity if self.capacity is not None else self.max_len
        top = ((max(cap, b0) + c - 1) // c) * c
        ladder = []
        b = b0
        while b < top:
            ladder.append(b)
            b *= 2
        ladder.append(top)
        return ladder

    def bucket_for(self, p: int) -> int | None:
        """Smallest bucket holding a p-token prompt (None = off-ladder:
        bucketing disabled, or p beyond the top rung on a
        length-unbounded arch — both fall back to the per-length loop)."""
        if not self.use_buckets:
            return None
        for b in self.buckets:
            if p <= b:
                return b
        return None

    def _template_for(self, batch: int):
        if batch not in self._templates:
            self._templates[batch] = serving.init_cache(
                self.cfg, batch, self.max_len, self.dtype,
                params=self.params)
        return self._templates[batch]

    def _pick_last(self, last):
        """Greedy token per row from last-position logits (b, V_pad)."""
        nxt = jnp.argmax(last, axis=-1)
        return jnp.minimum(nxt, self.cfg.vocab - 1).astype(jnp.int32)

    def _pick(self, logits):
        return self._pick_last(logits[:, -1])

    def _sample_last(self, last, keys):
        """Temperature/top-k sample per row: last (b, V_pad) logits,
        keys (b, 2) uint32 — one private lane per row."""
        logits = last.astype(jnp.float32) / self.temperature
        ids = jnp.arange(last.shape[-1])
        logits = jnp.where(ids < self.cfg.vocab, logits, -jnp.inf)
        if 0 < self.top_k < self.cfg.vocab:
            kth = jax.lax.top_k(logits, self.top_k)[0][..., -1:]
            logits = jnp.where(logits >= kth, logits, -jnp.inf)
        nxt = jax.vmap(jax.random.categorical)(keys, logits)
        return nxt.astype(jnp.int32)

    def _first_candidate(self, last, kfirst):
        """Next-token candidate during prefill: sampled from the
        request's first-token key lane when sampling, else argmax."""
        if self.temperature > 0:
            return self._sample_last(last, kfirst)
        return self._pick_last(last)

    @staticmethod
    def _seed_keys(seeds):
        """(b,) int32 seeds → (kslot, kfirst), each (b, 2) uint32. Both
        lanes derive from the seed alone, so prefill (kfirst) and insert
        (kslot) recompute them independently without passing keys through
        the host API."""
        base = jax.vmap(jax.random.PRNGKey)(seeds)
        ks = jax.vmap(jax.random.split)(base)
        return ks[:, 0], ks[:, 1]

    # ------------------------------------------------------- traced bodies
    def _decode1_fn(self, params, tok, cache, pos):
        return serving.decode_step(params, self.cfg, self.ctx,
                                   {"tokens": tok}, cache, pos)

    def _chunk1_fn(self, params, tok, cache, pos):
        return serving.decode_chunk(params, self.cfg, self.ctx,
                                    {"tokens": tok}, cache, pos)

    def _insert_fn(self, state, prefix, slot, plen, token, seed):
        kslot, _ = self._seed_keys(seed[None])
        return st.insert(state, prefix, slot, plen, token, key=kslot[0])

    def _insert_from_fn(self, state, packed, row, slot, plen, token, seed):
        prefix = st.take_row(packed, row)
        kslot, _ = self._seed_keys(seed[None])
        return st.insert(state, prefix, slot, plen, token, key=kslot[0])

    def _n_tok_for(self, bucket: int, plens) -> int:
        """Static token-remainder phase length for a packed prefill:
        streaming archs need C catch-up steps only when some prompt is
        not chunk-aligned (0 when all are — the fast path); non-stream
        archs teacher-force the whole bucket."""
        c = self._chunk_c
        if c and bucket % c == 0:
            return 0 if all(p % c == 0 for p in plens) else c
        return bucket

    def _prefill_bucket_fn(self, params, cache, prompts, plens, seeds,
                           n_tok):
        """Packed bucketed prefill: prompts (B, Lb) padded to bucket Lb,
        plens (B,) true lengths (0 = dead pad row). One masked lax.scan
        executable per (B, Lb, n_tok): streaming archs run Lb//C
        whole-chunk steps then ``n_tok`` (≤C) per-row remainder tokens;
        everything else teacher-forces all Lb positions. Rows merge
        their cache only while a step is inside their own prompt
        (state.select_rows), so each row's final cache — and its greedy
        first token — is bit-identical to a b=1 prefill of that prompt
        alone."""
        B, Lb = prompts.shape
        _, kfirst = self._seed_keys(seeds)
        first = jnp.zeros((B,), jnp.int32)
        c = self._chunk_c
        if c and Lb % c == 0:
            nb = Lb // c

            def chunk_body(carry, k):
                cache, first = carry
                tok = jax.lax.dynamic_slice(
                    prompts, (jnp.int32(0), k * c), (B, c))
                logits, new = serving.decode_chunk(
                    params, self.cfg, self.ctx, {"tokens": tok}, cache,
                    k * c)
                take = (k + 1) * c <= plens
                cache = st.select_rows(take, new, cache)
                cand = self._first_candidate(logits[:, -1], kfirst)
                first = jnp.where((k + 1) * c == plens, cand, first)
                return (cache, first), None

            (cache, first), _ = jax.lax.scan(
                chunk_body, (cache, first), jnp.arange(nb, dtype=jnp.int32))
            base = (plens // c) * c
        else:
            base = jnp.zeros_like(plens)
        if n_tok == 0:
            return cache, first

        def tok_body(carry, t):
            cache, first = carry
            pos = base + t
            take = pos < plens
            # finished/pad rows park at position 0 like the generate
            # step's inactive slots — never on a stream-block boundary
            # refresh, and their writes are merge-discarded anyway
            pos_safe = jnp.where(take, pos, 0)
            idx = jnp.clip(pos, 0, Lb - 1)
            tok = jnp.take_along_axis(prompts, idx[:, None], axis=1)
            logits, new = serving.decode_step(
                params, self.cfg, self.ctx, {"tokens": tok}, cache,
                pos_safe)
            cache = st.select_rows(take, new, cache)
            cand = self._first_candidate(logits[:, -1], kfirst)
            first = jnp.where(pos == plens - 1, cand, first)
            return (cache, first), None

        (cache, first), _ = jax.lax.scan(
            tok_body, (cache, first), jnp.arange(n_tok, dtype=jnp.int32))
        return cache, first

    def _generate_fn(self, params, state):
        # inactive slots step at position 0 with a pad token: harmless
        # writes into scratch rows (the next insert overwrites the whole
        # row) and — deliberately — never on a stream-block boundary, so
        # parked slots cannot trigger the FD tail refresh
        cur = jnp.where(state.active, state.cur_len, 0)
        toks = jnp.where(state.active, state.tokens, 0)[:, None]
        logits, cache = serving.decode_step(
            params, self.cfg, self.ctx, {"tokens": toks}, state.cache, cur)
        last = logits[:, -1]
        if self.temperature > 0:
            # split each slot's private lane; parked/frozen slots keep
            # their key (only advancing slots consume randomness, so a
            # snapshot-resumed run replays the identical stream)
            pair = jax.vmap(jax.random.split)(state.rng)
            new_keys, sub = pair[:, 0], pair[:, 1]
            nxt = self._sample_last(last, sub)
        else:
            new_keys = state.rng
            nxt = self._pick_last(last)
        if self.guard_nonfinite:
            # parked slots decode scratch rows (possibly a quarantined
            # slot's NaN remnants) — only active slots can be flagged
            row_ok = jnp.all(jnp.isfinite(last), axis=-1)
            ok = jnp.where(state.active, row_ok, True)
        else:
            ok = jnp.ones((state.slots,), bool)
        # quarantine: a flagged slot neither advances nor stays active,
        # so its garbage token is never fed back on the next step
        advance = state.active & ok
        new_state = st.DecodeState(
            cache=cache,
            cur_len=jnp.where(advance, state.cur_len + 1, state.cur_len),
            tokens=jnp.where(advance, nxt, state.tokens),
            active=advance,
            rng=jnp.where(advance[:, None], new_keys, state.rng),
        )
        return new_state, nxt, ok

    # -------------------------------------------------------------- public
    def init_state(self) -> st.DecodeState:
        return st.init_decode_state(self.cfg, self.params, self.slots,
                                    self.max_len, self.dtype)

    def _check_prompt_len(self, p: int):
        if p < 1:
            raise ValueError("empty prompt")
        if self.capacity is not None and p > self.capacity:
            raise ValueError(
                f"prompt length {p} exceeds slot capacity "
                f"{self.capacity} (cache max_len {self.max_len}); "
                "raise Engine(max_len=...) or reject the request")

    def _prefill_loop(self, prompt, seed: int):
        """PR 5 per-length fallback: whole C-blocks then token-by-token
        on a batch-1 cache (one decode1/chunk1 trace, but a distinct
        XLA *launch sequence* per prompt length)."""
        p = prompt.shape[1]
        cache = self._prefix_template
        pos = 0
        logits = None
        if self._chunk_c:
            c = self._chunk_c
            while pos + c <= p:
                logits, cache = self._chunk1(
                    self.params, prompt[:, pos:pos + c], cache,
                    jnp.int32(pos))
                pos += c
        while pos < p:
            logits, cache = self._decode1(
                self.params, prompt[:, pos:pos + 1], cache, jnp.int32(pos))
            pos += 1
        if self.temperature > 0:
            _, kfirst = self._seed_keys(jnp.asarray([seed], jnp.int32))
            first = self._sample_last(logits[:, -1], kfirst)[0]
        else:
            first = self._pick(logits)[0]
        return cache, first, p

    def prefill(self, prompt, seed: int = 0):
        """prompt: (p,) or (1, p) int tokens. Returns (prefix_cache,
        first_token (device scalar), prompt_len). The prompt is padded to
        its length bucket and run through the cached (batch=1, bucket)
        executable; off-ladder lengths use the per-length loop. ``seed``
        only matters when the engine samples (temperature > 0): it
        derives the request's first-token key and must match the seed
        later passed to ``insert``. Raises when the prompt alone exceeds
        the slot capacity (an oversized insert would clamp the cache
        writes and silently corrupt the ring/KV rows)."""
        prompt = np.asarray(prompt, np.int32).reshape(1, -1)
        p = prompt.shape[1]
        self._check_prompt_len(p)
        bucket = self.bucket_for(p)
        if bucket is None:
            return self._prefill_loop(jnp.asarray(prompt), seed)
        # pad on the host: ONE device transfer per admission, not a
        # zeros + update_slice dispatch pair (admission is glue-bound)
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :p] = prompt[0]
        cache, first = self._prefill_bucket(
            self.params, self._template_for(1), jnp.asarray(padded),
            jnp.asarray([p], jnp.int32), jnp.asarray([seed], jnp.int32),
            self._n_tok_for(bucket, [p]))
        return cache, first[0], p

    def prefill_packed(self, prompts, seeds=None):
        """Pack several prompts into ONE padded prefill batch.

        prompts: sequence of (p_i,) int token arrays; seeds: optional
        per-prompt sampling seeds. All prompts are padded to the bucket
        of the longest and driven through a single (B, bucket)
        executable. Returns (packed_cache, first_tokens (B,) device,
        plens list) — scatter row i into a slot with :meth:`insert_from`.
        Raises when any prompt is off the bucket ladder (callers check
        :meth:`bucket_for` first and fall back to sequential prefill)."""
        B = len(prompts)
        if B < 1:
            raise ValueError("prefill_packed needs at least one prompt")
        prompts = [np.asarray(pr, np.int32).reshape(-1) for pr in prompts]
        plens = [int(pr.shape[0]) for pr in prompts]
        for p in plens:
            self._check_prompt_len(p)
        bucket = self.bucket_for(max(plens))
        if bucket is None:
            raise ValueError(
                f"prompt length {max(plens)} is off the bucket ladder "
                f"(buckets={self.buckets}, use_buckets={self.use_buckets})")
        # host-side packing: one (B, bucket) transfer per wave instead of
        # B .at[].set dispatches — the packed path's win is amortised
        # launch overhead, so its own glue has to stay thin
        padded = np.zeros((B, bucket), np.int32)
        for i, pr in enumerate(prompts):
            padded[i, :plens[i]] = pr
        if seeds is None:
            seeds = [0] * B
        cache, first = self._prefill_bucket(
            self.params, self._template_for(B), jnp.asarray(padded),
            jnp.asarray(plens, jnp.int32), jnp.asarray(seeds, jnp.int32),
            self._n_tok_for(bucket, plens))
        return cache, first, plens

    def insert(self, state, prefix_cache, plen, token, slot, seed: int = 0):
        """Admit a prefilled request into ``slot`` (traced index — no
        retrace across slots). ``seed`` must be the request's prefill
        seed: it re-derives the slot's sampling key lane."""
        return self._insert(state, prefix_cache, jnp.int32(slot),
                            jnp.int32(plen), jnp.asarray(token, jnp.int32),
                            jnp.int32(seed))

    def insert_from(self, state, packed_cache, row, plen, token, slot,
                    seed: int = 0):
        """Admit row ``row`` of a packed prefill cache into ``slot``
        (both traced — one trace per packed batch size)."""
        return self._insert_from(state, packed_cache, jnp.int32(row),
                                 jnp.int32(slot), jnp.int32(plen),
                                 jnp.asarray(token, jnp.int32),
                                 jnp.int32(seed))

    def generate(self, state):
        """One batched decode step: (state, tokens (S,), ok (S,)) — read
        tokens only for slots that were active going in AND finite
        (``ok``). A slot with ``ok=False`` has been quarantined in the
        returned state (frozen + deactivated); the caller must record
        the failure and release/recycle it."""
        return self._generate(self.params, state)

    def release(self, state, slot: int):
        return st.release(state, slot)

    def poison_slot(self, state, slot: int):
        """Chaos hook: overwrite ``slot``'s per-slot float cache rows with
        NaN so the next decode step trips the non-finite guard for that
        slot only — exercises the real quarantine path end to end."""
        return st.poison(state, slot)
