"""Continuous-batching inference engine: prefill → insert → generate.

The device-side half of the serving engine (the host-side queue lives in
:mod:`repro.serving_engine.scheduler`). Three jit-stable functions over a
:class:`~repro.serving_engine.state.DecodeState` of S slots:

* ``prefill(prompt)`` — run one request's prompt through a **batch-1**
  cache and return ``(prefix_cache, first_token, prompt_len)``. FD
  streaming archs consume the prompt in C-token blocks through the
  overlap-save machinery (serving.decode_chunk — PR 4's chunked
  prefill); the remainder, and every other mixer family, is
  teacher-forced token-by-token. Exactly the math of the solo
  ``launch/serve.generate`` prefill, so engine output is token-exact
  against solo decode.
* ``insert(state, prefix, plen, token, slot)`` — tree-map slice-in of
  the prefix cache into a free slot without touching other slots'
  rows (in-flight requests keep decoding across inserts).
* ``generate(state)`` — ONE batched masked decode_step over all S slots
  at their per-slot positions; advances only active slots, greedy-picks
  each slot's next token. With the (default-on) non-finite guard it also
  returns a per-slot ``ok`` mask and **quarantines** bad slots at the
  device level: a slot whose logits went non-finite (SDC, a poisoned
  request, an overflowed bf16 path) is frozen — its position/token do
  not advance and its active bit drops — so garbage is never fed back,
  and the host scheduler records an error outcome and recycles the slot
  (the next insert overwrites the whole row). Mirrors the trainer's NaN
  guard on the serving side.

jit-stability contract: at fixed S, the decode loop never retraces
across steps, inserts, or evictions — positions/slot indices/tokens are
traced scalars and vectors, shapes depend only on (S, max_len, C).
``trace_counts`` exposes the per-function trace counters the contract
test pins. Slot count defaults to ``REPRO_ENGINE_SLOTS`` (8).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.models import serving
from repro.models.config import ArchConfig
from repro.models.context import Ctx
from repro.serving_engine import state as st

_ENV_SLOTS = "REPRO_ENGINE_SLOTS"


def default_slots() -> int:
    v = os.environ.get(_ENV_SLOTS)
    if v is None or v == "":
        return 8
    s = int(v)
    if s < 1:
        raise ValueError(f"{_ENV_SLOTS}={s} must be >= 1")
    return s


class Engine:
    """Bind (cfg, params, S slots, max_len) and build the jitted step
    functions once. Greedy decoding (temperature 0) — the parity
    contract against solo decode is token-exactness."""

    def __init__(self, cfg: ArchConfig, params, *, slots: int | None = None,
                 max_len: int = 256, ctx: Ctx | None = None, dtype=None,
                 guard_nonfinite: bool = True):
        if cfg.kind != "decoder":
            raise NotImplementedError(
                f"serving engine supports decoder archs, got {cfg.kind}")
        self.cfg = cfg
        self.params = params
        self.slots = default_slots() if slots is None else int(slots)
        if self.slots < 1:
            # a 0-slot engine would make the scheduler spin forever on an
            # empty batch instead of ever draining the queue
            raise ValueError(f"slots={self.slots} must be >= 1")
        self.max_len = int(max_len)
        self.guard_nonfinite = bool(guard_nonfinite)
        self.ctx = ctx or Ctx(decode=True)
        self.dtype = dtype
        # one reusable batch-1 prefix template: constants (stream kernel
        # spectra, kcoef taps) are realised once, not per request
        self._prefix_template = serving.init_cache(
            cfg, 1, self.max_len, dtype, params=params)
        cap = serving.cache_capacity(self._prefix_template)
        self.capacity = cap          # None = length-unbounded (pure mamba)
        self._chunk_c = (serving.stream_block_of(self._prefix_template)
                         if serving.supports_chunked_prefill(
                             cfg, self._prefix_template) else None)
        self.trace_counts = {"generate": 0, "insert": 0, "decode1": 0,
                             "chunk1": 0}
        self._generate = jax.jit(self._make("generate", self._generate_fn))
        self._insert = jax.jit(self._make("insert", self._insert_fn))
        self._decode1 = jax.jit(self._make("decode1", self._decode1_fn))
        self._chunk1 = (jax.jit(self._make("chunk1", self._chunk1_fn))
                        if self._chunk_c else None)

    # ------------------------------------------------------------ plumbing
    def _make(self, name, fn):
        def counted(*args):
            self.trace_counts[name] += 1
            return fn(*args)
        return counted

    def _pick(self, logits):
        nxt = jnp.argmax(logits[:, -1], axis=-1)
        return jnp.minimum(nxt, self.cfg.vocab - 1).astype(jnp.int32)

    # ------------------------------------------------------- traced bodies
    def _decode1_fn(self, params, tok, cache, pos):
        return serving.decode_step(params, self.cfg, self.ctx,
                                   {"tokens": tok}, cache, pos)

    def _chunk1_fn(self, params, tok, cache, pos):
        return serving.decode_chunk(params, self.cfg, self.ctx,
                                    {"tokens": tok}, cache, pos)

    def _insert_fn(self, state, prefix, slot, plen, token):
        return st.insert(state, prefix, slot, plen, token)

    def _generate_fn(self, params, state):
        # inactive slots step at position 0 with a pad token: harmless
        # writes into scratch rows (the next insert overwrites the whole
        # row) and — deliberately — never on a stream-block boundary, so
        # parked slots cannot trigger the FD tail refresh
        cur = jnp.where(state.active, state.cur_len, 0)
        toks = jnp.where(state.active, state.tokens, 0)[:, None]
        logits, cache = serving.decode_step(
            params, self.cfg, self.ctx, {"tokens": toks}, state.cache, cur)
        nxt = self._pick(logits)
        if self.guard_nonfinite:
            # parked slots decode scratch rows (possibly a quarantined
            # slot's NaN remnants) — only active slots can be flagged
            row_ok = jnp.all(jnp.isfinite(logits[:, -1]), axis=-1)
            ok = jnp.where(state.active, row_ok, True)
        else:
            ok = jnp.ones((state.slots,), bool)
        # quarantine: a flagged slot neither advances nor stays active,
        # so its garbage token is never fed back on the next step
        advance = state.active & ok
        new_state = st.DecodeState(
            cache=cache,
            cur_len=jnp.where(advance, state.cur_len + 1, state.cur_len),
            tokens=jnp.where(advance, nxt, state.tokens),
            active=advance,
        )
        return new_state, nxt, ok

    # -------------------------------------------------------------- public
    def init_state(self) -> st.DecodeState:
        return st.init_decode_state(self.cfg, self.params, self.slots,
                                    self.max_len, self.dtype)

    def prefill(self, prompt):
        """prompt: (p,) or (1, p) int tokens. Returns (prefix_cache,
        first_token (device scalar), prompt_len). Raises when the prompt
        alone exceeds the slot capacity (an oversized insert would clamp
        the cache writes and silently corrupt the ring/KV rows)."""
        prompt = jnp.asarray(prompt, jnp.int32).reshape(1, -1)
        p = prompt.shape[1]
        if p < 1:
            raise ValueError("empty prompt")
        if self.capacity is not None and p > self.capacity:
            raise ValueError(
                f"prompt length {p} exceeds slot capacity "
                f"{self.capacity} (cache max_len {self.max_len}); "
                "raise Engine(max_len=...) or reject the request")
        cache = self._prefix_template
        pos = 0
        logits = None
        if self._chunk_c:
            c = self._chunk_c
            while pos + c <= p:
                logits, cache = self._chunk1(
                    self.params, prompt[:, pos:pos + c], cache,
                    jnp.int32(pos))
                pos += c
        while pos < p:
            logits, cache = self._decode1(
                self.params, prompt[:, pos:pos + 1], cache, jnp.int32(pos))
            pos += 1
        return cache, self._pick(logits)[0], p

    def insert(self, state, prefix_cache, plen, token, slot):
        """Admit a prefilled request into ``slot`` (traced index — no
        retrace across slots)."""
        return self._insert(state, prefix_cache, jnp.int32(slot),
                            jnp.int32(plen), jnp.asarray(token, jnp.int32))

    def generate(self, state):
        """One batched decode step: (state, tokens (S,), ok (S,)) — read
        tokens only for slots that were active going in AND finite
        (``ok``). A slot with ``ok=False`` has been quarantined in the
        returned state (frozen + deactivated); the caller must record
        the failure and release/recycle it."""
        return self._generate(self.params, state)

    def release(self, state, slot: int):
        return st.release(state, slot)

    def poison_slot(self, state, slot: int):
        """Chaos hook: overwrite ``slot``'s per-slot float cache rows with
        NaN so the next decode step trips the non-finite guard for that
        slot only — exercises the real quarantine path end to end."""
        return st.poison(state, slot)
