"""Engine snapshot/restore: preemptible serving (ISSUE 6 tentpole §4).

Serializes the full serving state through :mod:`repro.checkpoint.manifest`
(same atomic COMMITTED-marker layout as training checkpoints), so a
preempted server resumes mid-generation with **token-exact**
continuation:

* the device side — the :class:`~repro.serving_engine.state.DecodeState`
  pytree (every slot's cache rows, per-slot positions/tokens/active
  mask) is the manifest's array tree;
* the host side — scheduler bookkeeping (slot→request map, pending
  queue, per-request emitted tokens, outcomes, free-slot order, step
  counters, remaining deadline budgets) rides in the manifest's JSON
  ``extra``.

Greedy decode is deterministic and per-slot independent (the engine's
parity contract), so restoring cache + positions + bookkeeping and
rerunning the loop reproduces exactly the tokens an uninterrupted run
would have produced — CI-verified by the chaos-smoke gate.

``on_token`` callbacks are host closures and cannot be serialized;
:meth:`Scheduler.try_restore` re-attaches them from a ``callbacks``
mapping keyed by uid.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import numpy as np

from repro.checkpoint import manifest

SNAPSHOT_KIND = "serving-engine-snapshot"


def request_meta(req) -> Dict[str, Any]:
    return {
        "uid": req.uid,
        "prompt": np.asarray(req.prompt).astype(np.int64).tolist(),
        "max_new": int(req.max_new),
        "eos_id": None if req.eos_id is None else int(req.eos_id),
        # explicit sampling seed only; a None seed re-derives from the
        # uid on restore, which is stable by construction
        "seed": None if req.seed is None else int(req.seed),
    }


def meta_request(meta: Dict[str, Any], callbacks: Optional[Dict] = None):
    from repro.serving_engine.scheduler import Request
    uid = meta["uid"]
    return Request(
        uid=uid,
        prompt=np.asarray(meta["prompt"], np.int32),
        max_new=int(meta["max_new"]),
        eos_id=meta["eos_id"],
        on_token=(callbacks or {}).get(uid),
        seed=meta.get("seed"),
    )


def save_snapshot(snapshot_dir: str, sched, state, slot_req: Dict,
                  free, *, metrics=None) -> str:
    """Write one committed snapshot (manifest step = scheduler decode
    steps taken). Returns the step directory path. ``metrics`` (an obs
    registry) gets per-snapshot size gauges — payload bytes are the
    first thing to watch when snapshot latency regresses."""
    now = sched.clock()
    extra = {
        "kind": SNAPSHOT_KIND,
        "slots": sched.engine.slots,
        "max_len": sched.engine.max_len,
        "steps": sched.steps,
        "prefills": sched.prefills,
        "slot_req": [[int(slot), request_meta(req)]
                     for slot, req in sorted(slot_req.items())],
        "queue": [request_meta(r) for r in list(sched.queue)],
        "free": [int(s) for s in free],
        "results": {uid: [int(t) for t in toks]
                    for uid, toks in sched.results.items()},
        "outcomes": {uid: {"status": o.status, "error": o.error,
                           "callback_error": o.callback_error}
                     for uid, o in sched.outcomes.items()},
        # deadlines are wall-clock budgets: persist the *remaining* time
        # and re-arm on restore (a preempted second does not count)
        "deadline_remaining": {uid: float(dl - now)
                               for uid, dl in sched._deadlines.items()},
    }
    path = manifest.save(snapshot_dir, sched.steps, state, extra=extra)
    if metrics is not None:
        try:
            nbytes = sum(
                os.path.getsize(os.path.join(path, f))
                for f in os.listdir(path))
            metrics.gauge(
                "repro_snapshot_bytes",
                "size of the latest committed snapshot").set(nbytes)
            metrics.gauge(
                "repro_snapshot_inflight_requests",
                "in-flight requests captured by the latest snapshot",
            ).set(len(slot_req))
        except OSError:
            pass        # metrics must never fail a snapshot
    return path


def load_snapshot(snapshot_dir: str, engine, *,
                  step: Optional[int] = None) -> Optional[Dict[str, Any]]:
    """Returns {"state": DecodeState, "extra": dict} from the latest (or
    given) committed snapshot, or None when the directory holds none.
    Raises ValueError when the snapshot's engine geometry (slots,
    max_len) does not match ``engine`` — a mismatched resume would decode
    from misaligned cache rows, silently wrong."""
    if step is None:
        step = manifest.latest_step(snapshot_dir)
        if step is None:
            return None
    # validate kind/geometry from the manifest JSON *before* restoring the
    # array tree: a mismatched engine would otherwise surface as an opaque
    # per-leaf shape error instead of naming the geometry drift
    step_dir = os.path.join(snapshot_dir, f"step_{step:09d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        extra = json.load(f).get("extra", {})
    if extra.get("kind") != SNAPSHOT_KIND:
        raise ValueError(
            f"{snapshot_dir} step {step} is not a serving-engine snapshot "
            f"(kind={extra.get('kind')!r})")
    if (int(extra["slots"]) != engine.slots
            or int(extra["max_len"]) != engine.max_len):
        raise ValueError(
            f"snapshot geometry (slots={extra['slots']}, "
            f"max_len={extra['max_len']}) does not match engine "
            f"(slots={engine.slots}, max_len={engine.max_len})")
    state, extra = manifest.restore(snapshot_dir, engine.init_state(),
                                    step=step)
    return {"state": state, "extra": extra}


__all__ = ["SNAPSHOT_KIND", "save_snapshot", "load_snapshot",
           "request_meta", "meta_request"]
