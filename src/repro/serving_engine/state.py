"""Slot-based decode state for the continuous-batching engine.

A ``DecodeState`` is S *slots* — rows of one batched model cache — each
serving (at most) one in-flight request at its own position. The design
follows the standard continuous-batching substrate (MaxText/JetStream's
prefill → insert → generate loop; Qin & Zhong 2023's constant-time TNN
decode assumes the same shape):

* ``cache``   — the models/serving cache pytree batched over S slots
  (attention KV, mamba conv+state, TNO hist(+kcoef), FD overlap-save
  stream leaves);
* ``cur_len`` — **(S,) per-slot positions**: slot s's next write index.
  Every mixer's decode accepts this vector (masked decode_step), so one
  jitted step serves S requests at S different lengths;
* ``tokens``  — (S,) last emitted token per slot (next step's input);
* ``active``  — (S,) liveness mask: inactive slots are frozen (their
  cur_len/tokens don't advance; their cache rows are scratch until the
  next insert overwrites them);
* ``rng``     — (S, 2) uint32 per-slot PRNG lanes for sampled decode.
  Each slot's key is seeded at insert from the request's seed and split
  once per *advancing* step, so a request's sampled stream depends only
  on (params, prompt, seed, temperature) — never on its neighbours, the
  slot index, or how many engine steps happened before admission. Greedy
  engines carry the field untouched (zeros).

``insert_prefix_cache`` tree-maps a chunk-prefilled batch-1 cache into
one slot of the live batch with ``dynamic_update_slice`` along each
leaf's batch axis — no other slot's row is touched, and the
parameter-derived leaves shared by every slot (kernel constants
khead/khs/kseg, the memoised kcoef taps, the zero-element cap marker)
are left alone. All functions here are jit-stable at fixed S: traced
slot indices, no shape dependence on request lengths.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import serving

#: per-slot leaves, keyed by leaf name → batch-axis position from the
#: END of the shape (robust to the leading scan-layer axis of block
#: leaves, same convention as serving.shard_cache):
#:   k/v      (…, b, S, kvh, hd)   hist  (…, b, S, d)
#:   ring/tail(…, b, C, d)         conv  (…, b, w, conv_dim)
#:   state    (…, b, h, p, s)      uspec (…, b, NB, F, d)
#: Leaves not listed (khead, khs_re/im, kseg_re/im, kcoef, cap) are
#: parameter-derived constants identical for every slot: skipped.
BATCH_AXIS_FROM_END = {
    "k": 4, "v": 4, "hist": 3, "ring": 3, "tail": 3, "conv": 3,
    "state": 4, "uspec_re": 4, "uspec_im": 4,
}

#: leaves shared by every slot (identical for any request under the same
#: params/max_len) and therefore skipped by insert. Every cache leaf
#: MUST be classified in exactly one of these two tables — an unknown
#: name raises, because silently treating a new per-slot leaf as shared
#: would leak the previous occupant's state into a recycled slot.
SHARED_LEAVES = frozenset(
    {"khead", "khs_re", "khs_im", "kseg_re", "kseg_im", "kcoef", "cap"})


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("cache", "cur_len", "tokens", "active",
                                "rng"),
                   meta_fields=())
@dataclasses.dataclass
class DecodeState:
    cache: Any          # model cache pytree, batched over S slots
    cur_len: jax.Array  # (S,) int32 — next write position per slot
    tokens: jax.Array   # (S,) int32 — last emitted token per slot
    active: jax.Array   # (S,) bool  — slot liveness
    rng: jax.Array      # (S, 2) uint32 — per-slot sampling key lanes

    @property
    def slots(self) -> int:
        return self.cur_len.shape[0]


def init_decode_state(cfg, params, slots: int, max_len: int,
                      dtype=None) -> DecodeState:
    """Fresh all-free state: S slot rows of zeroed caches (params-aware,
    so fd mixers get streaming leaves and tno/fd hist leaves carry the
    memoised kcoef plan)."""
    cache = serving.init_cache(cfg, slots, max_len, dtype, params=params)
    return DecodeState(
        cache=cache,
        cur_len=jnp.zeros((slots,), jnp.int32),
        tokens=jnp.zeros((slots,), jnp.int32),
        active=jnp.zeros((slots,), bool),
        rng=jnp.zeros((slots, 2), jnp.uint32),
    )


def _leaf_name(path) -> str:
    names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
    return names[-1] if names else ""


def _classify(leaf: str):
    """Batch-axis offset (from the end) of a per-slot leaf, or None for a
    shared one. Unknown names raise — see SHARED_LEAVES."""
    off = BATCH_AXIS_FROM_END.get(leaf)
    if off is None and leaf not in SHARED_LEAVES:
        raise NotImplementedError(
            f"cache leaf {leaf!r} is not classified as per-slot "
            "(BATCH_AXIS_FROM_END) or shared (SHARED_LEAVES); "
            "add it before serving this cache through the engine")
    return off


def select_rows(take, new_cache, old_cache):
    """Per-row cache merge: batch row b of the result is ``new_cache``'s
    row where ``take[b]`` else ``old_cache``'s. This is the masked-update
    primitive of packed batch prefill — every row steps through the same
    jitted chunk/token op, but rows whose prompt ended earlier keep their
    already-final cache instead of absorbing pad-token writes. Shared
    parameter-derived leaves take the new side (they are identical on
    both by construction)."""
    take = jnp.asarray(take, bool)

    def f(path, new, old):
        off = _classify(_leaf_name(path))
        if off is None:
            return new                   # shared constant leaf
        ax = new.ndim - off
        shape = tuple(take.shape[0] if i == ax else 1
                      for i in range(new.ndim))
        return jnp.where(take.reshape(shape), new, old)
    return jax.tree_util.tree_map_with_path(f, new_cache, old_cache)


def take_row(packed_cache, row):
    """Slice batch row ``row`` (kept, size 1) out of a packed prefill
    cache, producing the batch-1 prefix tree :func:`insert_prefix_cache`
    expects. ``row`` may be traced — one jit trace serves every row.
    Shared leaves pass through whole."""
    row = jnp.asarray(row, jnp.int32)

    def f(path, leaf):
        off = _classify(_leaf_name(path))
        if off is None:
            return leaf
        ax = leaf.ndim - off
        starts = [jnp.int32(0)] * leaf.ndim
        starts[ax] = row
        sizes = tuple(1 if i == ax else s
                      for i, s in enumerate(leaf.shape))
        return jax.lax.dynamic_slice(leaf, tuple(starts), sizes)
    return jax.tree_util.tree_map_with_path(f, packed_cache)


def insert_prefix_cache(batched_cache, prefix_cache, slot):
    """Slice a batch-1 prefix cache into row ``slot`` of the batched
    cache (traced slot index — one jit trace serves every slot). Shared
    (non-per-slot) leaves keep the batched side's value."""
    def f(path, dst, src):
        off = _classify(_leaf_name(path))
        if off is None:
            return dst                       # shared constant leaf
        ax = dst.ndim - off
        starts = [jnp.int32(0)] * dst.ndim
        starts[ax] = jnp.asarray(slot, jnp.int32)
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                            tuple(starts))
    return jax.tree_util.tree_map_with_path(f, batched_cache, prefix_cache)


def insert(state: DecodeState, prefix_cache, slot, cur_len,
           token, key=None) -> DecodeState:
    """Admit a prefilled request into ``slot``: slice its cache row in,
    set the slot's position to the prefix length, seed the first decode
    input with the prefill's sampled token, and mark the slot live.
    ``key`` (uint32 (2,)) seeds the slot's sampling lane; None leaves the
    previous occupant's lane bits (greedy engines never read them).
    ``slot`` / ``cur_len`` / ``token`` / ``key`` may all be traced."""
    slot = jnp.asarray(slot, jnp.int32)
    rng = state.rng
    if key is not None:
        rng = rng.at[slot].set(jnp.asarray(key, jnp.uint32))
    return DecodeState(
        cache=insert_prefix_cache(state.cache, prefix_cache, slot),
        cur_len=state.cur_len.at[slot].set(jnp.asarray(cur_len, jnp.int32)),
        tokens=state.tokens.at[slot].set(jnp.asarray(token, jnp.int32)),
        active=state.active.at[slot].set(True),
        rng=rng,
    )


def release(state: DecodeState, slot: int) -> DecodeState:
    """Evict a finished request: the slot is frozen (mask off) and its
    cache row becomes scratch until the next insert recycles it."""
    return dataclasses.replace(state,
                               active=state.active.at[slot].set(False))


def poison(state: DecodeState, slot) -> DecodeState:
    """Fault-injection hook: overwrite ``slot``'s per-slot floating-point
    cache rows with NaN. The next decode step produces non-finite logits
    for that row only (per-slot leaves are row-independent — the same
    isolation property ``insert`` relies on), which the engine's
    non-finite guard must catch and quarantine. Shared parameter-derived
    leaves and integer leaves are untouched."""
    slot = jnp.asarray(slot, jnp.int32)

    def f(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = names[-1] if names else ""
        off = BATCH_AXIS_FROM_END.get(name)
        if off is None or not jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf
        ax = leaf.ndim - off
        row_shape = tuple(1 if i == ax else s
                          for i, s in enumerate(leaf.shape))
        starts = [jnp.int32(0)] * leaf.ndim
        starts[ax] = slot
        return jax.lax.dynamic_update_slice(
            leaf, jnp.full(row_shape, jnp.nan, leaf.dtype), tuple(starts))

    return dataclasses.replace(
        state, cache=jax.tree_util.tree_map_with_path(f, state.cache))
