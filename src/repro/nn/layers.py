"""Primitive NN layers: dense, norms, MLPs. Functional (params, x) -> y."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.params import KeyGen, boxed

ACTS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "sigmoid": jax.nn.sigmoid,
    "none": lambda x: x,
}


def cast_params(params, dtype):
    """Cast every floating-point leaf of a param tree to ``dtype``.

    Mixed-precision helper for the kernel training path: activations and
    params run in bf16 while the custom-VJP kernels accumulate in fp32
    (bf16-with-fp32-accum — see tests/test_ski_grad.py). Integer leaves
    (e.g. data cursors) pass through untouched.
    """
    def f(x):
        x = jnp.asarray(x)
        return x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x
    return jax.tree.map(f, params)


# ---------------------------------------------------------------- dense
def dense_init(key, d_in, d_out, *, axes=("embed", "mlp"), use_bias=False,
               dtype=jnp.float32, scale=1.0):
    kg = KeyGen(key)
    p = {"w": boxed(kg(), (d_in, d_out), axes, "lecun", dtype, scale)}
    if use_bias:
        p["b"] = boxed(kg(), (d_out,), (axes[-1],), "zeros", dtype)
    return p


def dense(p, x, dtype=None):
    w = p["w"]
    if dtype is not None:
        w = w.astype(dtype)
        x = x.astype(dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------- norms
def rmsnorm_init(key, d, *, axes=("embed",), dtype=jnp.float32):
    del key
    return {"scale": boxed(None, (d,), axes, "ones", dtype)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(key, d, *, axes=("embed",), dtype=jnp.float32):
    del key
    return {
        "scale": boxed(None, (d,), axes, "ones", dtype),
        "bias": boxed(None, (d,), axes, "zeros", dtype),
    }


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------- scalar MLP
def mlp_init(key, d_in, d_hidden, d_out, n_layers, *, use_layernorm=True,
             dtype=jnp.float32, axes_hidden="rpe_hidden"):
    """n_layers >= 1 linear layers with activations between (none on output)."""
    kg = KeyGen(key)
    layers = []
    dims = [d_in] + [d_hidden] * (n_layers - 1) + [d_out]
    for i in range(n_layers):
        a_in = axes_hidden if i > 0 else None
        a_out = axes_hidden if i < n_layers - 1 else "tno_channel"
        lp = dense_init(kg(), dims[i], dims[i + 1], axes=(a_in, a_out),
                        use_bias=True)
        if use_layernorm and i < n_layers - 1:
            lp["ln"] = layernorm_init(kg(), dims[i + 1], axes=(a_out,))
        layers.append(lp)
    return {"layers": layers}


def mlp_apply(p, x, act="relu"):
    """x: (..., d_in) -> (..., d_out)."""
    f = ACTS[act]
    n = len(p["layers"])
    for i, lp in enumerate(p["layers"]):
        x = dense(lp, x)
        if i < n - 1:
            if "ln" in lp:
                x = layernorm(lp["ln"], x)
            x = f(x)
    return x
