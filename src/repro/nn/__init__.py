"""NN primitives: layers (dense/rmsnorm/MLP/activations) and the boxed
parameter utilities (logical axes, init distributions). Real package (not
a namespace dir) so coverage accounting and ``python -m`` imports resolve
it like every sibling."""
