"""Lightweight functional parameter system with logical sharding axes.

No flax dependency: parameters are plain pytrees of jax.Arrays. During init
each leaf is wrapped in a :class:`Box` carrying its *logical axis names*
(one per dim). ``unbox`` splits a boxed tree into (params, axes) twin trees;
``repro.parallel.sharding`` maps logical names -> mesh axes -> NamedSharding.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, Sequence[int], Any], jax.Array]


@dataclasses.dataclass
class Box:
    """A parameter leaf + its logical axis names. NOT a pytree node."""

    value: jax.Array
    axes: tuple  # tuple[str | None, ...], len == value.ndim

    def __post_init__(self):
        assert len(self.axes) == self.value.ndim, (
            f"axes {self.axes} rank != value rank {self.value.shape}")


def is_box(x) -> bool:
    return isinstance(x, Box)


def boxed(key, shape, axes, init="lecun", dtype=jnp.float32, scale=1.0) -> Box:
    """Create a boxed parameter. ``init``: lecun|normal|zeros|ones|embed."""
    shape = tuple(int(s) for s in shape)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    elif init == "lecun":
        fan_in = shape[0] if len(shape) >= 1 else 1
        if len(shape) >= 2:
            fan_in = math.prod(shape[:-1])
        std = scale / math.sqrt(max(fan_in, 1))
        v = std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
        v = v.astype(dtype)
    elif init == "normal":
        v = (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    elif init == "embed":
        v = (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    else:
        raise ValueError(f"unknown init {init}")
    return Box(v, tuple(axes))


def unbox(tree):
    """Split tree-of-Box -> (params tree, axes tree)."""
    params = jax.tree.map(lambda b: b.value, tree, is_leaf=is_box)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_box)
    return params, axes


def rebox(vals, axes, prepend=()):
    """Zip a value tree with an axes tree (tuple leaves) back into Boxes,
    optionally prepending logical axes (e.g. a scanned "layers" dim)."""
    leaves, treedef = jax.tree.flatten(vals)
    axes_leaves = treedef.flatten_up_to(axes)
    return jax.tree.unflatten(
        treedef,
        [Box(v, tuple(prepend) + tuple(a)) for v, a in zip(leaves, axes_leaves)])


def tree_size_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


def tree_param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def split_keys(key, n):
    return list(jax.random.split(key, n))


class KeyGen:
    """Stateful key splitter for terse init code."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub
