"""Serving & training observability layer — see docs/observability.md.

Four pieces (ISSUE 9):

* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket histograms
  under a thread-safe registry, with Prometheus text exposition and a
  JSON dump. Off-by-default: the process default registry is a no-op
  unless ``REPRO_METRICS`` is truthy or an explicit registry is passed.
* :mod:`repro.obs.tracing` — per-request lifecycle span events (submit →
  queue → admit → prefill → first-token → decode → terminal status),
  JSONL on disk via ``REPRO_TRACE_FILE``, exportable to Chrome
  ``trace_event`` JSON for chrome://tracing / Perfetto.
* :mod:`repro.obs.log` — the one logger every banner routes through
  (``REPRO_LOG_LEVEL``; quiet by default under pytest).
* :mod:`repro.obs.profiling` — opt-in ``jax.profiler`` sessions +
  annotations around prefill/decode/train steps (``REPRO_PROFILE_DIR``).
"""
from repro.obs.metrics import (NULL_REGISTRY, MirroredCounts, NullRegistry,
                               Registry, default_registry, metrics_enabled,
                               set_default_registry)
from repro.obs.tracing import (Tracer, chrome_trace, default_tracer,
                               load_jsonl, set_default_tracer,
                               validate_spans, write_chrome)
from repro.obs.log import banner, get_logger, set_level
from repro.obs.profiling import annotation, profile_dir, session

__all__ = [
    "Registry", "NullRegistry", "NULL_REGISTRY", "MirroredCounts",
    "default_registry", "set_default_registry", "metrics_enabled",
    "Tracer", "default_tracer", "set_default_tracer", "load_jsonl",
    "chrome_trace", "write_chrome", "validate_spans",
    "get_logger", "set_level", "banner",
    "profile_dir", "session", "annotation",
]
