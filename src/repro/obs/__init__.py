"""Serving & training observability layer — see docs/observability.md.

Four pieces (ISSUE 9):

* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket histograms
  under a thread-safe registry, with Prometheus text exposition and a
  JSON dump. Off-by-default: the process default registry is a no-op
  unless ``REPRO_METRICS`` is truthy or an explicit registry is passed.
* :mod:`repro.obs.tracing` — per-request lifecycle span events (submit →
  queue → admit → prefill → first-token → decode → terminal status),
  JSONL on disk via ``REPRO_TRACE_FILE``, exportable to Chrome
  ``trace_event`` JSON for chrome://tracing / Perfetto.
* :mod:`repro.obs.log` — the one logger every banner routes through
  (``REPRO_LOG_LEVEL``; quiet by default under pytest).
* :mod:`repro.obs.profiling` — opt-in ``jax.profiler`` sessions +
  annotations around prefill/decode/train steps (``REPRO_PROFILE_DIR``).

The kernel tier (ISSUE 10) sits underneath:

* :mod:`repro.obs.cost` — analytic per-kernel FLOP/byte estimators keyed
  off the ski/tno plan objects, roofline math, and the
  ``cost_analysis()`` cross-check.
* :mod:`repro.obs.devstats` — kernel regions at the dispatch sites,
  profiler-trace aggregation / analytic attribution into
  ``repro_kernel_seconds_total{kernel}``, and HBM/live-buffer gauges.
* :mod:`repro.obs.compilewatch` — the compile/retrace watchdog
  (``repro_compiles_total{fn}`` + compile-seconds histogram + budget
  warnings) wrapping the memoised jit entry points.
"""
from repro.obs.metrics import (NULL_REGISTRY, MirroredCounts, NullRegistry,
                               Registry, default_registry, metrics_enabled,
                               set_default_registry)
from repro.obs.tracing import (Tracer, chrome_trace, default_tracer,
                               load_jsonl, set_default_tracer,
                               validate_spans, write_chrome)
from repro.obs.log import banner, get_logger, set_level
from repro.obs.profiling import annotation, profile_dir, session
from repro.obs.cost import (Cost, Peaks, achieved_fraction, cost_of_plan,
                            decode_step_cost, peaks, xla_cost)
from repro.obs.compilewatch import CompileWatch
from repro.obs.devstats import (aggregate_chrome, attribute_engine,
                                kernel_region, sample_memory)

__all__ = [
    "Registry", "NullRegistry", "NULL_REGISTRY", "MirroredCounts",
    "default_registry", "set_default_registry", "metrics_enabled",
    "Tracer", "default_tracer", "set_default_tracer", "load_jsonl",
    "chrome_trace", "write_chrome", "validate_spans",
    "get_logger", "set_level", "banner",
    "profile_dir", "session", "annotation",
    "Cost", "Peaks", "peaks", "cost_of_plan", "decode_step_cost",
    "achieved_fraction", "xla_cost",
    "CompileWatch",
    "kernel_region", "aggregate_chrome", "attribute_engine",
    "sample_memory",
]
