"""Device-time attribution + memory gauges (ISSUE 10 tentpole §2/§3b).

Three concerns, all off-by-default-cheap like the rest of the obs tier:

* **Kernel regions** — :func:`kernel_region` wraps every dispatch site
  in ``kernels/ops.py`` (the same layer that counts
  ``repro_kernel_dispatch_total``) in a ``jax.named_scope`` so the
  kernel name lands in HLO op metadata (→ XLA/TPU profiler attribution
  on real hardware), plus a ``jax.profiler.TraceAnnotation`` when
  ``REPRO_PROFILE_DIR`` is armed. Both are trace-time only: zero steady
  state cost inside a compiled executable.
* **Attribution** — on a profiled run, :func:`aggregate_chrome` sums
  per-kernel wall seconds out of a Chrome trace (ours or the
  profiler's). On CPU smoke runs — where annotations cannot see device
  time — :func:`attribute_engine` takes the *measured* engine seconds
  (the scheduler's ``repro_decode_step_seconds`` /
  ``repro_prefill_seconds`` histogram sums) and splits them across
  kernel families using the analytic share map from
  :func:`repro.obs.cost.decode_step_cost`. Either path records into
  ``repro_kernel_seconds_total{kernel}`` and a per-kernel
  ``repro_kernel_roofline_frac`` gauge, which ``tools/obs_report.py
  --kernels`` renders.
* **Memory gauges** — :func:`sample_memory` publishes live device
  bytes, DecodeState cache bytes, and the fd ring/spectra slice of the
  cache as gauges; the scheduler samples it every
  ``REPRO_MEM_SAMPLE_EVERY`` steps (0 = off, the default).
"""
from __future__ import annotations

import contextlib
import gzip
import json
import os
from pathlib import Path
from typing import Dict, Iterable, List, Optional

from repro.obs import cost as obs_cost
from repro.obs import metrics as obs_metrics
from repro.obs import profiling as obs_prof

#: named_scope prefix for kernel regions — the aggregator keys off it
KERNEL_SCOPE_PREFIX = "repro_kernel."

_ENV_MEM_EVERY = "REPRO_MEM_SAMPLE_EVERY"

#: DecodeState cache leaves that belong to the fd streaming decode path
#: (overlap-save ring + block/tail spectra) — see serving_engine/state.py
FD_STREAM_LEAVES = ("ring", "tail", "uspec_re", "uspec_im")


def mem_sample_every() -> int:
    v = os.environ.get(_ENV_MEM_EVERY)
    if v is None or v == "":
        return 0
    try:
        return max(int(v), 0)
    except ValueError:
        raise ValueError(f"{_ENV_MEM_EVERY}={v!r} is not an int") from None


# ------------------------------------------------------------ regions
@contextlib.contextmanager
def kernel_region(kernel: str):
    """Mark a kernel dispatch site. ``jax.named_scope`` stamps the
    kernel name into the HLO metadata of every op traced inside (the
    XLA profiler then attributes device time to it on real hardware);
    the profiler annotation additionally shows up as a host-side region
    when a ``REPRO_PROFILE_DIR`` session is live. Runs at trace time
    only — compiled calls never re-enter it."""
    import jax
    with jax.named_scope(KERNEL_SCOPE_PREFIX + kernel):
        with obs_prof.annotation(KERNEL_SCOPE_PREFIX + kernel):
            yield


# ------------------------------------------------ trace aggregation
def aggregate_chrome(events: Iterable[dict],
                     prefix: str = KERNEL_SCOPE_PREFIX) -> Dict[str, float]:
    """Sum per-kernel seconds from Chrome ``trace_event`` records (the
    profiler's ``*.trace.json``, or our own exporter's output). Handles
    complete events (``X`` with ``dur`` µs) and ``B``/``E`` pairs
    (stacked per (pid, tid, name)). Returns ``{kernel: seconds}`` for
    events whose name starts with ``prefix`` (stripped)."""
    totals: Dict[str, float] = {}
    open_b: Dict[tuple, List[float]] = {}
    for ev in events:
        name = ev.get("name", "")
        if not isinstance(name, str) or not name.startswith(prefix):
            continue
        kernel = name[len(prefix):]
        ph = ev.get("ph")
        if ph == "X":
            totals[kernel] = totals.get(kernel, 0.0) \
                + float(ev.get("dur", 0.0)) * 1e-6
        elif ph == "B":
            key = (ev.get("pid"), ev.get("tid"), kernel)
            open_b.setdefault(key, []).append(float(ev["ts"]))
        elif ph == "E":
            key = (ev.get("pid"), ev.get("tid"), kernel)
            stack = open_b.get(key)
            if stack:
                totals[kernel] = totals.get(kernel, 0.0) \
                    + (float(ev["ts"]) - stack.pop()) * 1e-6
    return totals


def load_profile_traces(profile_dir: str) -> List[dict]:
    """Collect ``traceEvents`` from every ``*.trace.json[.gz]`` under a
    ``jax.profiler`` session directory."""
    events: List[dict] = []
    root = Path(profile_dir)
    for p in sorted(root.rglob("*.trace.json")) + \
            sorted(root.rglob("*.trace.json.gz")):
        try:
            if p.suffix == ".gz":
                with gzip.open(p, "rt") as f:
                    doc = json.load(f)
            else:
                with open(p) as f:
                    doc = json.load(f)
        except (OSError, ValueError):
            continue
        events.extend(doc.get("traceEvents", []))
    return events


def record_kernel_seconds(seconds_by_kernel: Dict[str, float],
                          metrics=None) -> None:
    """Accumulate attributed seconds into
    ``repro_kernel_seconds_total{kernel}``."""
    reg = metrics if metrics is not None else obs_metrics.default_registry()
    m = reg.counter("repro_kernel_seconds_total",
                    "attributed device/engine seconds per kernel family",
                    ("kernel",))
    for kernel, s in seconds_by_kernel.items():
        if s > 0:
            m.labels(kernel=kernel).inc(s)


# ------------------------------------------------------ attribution
def _hist_sum(reg, name: str) -> float:
    m = reg.get(name) if hasattr(reg, "get") else None
    if m is None or getattr(m, "kind", None) != "histogram":
        return 0.0
    with m._lock:
        return sum(ch.sum for ch in m._children.values())


def attribute_engine(engine, metrics, *, drain_s: Optional[float] = None,
                     profile_dir: Optional[str] = None) -> dict:
    """Split measured engine seconds across kernel families and record
    them (tentpole §2's CPU-honest path; acceptance: ≥ 80% of the S=16
    drain accounted for).

    Ground truth seconds come from the scheduler's own histograms —
    ``repro_decode_step_seconds`` + ``repro_prefill_seconds`` sums,
    which time the blocking device calls. When a profiler trace is
    available (``profile_dir``), per-kernel region seconds are used
    directly; otherwise the decode seconds are projected onto families
    by the analytic FLOP shares of one decode step
    (:func:`repro.obs.cost.decode_step_cost` for the engine's arch —
    on CPU, where every family is effectively compute-bound, FLOPs are
    the honest weight). Records ``repro_kernel_seconds_total{kernel}``
    + ``repro_kernel_roofline_frac{kernel}`` and returns::

        {"device_s", "coverage", "rows": [
            {"kernel", "seconds", "frac", "roofline_frac"}, ...]}

    ``coverage`` is device_s / drain_s (None when drain_s not given).
    """
    step_s = _hist_sum(metrics, "repro_decode_step_seconds")
    prefill_s = _hist_sum(metrics, "repro_prefill_seconds")
    device_s = step_s + prefill_s

    by_kernel: Dict[str, float] = {}
    if profile_dir:
        by_kernel = aggregate_chrome(load_profile_traces(profile_dir))
    if not by_kernel and device_s > 0:
        cfg = engine.cfg
        costs = obs_cost.decode_step_cost(cfg, engine.slots, engine.max_len)
        flops_total = sum(c.flops for c in costs.values()) or 1.0
        by_kernel = {k: step_s * (c.flops / flops_total)
                     for k, c in costs.items()}
        if prefill_s > 0:
            # prefill is one fused forward over the prompt — same family
            # mix at n=bucket length; reuse the step shares
            for k, c in costs.items():
                by_kernel[k] = by_kernel.get(k, 0.0) \
                    + prefill_s * (c.flops / flops_total)
    record_kernel_seconds(by_kernel, metrics)

    pk = obs_cost.peaks()
    costs = obs_cost.decode_step_cost(engine.cfg, engine.slots,
                                      engine.max_len)
    # steps executed ≈ decode-step histogram count
    m = metrics.get("repro_decode_step_seconds") if hasattr(
        metrics, "get") else None
    n_steps = 0
    if m is not None and getattr(m, "kind", None) == "histogram":
        with m._lock:
            n_steps = sum(ch.count for ch in m._children.values())
    frac_gauge = metrics.gauge(
        "repro_kernel_roofline_frac",
        "achieved fraction of the roofline bound per kernel family",
        ("kernel",))
    total_s = sum(by_kernel.values()) or 1.0
    rows = []
    for kernel, s in sorted(by_kernel.items(), key=lambda kv: -kv[1]):
        rf = None
        c = costs.get(kernel)
        if c is not None and n_steps > 0 and s > 0:
            rf = obs_cost.achieved_fraction(c.scale(n_steps), s, pk)
            frac_gauge.labels(kernel=kernel).set(rf)
        rows.append({"kernel": kernel, "seconds": s,
                     "frac": s / total_s, "roofline_frac": rf})
    return {"device_s": device_s,
            "coverage": (device_s / drain_s) if drain_s else None,
            "rows": rows}


# --------------------------------------------------------- memory gauges
def _path_key_names(path) -> list:
    names = []
    for k in path:
        name = getattr(k, "key", None)
        if name is None:
            name = getattr(k, "name", None)
        if name is not None:
            names.append(str(name))
    return names


def _tree_bytes(tree, names: Optional[tuple] = None) -> int:
    """Sum ``nbytes`` over array leaves; with ``names``, only leaves
    whose pytree path contains one of those dict keys (DecodeState cache
    leaves are keyed by name — see ``state.BATCH_AXIS_FROM_END``)."""
    import jax
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        nb = getattr(leaf, "nbytes", None)
        if nb is None:
            continue
        if names is not None and not any(
                n in names for n in _path_key_names(path)):
            continue
        total += int(nb)
    return total


def sample_memory(metrics=None, state=None, *,
                  reuse: Optional[dict] = None) -> Dict[str, float]:
    """Publish HBM/live-buffer gauges (tentpole §3b): total live device
    bytes (``jax.live_arrays()``, guarded — absent on some backends),
    DecodeState cache bytes, and the fd ring/spectra slice of the cache.
    Returns the sampled values; called from the scheduler loop every
    ``REPRO_MEM_SAMPLE_EVERY`` steps.

    ``reuse`` (a caller-held dict) caches the cache-pytree byte sums:
    the DecodeState cache is fixed-shape for the lifetime of a drain, so
    the pytree walk happens once and later samples republish the cached
    sizes — only the live-array total is re-measured each time."""
    reg = metrics if metrics is not None else obs_metrics.default_registry()
    out: Dict[str, float] = {}
    import jax
    try:
        live = sum(int(getattr(a, "nbytes", 0))
                   for a in jax.live_arrays())
    except Exception:  # noqa: BLE001 — live_arrays is best-effort
        live = 0
    if live:
        reg.gauge("repro_live_device_bytes",
                  "total bytes of live jax arrays").set(live)
        out["repro_live_device_bytes"] = float(live)
    if state is not None:
        cache = getattr(state, "cache", None)
        if cache is not None:
            if reuse is not None and "cache_bytes" in reuse:
                cb, fd = reuse["cache_bytes"], reuse["fd_bytes"]
            else:
                cb = _tree_bytes(cache)
                fd = _tree_bytes(cache, FD_STREAM_LEAVES)
                if reuse is not None:
                    reuse["cache_bytes"], reuse["fd_bytes"] = cb, fd
            reg.gauge("repro_decode_cache_bytes",
                      "DecodeState cache bytes across slots").set(cb)
            out["repro_decode_cache_bytes"] = float(cb)
            if fd:
                reg.gauge("repro_fd_stream_bytes",
                          "fd overlap-save ring + spectra bytes").set(fd)
                out["repro_fd_stream_bytes"] = float(fd)
    return out


__all__ = ["kernel_region", "KERNEL_SCOPE_PREFIX", "FD_STREAM_LEAVES",
           "aggregate_chrome", "load_profile_traces",
           "record_kernel_seconds", "attribute_engine", "sample_memory",
           "mem_sample_every"]
