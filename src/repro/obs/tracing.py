"""Structured per-request trace spans + Chrome trace_event export.

The serving timeline recorder (ISSUE 9 tentpole §2). A :class:`Tracer`
collects flat span events — ``begin`` / ``end`` / ``instant`` /
``counter`` — each a small dict stamped with a monotonic timestamp, a
track (the request ``uid``, or ``None`` for engine-global events), and
free-form attributes. Events are appended to an in-memory list and,
when a path is given (or ``REPRO_TRACE_FILE`` is set), streamed as JSONL
so a killed process still leaves a readable trace prefix.

Request lifecycle span schema (emitted by
:class:`~repro.serving_engine.scheduler.Scheduler`):

======================  ====================================================
span / event            meaning
======================  ====================================================
``request``  B..E       submit → terminal; ``E`` carries ``status`` ∈
                        {ok, error, expired, preempted}
``queue``    B..E       submit → admission wave pop (or expiry/preempt)
``prefill``  B..E       engine prefill+insert; ``packed``/``retries`` attrs
``decode``   B..E       slot residency: insert → release
``first_token`` i       TTFT point (prefill-sampled token recorded)
``token``    i          one decoded token recorded for this request
``retry``    i          transient-fault retry (``site``, ``attempt``)
``fault``    i          injector firing (``site``, ``action``, ``spec``)
``quarantine`` i        non-finite guard evicted this request's slot
``expired``  i          deadline watchdog dropped/evicted the request
``step``     B..E       global track: one batched decode step
``snapshot`` B..E       global track: snapshot write
``queue_depth``/… C     global counter tracks (queue, slots, detok)
======================  ====================================================

Export: :func:`chrome_trace` converts an event list to the Chrome
``trace_event`` JSON object format — load the file in ``chrome://tracing``
or https://ui.perfetto.dev. Each request uid gets its own named thread
track; counter events render as counter tracks. :func:`validate_spans`
is the machine-checkable completeness contract (every begun span ends,
every request ends with a terminal status) shared by tests, the chaos
CI gate, and ``tools/obs_report.py``.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
from typing import Dict, List, Optional

_ENV_TRACE = "REPRO_TRACE_FILE"

#: terminal request statuses a ``request`` end event may carry
TERMINAL_STATUSES = ("ok", "error", "expired", "preempted")


class Tracer:
    """Append-only span event collector; thread-safe (the scheduler loop,
    the detok worker, and a submitter thread all emit concurrently).

    ``clock`` defaults to ``time.perf_counter`` — timestamps are
    monotonic seconds from an arbitrary origin; only differences and
    ordering are meaningful (Chrome export rebases to the first event).
    """

    #: events buffered before a batched disk write — per-event writes
    #: would put a syscall on the per-token hot path (measured > 5% at
    #: S=16 on the CPU smoke engine); batching amortises it to noise. A
    #: killed process still leaves a readable JSONL prefix, short of at
    #: most FLUSH_EVERY trailing events (``flush()`` runs at every
    #: scheduler ``run()`` exit, so completed serving is never lost).
    FLUSH_EVERY = 256

    def __init__(self, path: Optional[str] = None, *,
                 clock=time.perf_counter):
        self.path = path
        self.clock = clock
        self.events: List[dict] = []
        self._lock = threading.Lock()
        self._file = None
        self._pending: List[dict] = []   # not yet serialised to disk
        if path:
            self._file = open(path, "a", buffering=1)  # line-buffered

    # ------------------------------------------------------------- emit
    def _emit(self, ph: str, name: str, uid: Optional[str], attrs: dict):
        ev = {"ts": self.clock(), "ph": ph, "name": name}
        if uid is not None:
            ev["uid"] = uid
        if attrs:
            ev["attrs"] = attrs
        with self._lock:
            self.events.append(ev)
            if self._file is not None:
                self._pending.append(ev)
                if len(self._pending) >= self.FLUSH_EVERY:
                    self._write_pending_locked()

    def _write_pending_locked(self):
        if self._file is None or not self._pending:
            self._pending.clear()
            return
        try:
            self._file.write(
                "".join(json.dumps(ev) + "\n" for ev in self._pending))
        except (OSError, ValueError):
            self._file = None   # fd gone: keep in-memory trace
        self._pending.clear()

    def begin(self, name: str, uid: Optional[str] = None, **attrs):
        self._emit("B", name, uid, attrs)

    def end(self, name: str, uid: Optional[str] = None, **attrs):
        self._emit("E", name, uid, attrs)

    def instant(self, name: str, uid: Optional[str] = None, **attrs):
        self._emit("i", name, uid, attrs)

    def counter(self, name: str, value: float):
        self._emit("C", name, None, {"value": float(value)})

    def close(self):
        with self._lock:
            self._write_pending_locked()
            if self._file is not None:
                try:
                    self._file.close()
                except OSError:
                    pass
                self._file = None

    def flush(self):
        with self._lock:
            self._write_pending_locked()
            if self._file is not None:
                try:
                    self._file.flush()
                except (OSError, ValueError):
                    pass


_default: Optional[Tracer] = None
_default_lock = threading.Lock()
_atexit_registered = False


def _close_default_tracer() -> None:
    """atexit hook: flush+close whatever the default tracer is *now* —
    the JSONL writer batches :attr:`Tracer.FLUSH_EVERY` events, so a
    process that exits without ``close()`` would silently drop the tail
    of the trace (ISSUE 10 satellite bugfix)."""
    with _default_lock:
        t = _default
    if t is not None:
        try:
            t.close()
        except Exception:  # noqa: BLE001 — never fail interpreter exit
            pass


def default_tracer() -> Optional[Tracer]:
    """Process-wide tracer writing to ``REPRO_TRACE_FILE`` (None when the
    env is unset — tracing is opt-in). Explicit tracers passed to the
    Scheduler bypass this. The first creation registers an ``atexit``
    close so the batched JSONL tail survives an exit without an explicit
    ``close()``."""
    global _default, _atexit_registered
    if _default is None:
        path = os.environ.get(_ENV_TRACE)
        if not path:
            return None
        with _default_lock:
            if _default is None:
                _default = Tracer(path)
                if not _atexit_registered:
                    atexit.register(_close_default_tracer)
                    _atexit_registered = True
    return _default


def set_default_tracer(tracer: Optional[Tracer]) -> None:
    global _default, _atexit_registered
    with _default_lock:
        _default = tracer
        if tracer is not None and not _atexit_registered:
            atexit.register(_close_default_tracer)
            _atexit_registered = True


# ---------------------------------------------------------------- loading
def load_jsonl(path: str) -> List[dict]:
    events = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except ValueError as e:
                raise ValueError(f"{path}:{i}: bad trace line: {e}") from e
    return events


# ----------------------------------------------------------- chrome export
def chrome_trace(events: List[dict]) -> dict:
    """Chrome ``trace_event`` JSON object format. One pid; tid 0 is the
    engine-global track (steps, snapshots), each request uid gets its
    own named tid in order of first appearance; counter events become
    ``ph: "C"`` counter tracks. Timestamps rebase to the first event and
    scale to microseconds (the format's unit)."""
    if not events:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t0 = min(e["ts"] for e in events)
    tids: Dict[str, int] = {}
    out = [{"ph": "M", "pid": 1, "tid": 0, "name": "thread_name",
            "args": {"name": "engine"}},
           {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
            "args": {"name": "repro-serving"}}]

    def tid_of(uid: Optional[str]) -> int:
        if uid is None:
            return 0
        if uid not in tids:
            tids[uid] = len(tids) + 1
            out.append({"ph": "M", "pid": 1, "tid": tids[uid],
                        "name": "thread_name",
                        "args": {"name": f"req {uid}"}})
        return tids[uid]

    for ev in events:
        ts = (ev["ts"] - t0) * 1e6
        attrs = dict(ev.get("attrs", {}))
        uid = ev.get("uid")
        base = {"pid": 1, "ts": ts, "name": ev["name"], "cat": "serving"}
        if ev["ph"] == "C":
            out.append({**base, "ph": "C", "tid": 0,
                        "args": {"value": attrs.get("value", 0)}})
            continue
        if uid is not None:
            attrs["uid"] = uid
        base["tid"] = tid_of(uid)
        if ev["ph"] == "i":
            out.append({**base, "ph": "i", "s": "t", "args": attrs})
        else:
            out.append({**base, "ph": ev["ph"], "args": attrs})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome(events: List[dict], path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(events), f, indent=1)
        f.write("\n")


# ------------------------------------------------------------- validation
def validate_spans(events: List[dict]) -> Dict[str, List[dict]]:
    """Machine-check the span contract; returns ``{uid: [request span
    records]}`` (a uid may legitimately carry several sequential request
    spans — e.g. a preempted run resumed in the same process).

    Raises ``ValueError`` when any track has a begin without a matching
    end (or vice versa, or interleaved same-name nesting), when a
    ``request`` end carries no terminal status, or when a request span
    contains no ``queue`` span (every admitted request must have been
    queued first). Each record: ``{"status", "t0", "t1", "children":
    {name: count}, "tokens": n}``.
    """
    open_spans: Dict[tuple, List[dict]] = {}
    requests: Dict[str, List[dict]] = {}
    current: Dict[str, dict] = {}       # uid -> open request record

    def fail(msg, ev):
        raise ValueError(f"trace span error: {msg} (event {ev})")

    for ev in events:
        ph, name, uid = ev["ph"], ev["name"], ev.get("uid")
        key = (uid, name)
        if ph == "B":
            open_spans.setdefault(key, []).append(ev)
            if name == "request":
                if uid is None:
                    fail("request span without uid", ev)
                if uid in current:
                    fail(f"request {uid} re-begun while open", ev)
                rec = {"status": None, "t0": ev["ts"], "t1": None,
                       "children": {}, "tokens": 0,
                       "attrs": dict(ev.get("attrs", {}))}
                current[uid] = rec
                requests.setdefault(uid, []).append(rec)
            elif uid is not None and uid in current:
                c = current[uid]["children"]
                c[name] = c.get(name, 0) + 1
        elif ph == "E":
            stack = open_spans.get(key)
            if not stack:
                fail(f"end without begin: {name} uid={uid}", ev)
            stack.pop()
            if name == "request":
                rec = current.pop(uid, None)
                if rec is None:
                    fail(f"request end for unopened {uid}", ev)
                status = ev.get("attrs", {}).get("status")
                if status not in TERMINAL_STATUSES:
                    fail(f"request {uid} ended with non-terminal "
                         f"status {status!r}", ev)
                rec["status"] = status
                rec["t1"] = ev["ts"]
        elif ph == "i":
            if uid is not None and uid in current:
                rec = current[uid]
                rec["children"][name] = rec["children"].get(name, 0) + 1
                if name in ("token", "first_token"):
                    rec["tokens"] += 1
    dangling = [k for k, v in open_spans.items() if v]
    if dangling:
        raise ValueError(f"trace span error: unclosed spans {dangling}")
    for uid, recs in requests.items():
        for rec in recs:
            if "queue" not in rec["children"]:
                raise ValueError(
                    f"trace span error: request {uid} has no queue span")
    return requests


__all__ = ["Tracer", "TERMINAL_STATUSES", "default_tracer",
           "set_default_tracer", "load_jsonl", "chrome_trace",
           "write_chrome", "validate_spans"]
