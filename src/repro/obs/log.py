"""One obs-aware logger for every banner/status line in the stack.

ISSUE 9 satellite: ``backend.describe()`` banners, trainer step lines,
and scheduler supervision messages used to go through ad-hoc ``print``
and ``log=`` callables. They now share one stdlib logger tree rooted at
``"repro"`` with a single knob:

* ``REPRO_LOG_LEVEL`` — DEBUG | INFO | WARNING | ERROR (or a numeric
  level). Default: **INFO**, except **WARNING under pytest** (detected
  via ``PYTEST_CURRENT_TEST`` / an imported ``pytest`` module) so test
  output stays quiet without every suite silencing banners by hand.

``get_logger()`` configures the root handler exactly once (an idempotent
StreamHandler with the ``[repro.<sub>] msg`` format the old banners
used); ``set_level`` re-levels at runtime. CLI entrypoints that *are*
the user-facing output (examples, benchmarks) keep printing — this
module is for the library's own chatter."""
from __future__ import annotations

import logging
import os
import sys
import threading

_ENV_LEVEL = "REPRO_LOG_LEVEL"
_ROOT = "repro"
_configured = False
_lock = threading.Lock()


def _under_pytest() -> bool:
    return "PYTEST_CURRENT_TEST" in os.environ or "pytest" in sys.modules


def default_level() -> int:
    v = os.environ.get(_ENV_LEVEL)
    if v:
        v = v.strip().upper()
        if v.isdigit():
            return int(v)
        lvl = logging.getLevelName(v)
        if isinstance(lvl, int):
            return lvl
        raise ValueError(f"{_ENV_LEVEL}={v!r} is not a logging level "
                         "(DEBUG/INFO/WARNING/ERROR or an int)")
    return logging.WARNING if _under_pytest() else logging.INFO


class _Formatter(logging.Formatter):
    def format(self, record):
        return f"[{record.name}] {record.getMessage()}"


def _configure():
    global _configured
    with _lock:
        if _configured:
            return
        root = logging.getLogger(_ROOT)
        if not root.handlers:            # respect an app-installed handler
            h = logging.StreamHandler()
            h.setFormatter(_Formatter())
            root.addHandler(h)
            root.propagate = False
        root.setLevel(default_level())
        _configured = True


def get_logger(name: str = "") -> logging.Logger:
    """``get_logger("trainer")`` → the ``repro.trainer`` logger (lazy
    one-time handler/level setup on the ``repro`` root)."""
    _configure()
    return logging.getLogger(f"{_ROOT}.{name}" if name else _ROOT)


def set_level(level) -> None:
    """Programmatic re-level (accepts names or ints)."""
    _configure()
    if isinstance(level, str):
        lv = logging.getLevelName(level.strip().upper())
        if not isinstance(lv, int):
            raise ValueError(f"unknown log level {level!r}")
        level = lv
    logging.getLogger(_ROOT).setLevel(level)


def banner(msg: str, name: str = "") -> None:
    """An INFO status line (the ``backend.describe()`` class of output)."""
    get_logger(name).info(msg)


__all__ = ["get_logger", "set_level", "banner", "default_level"]
