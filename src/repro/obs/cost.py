"""Analytic per-kernel cost model: FLOP/byte estimators + roofline math.

ISSUE 10 tentpole §1 — ``benchmarks/roofline.py``'s three-term analysis
lifted into a library the observability tier can consult at runtime.
Estimators are keyed off the SAME plan objects the kernel backend
dispatches on (:func:`repro.core.ski.ski_plan` /
:func:`repro.core.tno.tno_plan`), so "what should this op cost" and
"which kernel actually ran" cannot drift apart:

* :func:`cost_of_plan` — dispatch on a ski/tno plan dict → per-kernel
  :class:`Cost` map (the kernel names match
  ``backend._DEFAULT_TARGETS`` / ``repro_kernel_dispatch_total``
  labels wherever a Pallas kernel exists).
* family estimators — ``short_conv_cost``, ``interp_cost``,
  ``gram_cost`` (dense/windowed/fft), ``fd_mul_cost``,
  ``fd_khat_grad_cost``, ``hilbert_window_cost``, ``rfft_cost``,
  ``ssd_cost``, ``attention_decode_cost``.
* :func:`decode_step_cost` — a whole engine decode step (embed + every
  layer's mixer + FFN + LM head) as a per-family map; this is what
  :func:`repro.obs.devstats.attribute_engine` uses to split measured
  engine seconds across kernel families.
* roofline: :func:`seconds` (compute/memory terms under a platform
  :class:`Peaks`), :func:`achieved_fraction` (roofline-implied time /
  measured time), :func:`xla_cost` (the
  ``jit(...).lower().compile().cost_analysis()`` cross-check the unit
  tests pin the estimators against).

Estimates are *models*, not measurements: they count the algorithmic
multiply-adds and the unavoidable HBM traffic of each family. The
cross-check test keeps them within a small factor of XLA's own
cost_analysis on concrete shapes; the roofline fractions they imply are
for ranking kernels and spotting order-of-magnitude waste, not for
benchmarking.
"""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Dict, Optional

#: per-chip peaks, from benchmarks/roofline.py (TPU v5e class)
TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9
TPU_ICI_BW = 50e9

_ENV_CPU_FLOPS = "REPRO_CPU_PEAK_FLOPS"
_ENV_CPU_BW = "REPRO_CPU_PEAK_BW"


@dataclasses.dataclass(frozen=True)
class Cost:
    """Algorithmic work of one kernel launch: floating-point operations
    and bytes moved to/from main memory (inputs + outputs, once each)."""
    flops: float
    bytes: float

    def __add__(self, other: "Cost") -> "Cost":
        return Cost(self.flops + other.flops, self.bytes + other.bytes)

    def scale(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k)


@dataclasses.dataclass(frozen=True)
class Peaks:
    """Per-device roofline ceilings (FLOP/s, memory B/s, interconnect
    B/s). ``collective_bw=0`` means no interconnect term."""
    flops: float
    mem_bw: float
    collective_bw: float = 0.0


def _env_float(name: str, default: float) -> float:
    v = os.environ.get(name)
    if v is None or v == "":
        return default
    try:
        return float(v)
    except ValueError:
        raise ValueError(f"{name}={v!r} is not a number") from None


def peaks(platform: Optional[str] = None) -> Peaks:
    """Roofline ceilings for a platform (default: the active backend).
    TPU numbers are the committed v5e constants; CPU defaults are a
    deliberately conservative laptop-class estimate, overridable via
    ``REPRO_CPU_PEAK_FLOPS`` / ``REPRO_CPU_PEAK_BW`` — on CPU the
    fractions rank kernels, they are not MFU claims."""
    if platform is None:
        from repro.kernels import backend
        platform = backend.platform()
    if platform == "tpu":
        return Peaks(TPU_PEAK_FLOPS, TPU_HBM_BW, TPU_ICI_BW)
    if platform == "gpu":
        return Peaks(60e12, 1.5e12, 0.0)       # A100-class ballpark
    return Peaks(_env_float(_ENV_CPU_FLOPS, 5e10),
                 _env_float(_ENV_CPU_BW, 2e10), 0.0)


def dtype_bytes(dtype) -> int:
    import jax.numpy as jnp
    return jnp.dtype(dtype).itemsize


def fft_flops(n: int) -> float:
    """Real-input FFT of length n: ~2.5·n·log2(n) (split-radix real
    transform; the standard roofline convention)."""
    return 2.5 * n * math.log2(max(n, 2))


# -------------------------------------------------- per-family estimators
def short_conv_cost(n: int, m: int, d: int, batch: int = 1,
                    elem: int = 4) -> Cost:
    """Depthwise m-tap conv over (b, n, d): one multiply-add per tap."""
    return Cost(2.0 * batch * n * m * d,
                elem * (2.0 * batch * n * d + d * m))


def interp_cost(n: int, r: int, d: int, batch: int = 1,
                elem: int = 4) -> Cost:
    """One hat-interpolation pass (reduce z=Wᵀx or expand y=Wz): two
    taps per position, multiply-add each."""
    return Cost(4.0 * batch * n * d,
                elem * (batch * n * d + batch * r * d) + 8.0 * n)


def gram_cost(variant: str, r: int, d: int, batch: int = 1,
              elem: int = 4, bw: Optional[int] = None) -> Cost:
    """Applying the r×r inducing Gram per channel: dense matvec,
    banded (width bw) matvec, or circulant FFT matvec (length 2r)."""
    if variant == "dense":
        return Cost(2.0 * batch * d * r * r,
                    elem * (d * r * r + 2.0 * batch * r * d))
    if variant == "windowed":
        if bw is None:
            from repro.kernels import backend
            bw = min(backend.band_budget(), r)
        return Cost(2.0 * batch * d * r * bw,
                    elem * (d * (2 * r - 1) + 2.0 * batch * r * d))
    if variant == "fft":
        n2 = 2 * r
        per_ch = 2 * fft_flops(n2) + 6.0 * n2     # fwd+inv FFT + pointwise
        return Cost(batch * d * per_ch,
                    elem * (d * (2 * r - 1) + 2.0 * batch * r * d))
    raise ValueError(f"unknown gram variant {variant!r} "
                     "(want dense|windowed|fft)")


def rfft_cost(n: int, d: int, batch: int = 1, elem: int = 4) -> Cost:
    """One real FFT (or inverse) of length n per (batch, channel)."""
    return Cost(batch * d * fft_flops(n),
                elem * 2.0 * batch * n * d)


def fd_mul_cost(n_f: int, d: int, batch: int = 1, elem: int = 4) -> Cost:
    """Pointwise complex spectral multiply over n_f frequency bins:
    6 real flops per complex multiply."""
    return Cost(6.0 * batch * n_f * d,
                elem * (4.0 * batch * n_f * d + 2.0 * n_f * d))


def fd_khat_grad_cost(n_f: int, d: int, batch: int = 1,
                      elem: int = 4) -> Cost:
    """Backward khat reduction: conjugated multiply + batch-sum."""
    return Cost(8.0 * batch * n_f * d,
                elem * (4.0 * batch * n_f * d + 2.0 * n_f * d))


def hilbert_window_cost(n: int, d: int, elem: int = 4) -> Cost:
    """Causal (analytic-signal) lag window over the (d, n) response."""
    return Cost(4.0 * d * n, elem * 2.0 * d * n)


def ssd_cost(n: int, d_inner: int, state: int, batch: int = 1,
             elem: int = 4) -> Cost:
    """Selective state-space scan: per token, a (d_inner × state) update
    and readout (~6 flops per element)."""
    return Cost(6.0 * batch * n * d_inner * state,
                elem * (2.0 * batch * n * d_inner
                        + batch * d_inner * state))


def attention_decode_cost(n_ctx: int, heads: int, head_dim: int,
                          batch: int = 1, elem: int = 4) -> Cost:
    """One decode step against an n_ctx KV cache: QK^T + AV."""
    return Cost(4.0 * batch * heads * n_ctx * head_dim,
                elem * 2.0 * batch * n_ctx * heads * head_dim)


def mlp_cost(d_model: int, d_ff: int, batch: int = 1, tokens: int = 1,
             elem: int = 4) -> Cost:
    """Gated FFN: up + gate + down projections per token."""
    t = batch * tokens
    return Cost(2.0 * t * d_model * d_ff * 3,
                elem * (3.0 * d_model * d_ff + 2.0 * t * d_model))


def lm_head_cost(d_model: int, vocab: int, batch: int = 1,
                 elem: int = 4) -> Cost:
    return Cost(2.0 * batch * d_model * vocab,
                elem * (d_model * vocab + batch * (d_model + vocab)))


# -------------------------------------------------------- plan dispatch
def ski_plan_cost(plan: dict, n: int, d: int, batch: int = 1,
                  elem: int = 4, m: int = 4) -> Dict[str, Cost]:
    """Per-kernel cost of one fused SKI-TNO forward under ``plan``
    (:func:`repro.core.ski.ski_plan`): pass-1 reduce, the Gram apply in
    the plan's variant, pass-2 expand, and the m-tap sparse correction.
    Kernel keys match the backend dispatch names: the dense variant's
    Gram+expand+conv run as one ``ski_fused`` launch; windowed/fft split
    into ``ski_windowed``/``ski_fft_gram`` + the Gram-free
    ``ski_expand2``."""
    r = int(plan["r"])
    variant = plan.get("variant", "dense" if "a_dense" in plan
                       else "unfused")
    reduce_c = interp_cost(n, r, d, batch, elem)
    expand_c = interp_cost(n, r, d, batch, elem)
    conv_c = short_conv_cost(n, m, d, batch, elem)
    if variant in ("dense", "unfused"):
        return {"interp_reduce": reduce_c,
                "ski_fused": gram_cost("dense", r, d, batch, elem)
                + expand_c + conv_c}
    if variant == "windowed":
        return {"interp_reduce": reduce_c,
                "ski_windowed": gram_cost("windowed", r, d, batch, elem),
                "ski_expand2": expand_c + conv_c}
    if variant == "fft":
        return {"interp_reduce": reduce_c,
                "ski_fft_gram": gram_cost("fft", r, d, batch, elem),
                "ski_expand2": expand_c + conv_c}
    raise ValueError(f"ski plan with unknown variant {variant!r}")


def fd_plan_cost(plan: dict, n: int, d: int, batch: int = 1,
                 elem: int = 4) -> Dict[str, Cost]:
    """Per-kernel cost of one causal/acausal FD-TNO forward under a
    :func:`repro.core.tno.tno_plan` fd plan: x rfft + spectral multiply
    + irfft, plus (causal plans, ``khat_real``) the Hilbert completion
    of the real response."""
    n_f = n + 1                       # rfft bins of the length-2n embed
    out = {"rfft": rfft_cost(2 * n, d, batch, elem).scale(2.0),
           "fd_mul": fd_mul_cost(n_f, d, batch, elem)}
    if "khat_real" in plan:
        out["hilbert_window"] = hilbert_window_cost(n, d, elem)
    return out


def cost_of_plan(plan: dict, *, n: int, d: int, batch: int = 1,
                 dtype=None, m: int = 4) -> Dict[str, Cost]:
    """Dispatch on the SAME plan objects the kernel layer receives:

    * ski plan (``{"variant", "r", ...}``) → :func:`ski_plan_cost`;
    * fd plan (``{"khat"}`` / ``{"khat_real"}``) → :func:`fd_plan_cost`;
    * baseline tno plan (``{"coef"}``) → circulant Toeplitz matvec.
    """
    elem = 4 if dtype is None else dtype_bytes(dtype)
    if "variant" in plan or "a_dense" in plan:
        return ski_plan_cost(plan, n, d, batch, elem, m)
    if "khat" in plan or "khat_real" in plan:
        return fd_plan_cost(plan, n, d, batch, elem)
    if "coef" in plan:
        # dense Toeplitz matvec via length-2n circular embedding
        return {"toeplitz_fft": rfft_cost(2 * n, d, batch, elem).scale(3.0)
                + fd_mul_cost(n + 1, d, batch, elem)}
    raise ValueError(
        f"unrecognised plan keys {sorted(plan)}: want a ski plan "
        "(variant/a_dense), an fd plan (khat/khat_real), or a baseline "
        "plan (coef)")


def decode_step_cost(cfg, batch: int, max_len: int,
                     dtype=None) -> Dict[str, Cost]:
    """One engine decode step (S=batch slots, one token each) against a
    ``max_len`` cache, split per kernel family — the analytic share map
    :func:`repro.obs.devstats.attribute_engine` projects measured engine
    seconds onto. Mixer families follow ``cfg.layers_spec`` (the same
    per-layer table the model builds from)."""
    elem = 4 if dtype is None else dtype_bytes(dtype)
    d = cfg.d_model
    out: Dict[str, Cost] = {}

    def add(key: str, c: Cost):
        out[key] = out.get(key, Cost(0.0, 0.0)) + c

    add("embed", Cost(0.0, elem * float(batch * d)))
    c_blk = None
    for mixer, _ffn in cfg.layers_spec:
        if mixer == "fd":
            # streaming decode: O(C·d) ring head per token, spectra
            # refresh amortised over C steps (one block rfft + multiply)
            if c_blk is None:
                from repro.kernels import backend
                c_blk = backend.fd_stream_block()
            head = short_conv_cost(1, c_blk, d, batch, elem)
            refresh = (rfft_cost(2 * c_blk, d, batch, elem)
                       + fd_mul_cost(c_blk + 1, d, batch, elem)
                       ).scale(1.0 / c_blk)
            add("fd_stream", head + refresh)
        elif mixer in ("tno", "ski"):
            # hist-replay decode: the full Toeplitz row against max_len
            add("tno_hist", Cost(2.0 * batch * max_len * d,
                                 elem * batch * max_len * d))
        elif mixer in ("attention", "local"):
            heads = max(getattr(cfg, "n_heads", 1), 1)
            hd = max(d // heads, 1)
            n_ctx = (min(max_len, cfg.window) if mixer == "local"
                     and cfg.window else max_len)
            add("attention", attention_decode_cost(
                n_ctx, heads, hd, batch, elem))
        elif mixer == "mamba":
            add("ssd", ssd_cost(1, cfg.d_inner,
                                getattr(cfg, "ssm_state", 16), batch, elem))
        else:
            add(mixer or "mixer", Cost(2.0 * batch * d, elem * batch * d))
        add("mixer_proj", Cost(2.0 * batch * d * d * 2,
                               elem * 2.0 * d * d))
        add("mlp", mlp_cost(d, cfg.d_ff, batch, 1, elem))
    add("lm_head", lm_head_cost(d, cfg.vocab_padded, batch, elem))
    return out


def total(costs: Dict[str, Cost]) -> Cost:
    t = Cost(0.0, 0.0)
    for c in costs.values():
        t = t + c
    return t


# ------------------------------------------------------------- roofline
def seconds(cost: Cost, pk: Optional[Peaks] = None) -> dict:
    """Roofline-implied times for one launch: compute and memory terms,
    the binding one, and its name."""
    pk = pk or peaks()
    t_comp = cost.flops / max(pk.flops, 1.0)
    t_mem = cost.bytes / max(pk.mem_bw, 1.0)
    t_star = max(t_comp, t_mem)
    return {"compute_s": t_comp, "memory_s": t_mem, "bound_s": t_star,
            "dominant": "compute" if t_comp >= t_mem else "memory"}


def achieved_fraction(cost: Cost, measured_s: float,
                      pk: Optional[Peaks] = None) -> float:
    """Fraction of the roofline bound achieved: (time the dominant
    roofline term implies) / (measured time). 1.0 = at the roof; small
    values mean the kernel leaves the machine idle (launch overhead,
    bad tiling, interpreter overhead on CPU)."""
    if measured_s <= 0:
        return float("nan")
    return seconds(cost, pk)["bound_s"] / measured_s


# ------------------------------------------------- XLA cost cross-check
def xla_cost(fn, *args, **kwargs) -> Optional[dict]:
    """``jit(fn).lower(*args).compile().cost_analysis()`` reduced to
    ``{"flops": f, "bytes": b}``. Returns None when the backend does not
    expose cost analysis (some CPU wheels) — callers/tests must skip,
    not fail. This is the estimator's ground truth on shapes small
    enough to compile in a test."""
    import jax
    try:
        compiled = jax.jit(fn).lower(*args, **kwargs).compile()
        ca = compiled.cost_analysis()
    except Exception:  # noqa: BLE001 — availability probe, not a code path
        return None
    if ca is None:
        return None
    if isinstance(ca, (list, tuple)):      # older jax: one dict per device
        ca = ca[0] if ca else None
        if ca is None:
            return None
    flops = float(ca.get("flops", 0.0))
    nbytes = sum(float(v) for k, v in ca.items()
                 if "bytes accessed" in k and isinstance(v, (int, float)))
    return {"flops": flops, "bytes": nbytes, "raw": dict(ca)}


__all__ = [
    "Cost", "Peaks", "peaks", "dtype_bytes", "fft_flops",
    "short_conv_cost", "interp_cost", "gram_cost", "rfft_cost",
    "fd_mul_cost", "fd_khat_grad_cost", "hilbert_window_cost",
    "ssd_cost", "attention_decode_cost", "mlp_cost", "lm_head_cost",
    "ski_plan_cost", "fd_plan_cost", "cost_of_plan", "decode_step_cost",
    "total", "seconds", "achieved_fraction", "xla_cost",
]
