"""Compile/retrace watchdog for memoised jit entry points (ISSUE 10
tentpole §3).

Every jit-compiled serving/training entry point in this repo is
shape-memoised by construction: the engine keeps ≤ 2 executables per
(batch, bucket) prefill shape, the StepBuilder one per serve-shape, the
trainer exactly one. A retrace outside those families is a silent
performance outage — each one costs seconds of XLA time on the hot
path and the old ``trace_counts`` dicts only surfaced it if a test
happened to look.

:class:`CompileWatch` wraps ``jax.jit`` so every *fresh trace* is:

* counted into ``repro_compiles_total{fn}``,
* timed into the ``repro_compile_seconds{fn}`` histogram (trace +
  compile + first execution — the latency a request actually felt),
* checked against the expected ceiling declared via :meth:`expect`,
  warning through the obs logger the moment a function exceeds its
  shape-family budget.

Detection reuses the repo's own retrace-pinning idiom (the engine's
``_make`` counted wrappers): a host-side side effect inside the traced
body fires exactly when JAX traces, never on cached executions. The
wrapper stays compatible with those counters — pass the already-counted
body in, both fire on the same trace.
"""
from __future__ import annotations

import functools
import threading
import time
from typing import Callable, Dict, Optional

from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics

#: compile latencies span ~50ms (tiny CPU smoke graphs) to minutes
#: (real-TPU Mosaic builds) — wider than the serving-latency default
COMPILE_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0,
                   60.0, 120.0, 300.0)


class CompileWatch:
    """Watches a family of jit entry points for compiles/retraces.

    ``wrap(name, fn, **jit_kwargs)`` returns a callable with the same
    signature as ``jax.jit(fn, **jit_kwargs)``; ``expect(name, n)``
    declares the shape-family ceiling (the warning threshold — counting
    is unconditional). ``counts()`` is the host-side mirror for tests.
    """

    def __init__(self, metrics=None, *, prefix: str = "",
                 logger=None):
        reg = metrics if metrics is not None \
            else obs_metrics.default_registry()
        self.prefix = prefix
        self._m_compiles = reg.counter(
            "repro_compiles_total",
            "fresh jit traces (compiles) per wrapped entry point",
            ("fn",))
        self._m_seconds = reg.histogram(
            "repro_compile_seconds",
            "wall seconds of calls that triggered a fresh trace "
            "(trace + compile + first run)",
            ("fn",), buckets=COMPILE_BUCKETS)
        self._log = logger or obs_log.get_logger("obs")
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {}
        self._expected: Dict[str, int] = {}
        self._tl = threading.local()

    # ------------------------------------------------------------ config
    def expect(self, name: str, max_traces: int) -> None:
        """Declare the retrace budget: warn when ``name`` exceeds it."""
        self._expected[name] = int(max_traces)

    def counts(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counts)

    def count(self, name: str) -> int:
        with self._lock:
            return self._counts.get(name, 0)

    # ---------------------------------------------------------- recording
    def _record(self, name: str, seconds: Optional[float]) -> None:
        with self._lock:
            self._counts[name] = n = self._counts.get(name, 0) + 1
        label = self.prefix + name
        self._m_compiles.labels(fn=label).inc()
        if seconds is not None:
            self._m_seconds.labels(fn=label).observe(seconds)
        exp = self._expected.get(name)
        if exp is not None and n > exp:
            self._log.warning(
                f"compile watchdog: {label} retraced ({n} traces > "
                f"expected {exp}) — a shape outside the memoised family "
                "reached this entry point")

    def _mark(self, name: str) -> None:
        """Called from inside a traced body: flag the innermost live
        call frame for ``name``. A trace with no live frame (AOT
        ``.lower()``, warmup helpers) still counts, just untimed."""
        stack = getattr(self._tl, "stack", None)
        if stack:
            for frame_name, cell in reversed(stack):
                if frame_name == name:
                    cell["traced"] = True
                    return
        self._record(name, None)

    # ------------------------------------------------------------- wrap
    def wrap(self, name: str, fn: Callable, **jit_kwargs) -> Callable:
        """``jax.jit`` with compile accounting. ``fn`` may already be a
        counted wrapper (the engine's ``_make``) — its side effect and
        this watchdog's fire on the same trace."""
        import jax

        def traced_body(*args, **kwargs):
            self._mark(name)
            return fn(*args, **kwargs)

        jitted = jax.jit(traced_body, **jit_kwargs)

        @functools.wraps(fn)
        def call(*args, **kwargs):
            stack = getattr(self._tl, "stack", None)
            if stack is None:
                stack = self._tl.stack = []
            cell = {"traced": False}
            stack.append((name, cell))
            t0 = time.perf_counter()
            try:
                return jitted(*args, **kwargs)
            finally:
                stack.pop()
                if cell["traced"]:
                    self._record(name, time.perf_counter() - t0)

        call.watch_name = name
        call.jitted = jitted
        return call


__all__ = ["CompileWatch", "COMPILE_BUCKETS"]
