"""Opt-in ``jax.profiler`` hooks around serving/training step regions.

ISSUE 9 tentpole §4: set ``REPRO_PROFILE_DIR=/path`` and the scheduler
(and trainer) bracket their run loops in a ``jax.profiler`` trace
session writing TensorBoard-loadable protos there, with named
``TraceAnnotation`` regions around prefill / decode / train steps so
the device timeline is attributable to serving phases. With the env
unset every hook is a no-op ``nullcontext`` — zero overhead, nothing
imported beyond this module.

The profiler can genuinely fail to start (no profiler plugin in a
stripped CPU wheel, a second concurrent session, a read-only dir);
``session`` degrades to a logged warning instead of taking down the
serving loop — observability must never become the outage."""
from __future__ import annotations

import contextlib
import os

from repro.obs import log as obs_log

_ENV_DIR = "REPRO_PROFILE_DIR"


def profile_dir() -> str | None:
    v = os.environ.get(_ENV_DIR)
    return v or None


@contextlib.contextmanager
def session(name: str = "run"):
    """Bracket a region in a ``jax.profiler`` trace when
    ``REPRO_PROFILE_DIR`` is set; no-op otherwise. Never raises."""
    d = profile_dir()
    if d is None:
        yield False
        return
    import jax
    started = False
    try:
        jax.profiler.start_trace(d)
        started = True
        obs_log.get_logger("obs").info(
            f"profiler session '{name}' -> {d}")
    except Exception as e:  # noqa: BLE001 — never fail the serving loop
        obs_log.get_logger("obs").warning(
            f"profiler session '{name}' failed to start: {e!r}")
    try:
        yield started
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception as e:  # noqa: BLE001
                obs_log.get_logger("obs").warning(
                    f"profiler stop failed: {e!r}")


def annotation(name: str):
    """Named sub-region (shows as a band on the profiler timeline).
    Cheap nullcontext when no profile dir is configured."""
    if profile_dir() is None:
        return contextlib.nullcontext()
    import jax
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # noqa: BLE001
        return contextlib.nullcontext()


__all__ = ["profile_dir", "session", "annotation"]
