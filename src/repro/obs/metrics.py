"""Host-side metrics registry: counters, gauges, fixed-bucket histograms.

The serving/training observability substrate (ISSUE 9 tentpole §1). Three
instrument kinds, all **host-side floats under one registry lock** — an
``inc``/``observe`` on the decode hot path is a dict lookup and a float
add, never a device sync, never an allocation after the first call for a
given label set:

* :class:`Counter` — monotone ``inc(n)``; per-label-set children.
* :class:`Gauge` — ``set``/``inc``/``dec``; last-write-wins.
* :class:`Histogram` — fixed cumulative buckets chosen at registration
  (Prometheus ``le`` semantics: ``observe(x)`` increments every bucket
  with ``x <= le``, plus ``sum`` and ``count``).

Exports:

* :meth:`Registry.render_prometheus` — the text exposition format
  (``# HELP`` / ``# TYPE`` / samples) that ``promtool``/Prometheus
  scrape; histograms emit ``_bucket{le=...}``/``_sum``/``_count``.
* :meth:`Registry.to_dict` / :meth:`Registry.dump_json` — a JSON mirror
  for ``tools/obs_report.py`` and test assertions.

**Off-by-default-cheap**: the process-wide default registry
(:func:`default_registry`) is a real :class:`Registry` only when
``REPRO_METRICS`` is truthy; otherwise it is :data:`NULL_REGISTRY`,
whose instruments are shared no-op singletons — an un-instrumented run
pays one attribute load and a no-op call per site. Launchers/tests that
want metrics regardless of the env construct an explicit
:class:`Registry` and pass it down (``Scheduler(metrics=...)``,
``Engine(metrics=...)``, ``Trainer`` via its registry argument).

Registration is idempotent: asking for an existing name returns the
existing collector (kind and labelnames must match — a silent kind
collision would corrupt the exposition).
"""
from __future__ import annotations

import atexit
import json
import os
import threading
from typing import Dict, Iterable, Optional, Sequence, Tuple

_ENV_METRICS = "REPRO_METRICS"
_ENV_METRICS_FILE = "REPRO_METRICS_FILE"

#: default histogram buckets (seconds) — serving latencies span ~100µs
#: (one CPU smoke decode step) to ~10s (a cold packed prefill compile)
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def metrics_enabled() -> bool:
    v = os.environ.get(_ENV_METRICS)
    if v is None or v == "":
        return False
    return v.strip().lower() not in ("0", "false", "off", "no")


def _check_name(name: str):
    if not name or not all(c.isalnum() or c == "_" for c in name):
        raise ValueError(f"metric name {name!r}: want [a-zA-Z0-9_]+ "
                         "(Prometheus exposition identifier)")


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace(
        "\n", "\\n")


def _fmt(x: float) -> str:
    """Prometheus sample value: integers render without the trailing .0
    (``17`` not ``17.0``) — promtool accepts both, humans prefer one."""
    f = float(x)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _Child:
    """One label-set's value cell. All mutation under the parent lock."""

    __slots__ = ("_metric", "_labels", "value", "bucket_counts", "sum",
                 "count")

    def __init__(self, metric: "_Metric", labels: Tuple[str, ...]):
        self._metric = metric
        self._labels = labels
        self.value = 0.0
        if metric.kind == "histogram":
            self.bucket_counts = [0] * len(metric.buckets)
            self.sum = 0.0
            self.count = 0

    # ---- counter / gauge
    def inc(self, n: float = 1.0):
        if self._metric.kind == "counter" and n < 0:
            raise ValueError(f"counter {self._metric.name}: inc({n}) < 0")
        with self._metric._lock:
            self.value += n

    def dec(self, n: float = 1.0):
        if self._metric.kind != "gauge":
            raise TypeError(f"{self._metric.kind} {self._metric.name} "
                            "has no dec()")
        with self._metric._lock:
            self.value -= n

    def set(self, v: float):
        if self._metric.kind != "gauge":
            raise TypeError(f"{self._metric.kind} {self._metric.name} "
                            "has no set()")
        with self._metric._lock:
            self.value = float(v)

    # ---- histogram
    def observe(self, x: float):
        if self._metric.kind != "histogram":
            raise TypeError(f"{self._metric.kind} {self._metric.name} "
                            "has no observe()")
        x = float(x)
        with self._metric._lock:
            for i, le in enumerate(self._metric.buckets):
                if x <= le:
                    self.bucket_counts[i] += 1
            self.sum += x
            self.count += 1

    def get(self) -> float:
        with self._metric._lock:
            return self.count if self._metric.kind == "histogram" \
                else self.value


class _Metric:
    """One named collector (counter | gauge | histogram) with labeled
    children. ``labels(**kw)`` memoises the child per label-value tuple
    so the hot path after the first call is a dict hit."""

    def __init__(self, name: str, help: str, kind: str,
                 labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        _check_name(name)
        for ln in labelnames:
            _check_name(ln)
        self.name = name
        self.help = help
        self.kind = kind
        self.labelnames = tuple(labelnames)
        if kind == "histogram":
            bs = tuple(sorted(float(b) for b in buckets))
            if len(set(bs)) != len(bs) or not bs:
                raise ValueError(f"histogram {name}: buckets must be "
                                 f"non-empty and strictly increasing: {bs}")
            self.buckets = bs
        else:
            self.buckets = ()
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], _Child] = {}
        if not self.labelnames:          # unlabeled: one eager child
            self._children[()] = _Child(self, ())

    def labels(self, **kw) -> _Child:
        if set(kw) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(kw)} != declared "
                f"{sorted(self.labelnames)}")
        key = tuple(str(kw[ln]) for ln in self.labelnames)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, _Child(self, key))
        return child

    # unlabeled convenience: metric.inc() == metric.labels().inc()
    def _solo(self) -> _Child:
        if self.labelnames:
            raise ValueError(f"{self.name} has labels {self.labelnames}; "
                             "use .labels(...)")
        return self._children[()]

    def inc(self, n: float = 1.0):
        self._solo().inc(n)

    def dec(self, n: float = 1.0):
        self._solo().dec(n)

    def set(self, v: float):
        self._solo().set(v)

    def observe(self, x: float):
        self._solo().observe(x)

    def get(self, **kw) -> float:
        return (self.labels(**kw) if kw else self._solo()).get()

    def samples(self) -> Iterable[tuple]:
        """(suffix, label_pairs, value) rows, snapshot under the lock."""
        with self._lock:
            items = sorted(self._children.items())
            for key, ch in items:
                pairs = tuple(zip(self.labelnames, key))
                if self.kind == "histogram":
                    # bucket_counts[i] is already cumulative (observe
                    # increments every bucket x fits under), matching
                    # Prometheus `le` semantics — emit directly
                    for le, c in zip(self.buckets, ch.bucket_counts):
                        yield ("_bucket", pairs + (("le", _fmt(le)),), c)
                    yield ("_bucket", pairs + (("le", "+Inf"),), ch.count)
                    yield ("_sum", pairs, ch.sum)
                    yield ("_count", pairs, ch.count)
                else:
                    yield ("", pairs, ch.value)


class Registry:
    """Named collectors under one roof; see module docstring."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _register(self, name, help, kind, labelnames, buckets) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if m.kind != kind or m.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name} re-registered as {kind}"
                        f"{tuple(labelnames)} but exists as {m.kind}"
                        f"{m.labelnames}")
                return m
            m = _Metric(name, help, kind, labelnames, buckets)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> _Metric:
        return self._register(name, help, "counter", labelnames, ())

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> _Metric:
        return self._register(name, help, "gauge", labelnames, ())

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> _Metric:
        return self._register(name, help, "histogram", labelnames, buckets)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def collect(self):
        with self._lock:
            return sorted(self._metrics.values(), key=lambda m: m.name)

    # ------------------------------------------------------------- export
    def render_prometheus(self) -> str:
        """Text exposition format (version 0.0.4)."""
        out = []
        for m in self.collect():
            if m.help:
                out.append(f"# HELP {m.name} {_escape(m.help)}")
            out.append(f"# TYPE {m.name} {m.kind}")
            for suffix, pairs, value in m.samples():
                if pairs:
                    lbl = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
                    out.append(f"{m.name}{suffix}{{{lbl}}} {_fmt(value)}")
                else:
                    out.append(f"{m.name}{suffix} {_fmt(value)}")
        return "\n".join(out) + ("\n" if out else "")

    def to_dict(self) -> dict:
        """JSON mirror: {name: {kind, help, labelnames, series: [...]}}.
        Histogram series carry buckets/counts/sum/count; scalar series a
        single value."""
        out = {}
        for m in self.collect():
            series = []
            with m._lock:
                for key, ch in sorted(m._children.items()):
                    row = {"labels": dict(zip(m.labelnames, key))}
                    if m.kind == "histogram":
                        row.update(buckets=list(m.buckets),
                                   counts=list(ch.bucket_counts),
                                   sum=ch.sum, count=ch.count)
                    else:
                        row["value"] = ch.value
                    series.append(row)
            out[m.name] = {"kind": m.kind, "help": m.help,
                           "labelnames": list(m.labelnames),
                           "series": series}
        return out

    def dump_json(self, path: str):
        with open(path, "w") as f:
            json.dump({"version": 1, "metrics": self.to_dict()}, f,
                      indent=1, sort_keys=True)
            f.write("\n")

    def dump_prometheus(self, path: str):
        with open(path, "w") as f:
            f.write(self.render_prometheus())


# ------------------------------------------------------------ null objects
class _NoopChild:
    __slots__ = ()

    def inc(self, n: float = 1.0):
        pass

    def dec(self, n: float = 1.0):
        pass

    def set(self, v: float):
        pass

    def observe(self, x: float):
        pass

    def get(self, **kw) -> float:
        return 0.0

    def labels(self, **kw) -> "_NoopChild":
        return self


_NOOP = _NoopChild()


class NullRegistry:
    """The disabled default: every instrument is one shared no-op."""

    def counter(self, *a, **kw):
        return _NOOP

    def gauge(self, *a, **kw):
        return _NOOP

    def histogram(self, *a, **kw):
        return _NOOP

    def get(self, name):
        return None

    def collect(self):
        return []

    def render_prometheus(self) -> str:
        return ""

    def to_dict(self) -> dict:
        return {}

    def dump_json(self, path: str):
        with open(path, "w") as f:
            json.dump({"version": 1, "metrics": {}}, f)
            f.write("\n")

    def dump_prometheus(self, path: str):
        with open(path, "w") as f:
            f.write("")


NULL_REGISTRY = NullRegistry()

_default: Registry | NullRegistry | None = None
_default_lock = threading.Lock()
_atexit_registered = False


def _dump_default_registry() -> None:
    """atexit hook: final metrics dump to ``REPRO_METRICS_FILE`` —
    without it a process that exits mid-run (chaos kills, cron smoke
    jobs) leaves no exposition at all (ISSUE 10 satellite). ``.json``
    suffix selects the JSON mirror, anything else the Prometheus text
    format (matching ``launch/serve.py --metrics-file``)."""
    path = os.environ.get(_ENV_METRICS_FILE)
    with _default_lock:
        reg = _default
    if not path or reg is None:
        return
    try:
        if path.endswith(".json"):
            reg.dump_json(path)
        else:
            reg.dump_prometheus(path)
    except Exception:  # noqa: BLE001 — never fail interpreter exit
        pass


def default_registry():
    """The process-wide registry: real when ``REPRO_METRICS`` is truthy
    at first use (or when ``REPRO_METRICS_FILE`` names a final dump
    target, which implies metrics), else the shared
    :data:`NULL_REGISTRY`. Explicit registries passed to
    Scheduler/Engine/Trainer bypass this. When ``REPRO_METRICS_FILE``
    is set, an ``atexit`` hook writes the final exposition there."""
    global _default, _atexit_registered
    if _default is None:
        with _default_lock:
            if _default is None:
                want = metrics_enabled() or bool(
                    os.environ.get(_ENV_METRICS_FILE))
                _default = Registry() if want else NULL_REGISTRY
                if want and not _atexit_registered:
                    atexit.register(_dump_default_registry)
                    _atexit_registered = True
    return _default


def set_default_registry(reg) -> None:
    """Programmatic override (tests, launchers); None re-resolves from
    the environment on next use. The final-dump atexit hook follows
    whatever the default is at exit."""
    global _default, _atexit_registered
    with _default_lock:
        _default = reg
        if reg is not None and not _atexit_registered and \
                os.environ.get(_ENV_METRICS_FILE):
            atexit.register(_dump_default_registry)
            _atexit_registered = True


class MirroredCounts(dict):
    """A plain dict of int counters that mirrors increments into a
    labeled registry counter — the bridge that keeps the engine's
    test-pinned ``trace_counts[name]`` reads working while the same
    counts appear in ``/metrics`` output (ISSUE 9 satellite)."""

    def __init__(self, initial: dict, counter, label: str):
        super().__init__(initial)
        self._counter = counter
        self._label = label

    def __setitem__(self, key, value):
        old = dict.get(self, key, 0)
        if value > old:
            self._counter.labels(**{self._label: key}).inc(value - old)
        dict.__setitem__(self, key, value)


__all__ = ["Registry", "NullRegistry", "NULL_REGISTRY", "MirroredCounts",
           "DEFAULT_BUCKETS", "default_registry", "set_default_registry",
           "metrics_enabled"]
