"""Training runtime: fault-tolerant step loop.

Responsibilities (DESIGN §5 "1000+-node posture"):

* **Checkpoint/restart** — async manifest checkpoints every
  ``ckpt_every`` steps; on start, auto-resume from the latest committed
  step (params + optimizer state + data cursor).
* **Preemption** — SIGTERM/SIGINT triggers a final synchronous
  checkpoint, then a clean exit (the cluster scheduler restarts the job
  and it resumes exactly where it stopped).
* **Step retry** — transient failures (injected in tests via
  ``failure_hook``; on real fleets: ICI timeouts, host OOM) retry the
  same step up to ``max_retries`` times. The data pipeline is stateless
  so a retried step re-reads the identical batch, and because
  ``train_step`` donates its state buffers, retries rebuild the state
  from an undonated host-side copy taken before the attempt
  (``undonated_retry_copy``) — never from buffers a failed attempt may
  have invalidated.
* **Straggler monitor** — per-step wall time EMA; steps slower than
  ``straggler_factor``× the EMA are logged with their step index. On a
  real fleet this feeds the scheduler's hot-spare swap; here it is a
  hook + a counter observable by tests.
* **NaN guard** — non-finite loss aborts the step and retries (on real
  hardware this catches SDC / chip faults; persistent NaN raises).
* **Dispatch banner** — ``run()`` logs the kernel backend policy
  (platform / use_pallas / pallas_grad, ``backend.describe()``) once at
  startup: a training run silently on the wrong path (e.g. reference
  kernels on TPU, or ``REPRO_PALLAS_GRAD=0`` left over from a debugging
  session) is visible in the first line of the step log.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manifest as ckpt
from repro.data.pipeline import DataConfig, batch_at
from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.obs import profiling as obs_prof


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep_ckpts: int = 3
    max_retries: int = 2
    straggler_factor: float = 3.0
    ema_alpha: float = 0.1
    log_every: int = 10
    # train_step is jit'd with donated state: a step that fails *after*
    # the call consumed its buffers leaves `state` invalidated, so a
    # naive retry replays the step on dead arrays. When retries are
    # enabled this keeps an undonated host-side copy of the state and
    # rebuilds from it on retry (cost: one host transfer per step —
    # disable for max-throughput runs that accept retry-unsafety).
    undonated_retry_copy: bool = True


class StragglerMonitor:
    """EMA step-time outlier detector."""

    def __init__(self, factor: float, alpha: float):
        self.factor = factor
        self.alpha = alpha
        self.ema: Optional[float] = None
        self.flagged: list = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.factor * self.ema
        if is_straggler:
            self.flagged.append((step, dt, self.ema))
        # EMA excludes outliers so one straggler doesn't mask the next
        if not is_straggler:
            self.ema = dt if self.ema is None else (
                (1 - self.alpha) * self.ema + self.alpha * dt)
        return is_straggler


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 data_cfg: DataConfig, *,
                 put_batch: Optional[Callable] = None,
                 failure_hook: Optional[Callable[[int, int], None]] = None,
                 log: Optional[Callable[[str], None]] = None,
                 metrics=None):
        """``train_step(state, batch) -> (state, metrics)`` must be jit'd
        with donated state. ``put_batch(host_batch) -> device batch``
        places host numpy onto the mesh (identity by default).
        ``failure_hook(step, attempt)`` may raise to inject failures.
        ``metrics`` is an obs registry (default: the process registry —
        a no-op unless ``REPRO_METRICS``); ``log`` defaults to the obs
        logger (``REPRO_LOG_LEVEL``; quiet under pytest)."""
        self.cfg = cfg
        self.train_step = train_step
        self.data_cfg = data_cfg
        self.put_batch = put_batch or (lambda b: b)
        self.failure_hook = failure_hook
        self.log = log or obs_log.get_logger("trainer").info
        self.metrics = (metrics if metrics is not None
                        else obs_metrics.default_registry())
        m = self.metrics
        self._m_steps = m.counter(
            "repro_train_steps_total", "training steps completed")
        self._m_retries = m.counter(
            "repro_train_retries_total", "training step retries")
        self._m_stragglers = m.counter(
            "repro_train_stragglers_total", "steps flagged as stragglers")
        self._m_ckpts = m.counter(
            "repro_train_checkpoints_total",
            "checkpoint saves issued", ("mode",))
        self._m_step_s = m.histogram(
            "repro_train_step_seconds", "train_step wall time")
        self._m_loss = m.gauge(
            "repro_train_loss", "last finite training loss")
        self._m_tok_s = m.gauge(
            "repro_train_tokens_per_s", "training throughput, last step")
        self.monitor = StragglerMonitor(cfg.straggler_factor, cfg.ema_alpha)
        self.ckpt = (ckpt.AsyncCheckpointer(cfg.ckpt_dir, cfg.keep_ckpts)
                     if cfg.ckpt_dir else None)
        self._preempted = False
        self.metrics_history: list = []

    # ---------------------------------------------------------- signals
    def _install_signals(self):
        def handler(signum, frame):
            self._preempted = True
            self.log(f"[trainer] signal {signum}: checkpoint-and-exit requested")
        self._old = {s: signal.signal(s, handler)
                     for s in (signal.SIGTERM, signal.SIGINT)}

    def _restore_signals(self):
        for s, h in getattr(self, "_old", {}).items():
            signal.signal(s, h)

    # ------------------------------------------------------------- ckpt
    def _save(self, step: int, state: Any, *, sync: bool = False):
        if self.ckpt is None:
            return
        extra = {"data_step": step}
        if sync:
            self.ckpt.wait()
            host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
            ckpt.save(self.cfg.ckpt_dir, step, host, extra=extra)
        else:
            self.ckpt.save_async(step, state, extra=extra)

    def try_restore(self, state_like: Any, shardings: Any = None):
        """Returns (state, start_step) — (state_like, 0) if no checkpoint."""
        if self.cfg.ckpt_dir is None:
            return state_like, 0
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            return state_like, 0
        state, extra = ckpt.restore(self.cfg.ckpt_dir, state_like,
                                    step=step, shardings=shardings)
        self.log(f"[trainer] restored step {step}")
        return state, int(extra.get("data_step", step))

    # -------------------------------------------------------------- run
    def run(self, state: Any, start_step: int = 0) -> Any:
        from repro.kernels import backend
        self.log(f"[trainer] kernel dispatch: {backend.describe()}")
        self._install_signals()
        prof = obs_prof.session("train")   # no-op unless REPRO_PROFILE_DIR
        prof.__enter__()
        try:
            step = start_step
            while step < self.cfg.total_steps and not self._preempted:
                batch = self.put_batch(batch_at(self.data_cfg, step))
                state, metrics = self._step_with_retry(step, state, batch)
                self.metrics_history.append(metrics)
                self._m_steps.inc()
                if self.cfg.log_every and step % self.cfg.log_every == 0:
                    ms = {k: float(v) for k, v in metrics.items()}
                    self.log(f"[trainer] step {step}: {ms}")
                step += 1
                if self.ckpt and step % self.cfg.ckpt_every == 0:
                    self._save(step, state)
                    self._m_ckpts.labels(mode="async").inc()
            if self.ckpt:
                self._save(step, state, sync=True)   # final / preemption save
                self._m_ckpts.labels(mode="sync").inc()
            return state, step
        finally:
            self._restore_signals()
            prof.__exit__(None, None, None)

    def _step_with_retry(self, step: int, state: Any, batch: Any):
        last_err: Optional[BaseException] = None
        backup = None
        if self.cfg.max_retries > 0 and self.cfg.undonated_retry_copy:
            # donated-buffer hazard: keep a host-side reference so a
            # retry never reuses buffers a failed attempt invalidated
            backup = jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), state)
        for attempt in range(self.cfg.max_retries + 1):
            try:
                if attempt > 0 and backup is not None:
                    state = jax.tree.map(jnp.asarray, backup)
                if self.failure_hook is not None:
                    self.failure_hook(step, attempt)
                t0 = time.perf_counter()
                with obs_prof.annotation("train_step"):
                    new_state, metrics = self.train_step(state, batch)
                loss = metrics.get("loss")
                if loss is not None and not np.isfinite(float(loss)):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                dt = time.perf_counter() - t0
                self._m_step_s.observe(dt)
                if loss is not None:
                    self._m_loss.set(float(loss))
                if isinstance(batch, dict) and "tokens" in batch and dt > 0:
                    self._m_tok_s.set(
                        float(np.asarray(batch["tokens"]).size) / dt)
                if self.monitor.observe(step, dt):
                    self._m_stragglers.inc()
                    self.log(f"[trainer] straggler: step {step} took {dt:.3f}s "
                             f"(ema {self.monitor.ema:.3f}s)")
                return new_state, metrics
            except (FloatingPointError, RuntimeError, ValueError) as e:
                last_err = e
                if attempt < self.cfg.max_retries:
                    self._m_retries.inc()
                self.log(f"[trainer] step {step} attempt {attempt} failed: {e}")
        raise RuntimeError(
            f"step {step} failed after {self.cfg.max_retries + 1} attempts"
        ) from last_err
