from repro.runtime.trainer import StragglerMonitor, Trainer, TrainerConfig
