"""Render serving observability artifacts into a human report (CLI).

Consumes the two ISSUE 9 artifact kinds:

* a **metrics dump** — the Prometheus text exposition or JSON file
  written by ``--metrics-file`` (launch/serve.py, examples, benchmarks)
  or ``Registry.dump_*``;
* a **trace file** — the JSONL span stream written by ``--trace-file``
  or ``REPRO_TRACE_FILE``.

and prints a latency/throughput summary (request counts by terminal
status, TTFT/TPOT/step-time percentiles reconstructed from spans,
fault/retry tallies). Also the artifact Swiss-army knife for CI:

    python tools/obs_report.py --trace t.jsonl --metrics m.prom
    python tools/obs_report.py --trace t.jsonl --check     # validate only
    python tools/obs_report.py --trace t.jsonl --chrome out.json

``--check`` exits non-zero unless every request span tree is complete
(every begin ended, terminal status present, queue child present) —
the machine contract from :func:`repro.obs.tracing.validate_spans`;
the serve/chaos CI smoke jobs gate on it. ``--chrome`` converts the
JSONL to Chrome ``trace_event`` JSON for chrome://tracing / Perfetto.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import tracing  # noqa: E402


def _pct(xs, q):
    if not xs:
        return float("nan")
    xs = sorted(xs)
    i = min(len(xs) - 1, max(0, int(round(q / 100 * (len(xs) - 1)))))
    return xs[i]


def _fmt_s(x):
    return "-" if x != x else (f"{x * 1e3:.2f}ms" if x < 1 else f"{x:.3f}s")


def report_trace(events) -> dict:
    """Span-derived serving report: terminal statuses, per-request
    TTFT (request begin → first_token), inter-token gaps, decode-step
    walls, fault/retry instants."""
    spans = tracing.validate_spans(events)
    statuses: dict = {}
    ttft, tpot, steps, faults, retries = [], [], [], 0, 0
    # per-uid instant timestamps for TTFT/TPOT reconstruction
    first_tok: dict = {}
    last_tok: dict = {}
    for ev in events:
        name, uid, ts = ev["name"], ev.get("uid"), ev["ts"]
        if ev["ph"] == "i":
            if name == "first_token" and uid is not None:
                first_tok.setdefault(uid, ts)
                last_tok[uid] = ts
            elif name == "token" and uid is not None:
                if uid in last_tok:
                    tpot.append(ts - last_tok[uid])
                last_tok[uid] = ts
            elif name == "fault":
                faults += 1
            elif name == "retry":
                retries += 1
        elif ev["ph"] == "E" and name == "step":
            pass
    # step walls from B/E pairs on the global track
    open_step = []
    for ev in events:
        if ev["name"] != "step":
            continue
        if ev["ph"] == "B":
            open_step.append(ev["ts"])
        elif ev["ph"] == "E" and open_step:
            steps.append(ev["ts"] - open_step.pop())
    n_spans = 0
    for uid, recs in spans.items():
        for rec in recs:
            n_spans += 1
            statuses[rec["status"]] = statuses.get(rec["status"], 0) + 1
            if uid in first_tok and first_tok[uid] >= rec["t0"] and (
                    rec["t1"] is None or first_tok[uid] <= rec["t1"]):
                ttft.append(first_tok[uid] - rec["t0"])
    return {
        "requests": len(spans), "request_spans": n_spans,
        "statuses": statuses,
        "ttft": ttft, "tpot": tpot, "step": steps,
        "faults": faults, "retries": retries,
    }


def load_metrics(path: str) -> dict:
    """Parse a metrics dump — JSON (Registry.dump_json) or the
    Prometheus text exposition — into {metric_name: [(labels, value)]}
    (histograms keep their _bucket/_sum/_count sample names)."""
    text = pathlib.Path(path).read_text()
    out: dict = {}
    if path.endswith(".json"):
        data = json.loads(text).get("metrics", {})
        for name, m in data.items():
            for s in m.get("series", []):
                labels = s.get("labels", {})
                if "value" in s:
                    out.setdefault(name, []).append((labels, s["value"]))
                else:                      # histogram series
                    out.setdefault(name + "_count", []).append(
                        (labels, float(s.get("count", 0))))
                    out.setdefault(name + "_sum", []).append(
                        (labels, float(s.get("sum", 0.0))))
        return out
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        head, _, val = line.rpartition(" ")
        if "{" in head:
            name, _, rest = head.partition("{")
            labels = dict(p.split("=", 1) for p in
                          rest.rstrip("}").split(",") if "=" in p)
            labels = {k: v.strip('"') for k, v in labels.items()}
        else:
            name, labels = head, {}
        out.setdefault(name, []).append((labels, float(val)))
    return out


def print_report(trace_path=None, metrics_path=None, out=print):
    if trace_path:
        events = tracing.load_jsonl(trace_path)
        r = report_trace(events)
        out(f"trace: {trace_path} ({len(events)} events)")
        out(f"  requests: {r['requests']} uids, {r['request_spans']} "
            f"span trees; statuses={r['statuses']}")
        for key, label in (("ttft", "TTFT"), ("tpot", "TPOT"),
                           ("step", "decode step")):
            xs = r[key]
            out(f"  {label}: n={len(xs)} p50={_fmt_s(_pct(xs, 50))} "
                f"p90={_fmt_s(_pct(xs, 90))} p99={_fmt_s(_pct(xs, 99))}")
        out(f"  faults injected: {r['faults']}; retries: {r['retries']}")
    if metrics_path:
        m = load_metrics(metrics_path)
        out(f"metrics: {metrics_path} ({len(m)} series)")
        for name in sorted(m):
            if name.endswith(("_bucket", "_sum")):
                continue
            for labels, val in m[name]:
                lbl = ("{" + ",".join(f"{k}={v}" for k, v in
                                      sorted(labels.items())) + "}"
                       if labels else "")
                out(f"  {name}{lbl} = {val:g}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarise / validate / convert obs artifacts")
    ap.add_argument("--trace", default=None,
                    help="span JSONL (REPRO_TRACE_FILE / --trace-file)")
    ap.add_argument("--metrics", default=None,
                    help="metrics dump (.prom text exposition or .json)")
    ap.add_argument("--chrome", default=None, metavar="OUT",
                    help="also write the trace as Chrome trace_event "
                         "JSON (Perfetto-loadable)")
    ap.add_argument("--check", action="store_true",
                    help="validate span completeness only; exit 1 on any "
                         "violation (CI smoke gate)")
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to do: pass --trace and/or --metrics")
    if (args.chrome or args.check) and not args.trace:
        ap.error("--chrome/--check need --trace")
    if args.check:
        events = tracing.load_jsonl(args.trace)
        try:
            spans = tracing.validate_spans(events)
        except ValueError as e:
            print(f"[obs-report] FAIL: {e}")
            return 1
        n = sum(len(v) for v in spans.values())
        print(f"[obs-report] OK: {len(spans)} uids, {n} complete "
              f"request span trees")
        if args.chrome:
            tracing.write_chrome(events, args.chrome)
            print(f"[obs-report] chrome trace: {args.chrome}")
        return 0
    print_report(args.trace, args.metrics)
    if args.chrome:
        tracing.write_chrome(tracing.load_jsonl(args.trace), args.chrome)
        print(f"[obs-report] chrome trace: {args.chrome}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
