"""Render serving observability artifacts into a human report (CLI).

Consumes the two ISSUE 9 artifact kinds:

* a **metrics dump** — the Prometheus text exposition or JSON file
  written by ``--metrics-file`` (launch/serve.py, examples, benchmarks)
  or ``Registry.dump_*``;
* a **trace file** — the JSONL span stream written by ``--trace-file``
  or ``REPRO_TRACE_FILE``.

and prints a latency/throughput summary (request counts by terminal
status, TTFT/TPOT/step-time percentiles reconstructed from spans,
fault/retry tallies). Also the artifact Swiss-army knife for CI:

    python tools/obs_report.py --trace t.jsonl --metrics m.prom
    python tools/obs_report.py --trace t.jsonl --check     # validate only
    python tools/obs_report.py --trace t.jsonl --chrome out.json

``--check`` exits non-zero unless every request span tree is complete
(every begin ended, terminal status present, queue child present) —
the machine contract from :func:`repro.obs.tracing.validate_spans`;
the serve/chaos CI smoke jobs gate on it. ``--chrome`` converts the
JSONL to Chrome ``trace_event`` JSON for chrome://tracing / Perfetto.

ISSUE 10 views:

* TTFT/TPOT p50/p99 are derived from the registry **histograms** as
  well as from spans whenever both artifacts are given, and any
  disagreement beyond the containing bucket's width is flagged
  (``DISAGREE``) — the cheap cross-check that catches histogram
  mirroring bugs.
* ``--kernels`` — the kernel-tier table: dispatch counts
  (``repro_kernel_dispatch_total``), attributed seconds
  (``repro_kernel_seconds_total``) with roofline fractions
  (``repro_kernel_roofline_frac``), and compile watchdog counts
  (``repro_compiles_total`` + compile-seconds histogram).
* ``--bench-trend [BENCH]`` — metric trends from the committed
  ``benchmarks/history/*.jsonl`` (tools/bench_history.py records).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.obs import tracing  # noqa: E402


def _pct(xs, q):
    if not xs:
        return float("nan")
    xs = sorted(xs)
    i = min(len(xs) - 1, max(0, int(round(q / 100 * (len(xs) - 1)))))
    return xs[i]


def _fmt_s(x):
    return "-" if x != x else (f"{x * 1e3:.2f}ms" if x < 1 else f"{x:.3f}s")


def report_trace(events) -> dict:
    """Span-derived serving report: terminal statuses, per-request
    TTFT (request begin → first_token), inter-token gaps, decode-step
    walls, fault/retry instants."""
    spans = tracing.validate_spans(events)
    statuses: dict = {}
    ttft, tpot, steps, faults, retries = [], [], [], 0, 0
    # per-uid instant timestamps for TTFT/TPOT reconstruction
    first_tok: dict = {}
    last_tok: dict = {}
    for ev in events:
        name, uid, ts = ev["name"], ev.get("uid"), ev["ts"]
        if ev["ph"] == "i":
            if name == "first_token" and uid is not None:
                first_tok.setdefault(uid, ts)
                last_tok[uid] = ts
            elif name == "token" and uid is not None:
                if uid in last_tok:
                    tpot.append(ts - last_tok[uid])
                last_tok[uid] = ts
            elif name == "fault":
                faults += 1
            elif name == "retry":
                retries += 1
        elif ev["ph"] == "E" and name == "step":
            pass
    # step walls from B/E pairs on the global track
    open_step = []
    for ev in events:
        if ev["name"] != "step":
            continue
        if ev["ph"] == "B":
            open_step.append(ev["ts"])
        elif ev["ph"] == "E" and open_step:
            steps.append(ev["ts"] - open_step.pop())
    n_spans = 0
    for uid, recs in spans.items():
        for rec in recs:
            n_spans += 1
            statuses[rec["status"]] = statuses.get(rec["status"], 0) + 1
            if uid in first_tok and first_tok[uid] >= rec["t0"] and (
                    rec["t1"] is None or first_tok[uid] <= rec["t1"]):
                ttft.append(first_tok[uid] - rec["t0"])
    return {
        "requests": len(spans), "request_spans": n_spans,
        "statuses": statuses,
        "ttft": ttft, "tpot": tpot, "step": steps,
        "faults": faults, "retries": retries,
    }


def load_metrics(path: str) -> dict:
    """Parse a metrics dump — JSON (Registry.dump_json) or the
    Prometheus text exposition — into {metric_name: [(labels, value)]}
    (histograms keep their _bucket/_sum/_count sample names)."""
    text = pathlib.Path(path).read_text()
    out: dict = {}
    if path.endswith(".json"):
        data = json.loads(text).get("metrics", {})
        for name, m in data.items():
            for s in m.get("series", []):
                labels = s.get("labels", {})
                if "value" in s:
                    out.setdefault(name, []).append((labels, s["value"]))
                else:                      # histogram series
                    out.setdefault(name + "_count", []).append(
                        (labels, float(s.get("count", 0))))
                    out.setdefault(name + "_sum", []).append(
                        (labels, float(s.get("sum", 0.0))))
        return out
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        head, _, val = line.rpartition(" ")
        if "{" in head:
            name, _, rest = head.partition("{")
            labels = dict(p.split("=", 1) for p in
                          rest.rstrip("}").split(",") if "=" in p)
            labels = {k: v.strip('"') for k, v in labels.items()}
        else:
            name, labels = head, {}
        out.setdefault(name, []).append((labels, float(val)))
    return out


def load_histograms(path: str) -> dict:
    """Parse histogram structure out of a metrics dump:
    ``{name: [(labels, buckets, cum_counts, sum, count)]}`` with
    ``buckets`` the finite ``le`` edges and ``cum_counts`` cumulative
    (Prometheus semantics), total in ``count``."""
    text = pathlib.Path(path).read_text()
    out: dict = {}
    if path.endswith(".json"):
        data = json.loads(text).get("metrics", {})
        for name, m in data.items():
            if m.get("kind") != "histogram":
                continue
            for s in m.get("series", []):
                out.setdefault(name, []).append(
                    (s.get("labels", {}), list(s["buckets"]),
                     list(s["counts"]), float(s.get("sum", 0.0)),
                     int(s.get("count", 0))))
        return out
    # prometheus text: group _bucket/_sum/_count by (name, labels\le)
    acc: dict = {}
    for labels_name, rows in load_metrics(path).items():
        for suffix in ("_bucket", "_sum", "_count"):
            if labels_name.endswith(suffix):
                base = labels_name[: -len(suffix)]
                for labels, val in rows:
                    key_labels = {k: v for k, v in labels.items()
                                  if k != "le"}
                    key = (base, tuple(sorted(key_labels.items())))
                    rec = acc.setdefault(
                        key, {"labels": key_labels, "edges": [],
                              "sum": 0.0, "count": 0})
                    if suffix == "_bucket":
                        le = labels.get("le", "+Inf")
                        if le != "+Inf":
                            rec["edges"].append((float(le), val))
                    elif suffix == "_sum":
                        rec["sum"] = val
                    else:
                        rec["count"] = int(val)
                break
    for (base, _), rec in acc.items():
        edges = sorted(rec["edges"])
        out.setdefault(base, []).append(
            (rec["labels"], [e for e, _ in edges],
             [int(c) for _, c in edges], rec["sum"], rec["count"]))
    return out


def hist_quantile(buckets, cum_counts, count, q):
    """Quantile from cumulative bucket counts: linear interpolation
    inside the containing bucket. Returns ``(value, lo, hi)`` where
    [lo, hi) is the containing bucket (hi = inf for the overflow
    bucket — observations above every edge). NaNs when empty."""
    nan = float("nan")
    if count <= 0 or not buckets:
        return nan, nan, nan
    target = q / 100.0 * count
    prev_edge, prev_cum = 0.0, 0
    for edge, cum in zip(buckets, cum_counts):
        if cum >= target:
            frac = ((target - prev_cum) / (cum - prev_cum)
                    if cum > prev_cum else 1.0)
            return prev_edge + frac * (edge - prev_edge), prev_edge, edge
        prev_edge, prev_cum = edge, cum
    return buckets[-1], buckets[-1], float("inf")


def compare_latency(trace_report: dict, hists: dict) -> list:
    """Span-derived vs histogram-derived TTFT/TPOT p50/p99 (ISSUE 10
    satellite): rows ``{"metric", "q", "span_s", "hist_s", "width_s",
    "agree"}``. ``agree`` is False when the two differ by more than the
    width of the histogram bucket containing the quantile — the
    histogram cannot localise finer than its bucket, so anything within
    one width is indistinguishable; beyond it the mirroring is broken."""
    pairs = (("ttft", "repro_ttft_seconds"),
             ("tpot", "repro_tpot_seconds"))
    rows = []
    for key, metric in pairs:
        series = hists.get(metric)
        xs = trace_report.get(key) or []
        if not series or not xs:
            continue
        labels, buckets, cum, _sum, count = series[0]
        for q in (50, 99):
            hv, lo, hi = hist_quantile(buckets, cum, count, q)
            sv = _pct(xs, q)
            width = (hi - lo) if hi != float("inf") else float("inf")
            agree = not (abs(sv - hv) > width) \
                if sv == sv and hv == hv else True
            rows.append({"metric": key, "q": q, "span_s": sv,
                         "hist_s": hv, "width_s": width, "agree": agree})
    return rows


def print_kernel_report(metrics_path, out=print) -> None:
    """The ``--kernels`` view: dispatch counts, attributed seconds with
    roofline fractions, and compile watchdog counts."""
    m = load_metrics(metrics_path)
    hists = load_histograms(metrics_path)

    def rows_of(name):
        return m.get(name, [])

    out("kernel tier:")
    disp = rows_of("repro_kernel_dispatch_total")
    if disp:
        out("  dispatches (kernel, source -> count):")
        for labels, val in sorted(disp, key=lambda r: (
                r[0].get("kernel", ""), r[0].get("source", ""))):
            out(f"    {labels.get('kernel', '?'):16s} "
                f"{labels.get('source', '?'):10s} {val:g}")
    secs = rows_of("repro_kernel_seconds_total")
    fracs = {r[0].get("kernel"): r[1]
             for r in rows_of("repro_kernel_roofline_frac")}
    if secs:
        total = sum(v for _, v in secs) or 1.0
        out("  attributed seconds (kernel: seconds, share, roofline "
            "fraction):")
        for labels, val in sorted(secs, key=lambda r: -r[1]):
            k = labels.get("kernel", "?")
            rf = fracs.get(k)
            rf_s = f"{rf:.4f}" if rf is not None else "-"
            out(f"    {k:16s} {val:9.4f}s  {val / total:6.1%}  rf={rf_s}")
    comp = rows_of("repro_compiles_total")
    if comp:
        out("  compiles (fn -> traces):")
        for labels, val in sorted(comp, key=lambda r: r[0].get("fn", "")):
            out(f"    {labels.get('fn', '?'):32s} {val:g}")
    ch = hists.get("repro_compile_seconds")
    if ch:
        tot_s = sum(s for _, _, _, s, _ in ch)
        tot_n = sum(n for _, _, _, _, n in ch)
        out(f"  compile wall: {tot_n} timed traces, {tot_s:.3f}s total")
    if not (disp or secs or comp):
        out("  (no kernel-tier series in this dump)")


def print_bench_trend(bench=None, out=print) -> None:
    """The ``--bench-trend`` view — delegates to tools/bench_history.py
    (same directory) so the trend math lives in one place."""
    sys.path.insert(0, str(ROOT / "tools"))
    import bench_history
    args = argparse.Namespace(bench=bench, history_dir=None)
    bench_history.cmd_show(args)


def print_report(trace_path=None, metrics_path=None, out=print):
    r = None
    if trace_path:
        events = tracing.load_jsonl(trace_path)
        r = report_trace(events)
        out(f"trace: {trace_path} ({len(events)} events)")
        out(f"  requests: {r['requests']} uids, {r['request_spans']} "
            f"span trees; statuses={r['statuses']}")
        for key, label in (("ttft", "TTFT"), ("tpot", "TPOT"),
                           ("step", "decode step")):
            xs = r[key]
            out(f"  {label}: n={len(xs)} p50={_fmt_s(_pct(xs, 50))} "
                f"p90={_fmt_s(_pct(xs, 90))} p99={_fmt_s(_pct(xs, 99))}")
        out(f"  faults injected: {r['faults']}; retries: {r['retries']}")
    if metrics_path:
        m = load_metrics(metrics_path)
        out(f"metrics: {metrics_path} ({len(m)} series)")
        for name in sorted(m):
            if name.endswith(("_bucket", "_sum")):
                continue
            for labels, val in m[name]:
                lbl = ("{" + ",".join(f"{k}={v}" for k, v in
                                      sorted(labels.items())) + "}"
                       if labels else "")
                out(f"  {name}{lbl} = {val:g}")
        # histogram-derived latency + span cross-check (ISSUE 10)
        hists = load_histograms(metrics_path)
        for metric, label in (("repro_ttft_seconds", "TTFT"),
                              ("repro_tpot_seconds", "TPOT")):
            series = hists.get(metric)
            if not series:
                continue
            _, buckets, cum, _s, count = series[0]
            p50, _, _ = hist_quantile(buckets, cum, count, 50)
            p99, _, _ = hist_quantile(buckets, cum, count, 99)
            out(f"  {label} (histogram): n={count} "
                f"p50={_fmt_s(p50)} p99={_fmt_s(p99)}")
        if r is not None:
            disagreements = 0
            for row in compare_latency(r, hists):
                mark = "ok" if row["agree"] else "DISAGREE"
                if not row["agree"]:
                    disagreements += 1
                out(f"  {row['metric']} p{row['q']}: "
                    f"span={_fmt_s(row['span_s'])} "
                    f"hist={_fmt_s(row['hist_s'])} "
                    f"(bucket width {_fmt_s(row['width_s'])}) {mark}")
            if disagreements:
                out(f"  WARNING: {disagreements} span-vs-histogram "
                    "disagreement(s) beyond one bucket width — check "
                    "metric mirroring")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="summarise / validate / convert obs artifacts")
    ap.add_argument("--trace", default=None,
                    help="span JSONL (REPRO_TRACE_FILE / --trace-file)")
    ap.add_argument("--metrics", default=None,
                    help="metrics dump (.prom text exposition or .json)")
    ap.add_argument("--chrome", default=None, metavar="OUT",
                    help="also write the trace as Chrome trace_event "
                         "JSON (Perfetto-loadable)")
    ap.add_argument("--check", action="store_true",
                    help="validate span completeness only; exit 1 on any "
                         "violation (CI smoke gate)")
    ap.add_argument("--kernels", action="store_true",
                    help="print the kernel-tier table (dispatch counts, "
                         "attributed seconds + roofline fractions, "
                         "compile watchdog) from --metrics")
    ap.add_argument("--bench-trend", nargs="?", const="", default=None,
                    metavar="BENCH",
                    help="print benchmarks/history trends (optionally "
                         "one bench name)")
    args = ap.parse_args(argv)
    if args.bench_trend is not None:
        print_bench_trend(args.bench_trend or None)
        if not args.trace and not args.metrics:
            return 0
    if not args.trace and not args.metrics:
        ap.error("nothing to do: pass --trace and/or --metrics "
                 "(or --bench-trend)")
    if args.kernels and not args.metrics:
        ap.error("--kernels needs --metrics")
    if (args.chrome or args.check) and not args.trace:
        ap.error("--chrome/--check need --trace")
    if args.check:
        events = tracing.load_jsonl(args.trace)
        try:
            spans = tracing.validate_spans(events)
        except ValueError as e:
            print(f"[obs-report] FAIL: {e}")
            return 1
        n = sum(len(v) for v in spans.values())
        print(f"[obs-report] OK: {len(spans)} uids, {n} complete "
              f"request span trees")
        if args.chrome:
            tracing.write_chrome(events, args.chrome)
            print(f"[obs-report] chrome trace: {args.chrome}")
        return 0
    print_report(args.trace, args.metrics)
    if args.kernels:
        print_kernel_report(args.metrics)
    if args.chrome:
        tracing.write_chrome(tracing.load_jsonl(args.trace), args.chrome)
        print(f"[obs-report] chrome trace: {args.chrome}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
