#!/usr/bin/env python
"""Append-only benchmark history + rolling-median drift gate (ISSUE 10).

The three BENCH_*.json files are overwritten on every run, so the perf
trajectory across PRs was empty — a regression just read as "the new
number". This tool gives every BENCH run a durable record and turns the
committed history into a CI gate:

* ``append BENCH_engine.json``   — extract the drift-gated metrics from
  the payload and append one JSONL record (git sha, platform, UTC
  timestamp, metrics) to ``benchmarks/history/<bench>.jsonl``.
* ``check BENCH_engine.json``    — compare the fresh payload against a
  **rolling median of the last K same-platform records** (default K=5);
  exit 1 when any metric drifts past its threshold in the bad
  direction. Medians are robust to one noisy run; same-platform
  filtering keeps CPU smoke numbers from gating TPU runs.
* ``show [bench]``               — print the trend per metric (n, first,
  median, last).

Gated metrics are *mostly ratios* (speedups, overhead fractions), which
are stable across host load; absolute tok/s is tracked but gated at a
generous threshold. Direction is per metric: ``higher`` fails on drops,
``lower`` on rises. Metrics with an ``abs`` entry use an absolute slack
instead of a relative one (overhead_frac lives near 0 where relative
thresholds are meaningless).
"""
from __future__ import annotations

import argparse
import datetime
import json
import pathlib
import subprocess
import sys
from typing import Dict, List, Optional

ROOT = pathlib.Path(__file__).resolve().parents[1]
HISTORY_DIR = ROOT / "benchmarks" / "history"

#: drift-gate config: bench -> metric -> {direction, threshold | abs}
#: threshold = max relative drift vs the rolling median (0.2 = 20%);
#: abs = absolute slack instead (for near-zero metrics)
GATES: Dict[str, Dict[str, dict]] = {
    "engine": {
        "engine_tok_s_S16": {"direction": "higher", "threshold": 0.5},
        "speedup_S16": {"direction": "higher", "threshold": 0.2},
        "prefill_pack_speedup": {"direction": "higher", "threshold": 0.2},
        "obs_overhead_frac": {"direction": "lower", "abs": 0.05},
        "obs_attr_coverage": {"direction": "higher", "abs": 0.1},
    },
    "ski_fused_vs_unfused": {
        "fwd_speedup_min": {"direction": "higher", "threshold": 0.2},
        "bwd_speedup_min": {"direction": "higher", "threshold": 0.2},
        "large_r_fwd_speedup_max": {"direction": "higher",
                                    "threshold": 0.2},
    },
    "fd_fused": {
        "fwd_speedup_min": {"direction": "higher", "threshold": 0.2},
        "decode_stream_speedup": {"direction": "higher", "threshold": 0.2},
        "decode_stream_tok_s": {"direction": "higher", "threshold": 0.5},
    },
}


# ------------------------------------------------------------ extraction
def _safe_min(xs: List[float]) -> Optional[float]:
    xs = [x for x in xs if x is not None]
    return min(xs) if xs else None


def extract_metrics(payload: dict) -> Dict[str, float]:
    """Pull the drift-gated metrics out of one BENCH payload. Tolerant
    of missing sections (older payloads lack ``obs``): absent metrics
    are simply not recorded, and the gate skips them."""
    bench = payload.get("bench", "")
    out: Dict[str, float] = {}
    if bench == "engine":
        for row in payload.get("results", []):
            if row.get("slots") == 16:
                out["engine_tok_s_S16"] = row["engine_tok_s"]
                out["speedup_S16"] = row["speedup"]
        pre = payload.get("prefill") or {}
        if "speedup" in pre:
            out["prefill_pack_speedup"] = pre["speedup"]
        obs = payload.get("obs") or {}
        if "overhead_frac" in obs:
            out["obs_overhead_frac"] = obs["overhead_frac"]
        if "attributed_coverage" in obs:
            out["obs_attr_coverage"] = obs["attributed_coverage"]
    elif bench == "ski_fused_vs_unfused":
        v = _safe_min([r.get("speedup_vs_4launch")
                       for r in payload.get("results", [])])
        if v is not None:
            out["fwd_speedup_min"] = v
        v = _safe_min([r.get("bwd_speedup_vs_unfused")
                       for r in payload.get("bwd", [])])
        if v is not None:
            out["bwd_speedup_min"] = v
        lr = [r.get("fwd_speedup_vs_dense")
              for r in payload.get("large_r", [])]
        lr = [x for x in lr if x is not None]
        if lr:
            out["large_r_fwd_speedup_max"] = max(lr)
    elif bench == "fd_fused":
        v = _safe_min([r.get("speedup_vs_4launch")
                       for r in payload.get("results", [])])
        if v is not None:
            out["fwd_speedup_min"] = v
        for r in payload.get("decode", []):
            if "speedup" in r:
                out["decode_stream_speedup"] = r["speedup"]
            if "stream_tok_s" in r:
                out["decode_stream_tok_s"] = r["stream_tok_s"]
    else:
        raise SystemExit(f"bench_history: unknown bench {bench!r} "
                         f"(known: {sorted(GATES)})")
    return out


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=ROOT,
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def make_record(payload: dict, *, sha: Optional[str] = None,
                timestamp: Optional[str] = None) -> dict:
    return {
        "bench": payload.get("bench", ""),
        "sha": sha if sha is not None else git_sha(),
        "platform": payload.get("platform", "unknown"),
        "timestamp": timestamp if timestamp is not None
        else datetime.datetime.now(datetime.timezone.utc).isoformat(
            timespec="seconds"),
        "metrics": extract_metrics(payload),
    }


# --------------------------------------------------------------- history
def history_path(bench: str,
                 history_dir: Optional[pathlib.Path] = None) -> pathlib.Path:
    return (history_dir or HISTORY_DIR) / f"{bench}.jsonl"


def load_history(bench: str,
                 history_dir: Optional[pathlib.Path] = None) -> List[dict]:
    p = history_path(bench, history_dir)
    if not p.exists():
        return []
    out = []
    with open(p) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except ValueError as e:
                raise SystemExit(f"{p}:{i}: bad history line: {e}")
    return out


def append_record(record: dict,
                  history_dir: Optional[pathlib.Path] = None
                  ) -> pathlib.Path:
    p = history_path(record["bench"], history_dir)
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")
    return p


# ------------------------------------------------------------ drift gate
def _median(xs: List[float]) -> float:
    xs = sorted(xs)
    n = len(xs)
    mid = n // 2
    return xs[mid] if n % 2 else 0.5 * (xs[mid - 1] + xs[mid])


def check_drift(record: dict, history: List[dict], *, window: int = 5
                ) -> List[dict]:
    """Compare one record against the rolling median of the last
    ``window`` same-platform history records. Returns a list of failure
    dicts (empty = gate passes): ``{"metric", "value", "baseline",
    "drift", "limit", "direction"}``. Metrics with no history (or not in
    the gate table) pass — the first committed record *is* the
    baseline."""
    gates = GATES.get(record["bench"], {})
    same = [r for r in history
            if r.get("platform") == record.get("platform")]
    failures = []
    for metric, gate in gates.items():
        value = record["metrics"].get(metric)
        if value is None:
            continue
        past = [r["metrics"][metric] for r in same[-window:]
                if metric in r.get("metrics", {})]
        if not past:
            continue
        baseline = _median(past)
        direction = gate["direction"]
        if "abs" in gate:
            drift = value - baseline
            bad = (drift < -gate["abs"] if direction == "higher"
                   else drift > gate["abs"])
            limit = gate["abs"]
        else:
            if baseline == 0:
                continue
            drift = value / baseline - 1.0
            bad = (drift < -gate["threshold"] if direction == "higher"
                   else drift > gate["threshold"])
            limit = gate["threshold"]
        if bad:
            failures.append({"metric": metric, "value": value,
                             "baseline": baseline, "drift": drift,
                             "limit": limit, "direction": direction})
    return failures


# ------------------------------------------------------------------- CLI
def cmd_append(args) -> int:
    payload = json.load(open(args.json_path))
    rec = make_record(payload, sha=args.sha)
    p = append_record(rec, args.history_dir)
    print(f"bench_history: appended {rec['bench']} @ {rec['sha']} "
          f"({len(rec['metrics'])} metrics) -> {p}")
    return 0


def cmd_check(args) -> int:
    payload = json.load(open(args.json_path))
    rec = make_record(payload, sha=args.sha)
    history = load_history(rec["bench"], args.history_dir)
    failures = check_drift(rec, history, window=args.window)
    same = [r for r in history
            if r.get("platform") == rec.get("platform")]
    print(f"bench_history: {rec['bench']} vs {len(same)} same-platform "
          f"record(s), window={args.window}")
    for m, v in sorted(rec["metrics"].items()):
        past = [r["metrics"][m] for r in same[-args.window:]
                if m in r.get("metrics", {})]
        base = f"{_median(past):.4g}" if past else "n/a"
        print(f"  {m:28s} {v:.4g}  (baseline {base})")
    if failures:
        for f in failures:
            print(f"DRIFT: {f['metric']} = {f['value']:.4g} vs rolling "
                  f"median {f['baseline']:.4g} "
                  f"(drift {f['drift']:+.2%}, limit {f['limit']:g}, "
                  f"want {f['direction']})")
        return 1
    print("bench_history: drift gate OK")
    return 0


def cmd_show(args) -> int:
    benches = [args.bench] if args.bench else sorted(
        p.stem for p in (args.history_dir or HISTORY_DIR).glob("*.jsonl"))
    for bench in benches:
        history = load_history(bench, args.history_dir)
        print(f"== {bench} ({len(history)} records)")
        metrics = sorted({m for r in history for m in r.get("metrics", {})})
        for m in metrics:
            xs = [(r["sha"], r["metrics"][m]) for r in history
                  if m in r.get("metrics", {})]
            vals = [v for _, v in xs]
            print(f"  {m:28s} n={len(vals):3d} first={vals[0]:.4g} "
                  f"median={_median(vals):.4g} last={vals[-1]:.4g} "
                  f"(@{xs[-1][0]})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--history-dir", type=pathlib.Path, default=None,
                    help=f"history directory (default {HISTORY_DIR})")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("append", help="append one record from a BENCH json")
    p.add_argument("json_path")
    p.add_argument("--sha", default=None)
    p.set_defaults(fn=cmd_append)
    p = sub.add_parser("check", help="drift-gate a BENCH json vs history")
    p.add_argument("json_path")
    p.add_argument("--sha", default=None)
    p.add_argument("--window", type=int, default=5)
    p.set_defaults(fn=cmd_check)
    p = sub.add_parser("show", help="print metric trends")
    p.add_argument("bench", nargs="?", default=None)
    p.set_defaults(fn=cmd_show)
    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
