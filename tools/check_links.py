"""Intra-repo markdown link checker (CI docs gate).

Scans README.md and docs/**/*.md for inline markdown links and fails
when a *relative* link target does not exist, or when a ``#anchor``
(same-file or cross-file) does not match any heading's GitHub-style
slug. External links (http/https/mailto) are not fetched — this gate
is about the repo's own docs never silently rotting.

    python tools/check_links.py            # default file set
    python tools/check_links.py FILE...    # explicit files
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

# inline links: [text](target) — skips images' alt brackets fine since
# ![alt](src) still yields (src), which we do want to check
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
_CODE_FENCE = re.compile(r"^(```|~~~)")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation except
    ``-``/``_``, spaces become hyphens (each space, so ``a + b`` →
    ``a--b`` once the ``+`` is dropped)."""
    heading = re.sub(r"`([^`]*)`", r"\1", heading)          # inline code
    heading = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)  # links
    heading = heading.lower()
    heading = re.sub(r"[^\w\- ]", "", heading)
    return heading.replace(" ", "-")


def anchors_of(path: pathlib.Path) -> set:
    out, seen, fenced = set(), {}, False
    for line in path.read_text().splitlines():
        if _CODE_FENCE.match(line):
            fenced = not fenced
            continue
        if fenced:
            continue
        m = _HEADING.match(line)
        if not m:
            continue
        slug = slugify(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        out.add(slug if n == 0 else f"{slug}-{n}")   # duplicate headings
    return out


def links_of(path: pathlib.Path):
    fenced = False
    for i, line in enumerate(path.read_text().splitlines(), 1):
        if _CODE_FENCE.match(line):
            fenced = not fenced
            continue
        if fenced:
            continue
        for m in _LINK.finditer(line):
            yield i, m.group(1)


def check(files) -> int:
    errors = []
    anchor_cache = {}

    def anchors(p: pathlib.Path):
        if p not in anchor_cache:
            anchor_cache[p] = anchors_of(p)
        return anchor_cache[p]

    for f in files:
        for line_no, target in links_of(f):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # scheme: external
                continue
            try:
                where = f"{f.relative_to(ROOT)}:{line_no}"
            except ValueError:
                where = f"{f}:{line_no}"
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            if target == "":
                dest = f                                   # same-file anchor
            else:
                dest = (f.parent / target).resolve()
                if not dest.exists():
                    errors.append(f"{where}: broken link -> {target}")
                    continue
            if frag is not None:
                if dest.is_dir() or dest.suffix.lower() not in (".md",):
                    continue                               # e.g. file.py#L10
                if frag not in anchors(dest):
                    errors.append(
                        f"{where}: broken anchor -> "
                        f"{target or dest.name}#{frag}")
    for e in errors:
        print(e)
    print(f"[check_links] {len(files)} files, "
          f"{'FAIL: ' + str(len(errors)) + ' broken' if errors else 'OK'}")
    return 1 if errors else 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if argv:
        files = [pathlib.Path(a).resolve() for a in argv]
    else:
        files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("**/*.md"))
    missing = [f for f in files if not f.exists()]
    if missing:
        for f in missing:
            print(f"no such file: {f}")
        return 1
    return check(files)


if __name__ == "__main__":
    raise SystemExit(main())
