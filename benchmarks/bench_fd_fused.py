"""Causal FD-TNO pipeline + streaming decode tracking (ISSUE 4).

Part 1 (training path): the single-op fused pipeline ``ops.fd_tno``
(Hilbert completion + spectral multiply + FFT staging in one graph — one
jit, one HBM round-trip between stages on the compiled path) vs the
*unfused per-stage* pipeline as four separately jit'd launches (causal
spectrum / rfft / complex multiply / irfft+slice) with the (b, n+1, d)
complex spectrum crossing HBM between each — the same measurement
discipline as bench_ski_components' fused-vs-4-launch rows. A monolithic
single-jit unfused number is reported for reference. ``jax.grad`` rows
ride along (fused custom-VJP graph vs plain autodiff of the monolith).

Part 2 (serving path): token-by-token decode of one FD mixer channel
stack — the O(n·d)-per-token hist-replay scheme (models/serving.py before
this PR, measured *generously*: kernel precomputed once, not re-realised
per step like the production hist path) vs the overlap-save streaming
cache (kernels/fd_stream.py). Both run as one jit'd lax.scan over
gen_len steps so the comparison times compute, not dispatch.

Results land in BENCH_fd_fused.json; the CI perf gate requires the fused
fwd to hold ≥ 0.95x vs the 4-launch pipeline at n ≥ 2048 and streaming
decode ≥ 2x hist-replay tok/s at gen_len = 2048.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import report, time_fns_interleaved
from repro.core import fd as fd_mod
from repro.core.hilbert import causal_spectrum
from repro.kernels import backend, fd_stream, ops
from repro.nn.params import unbox

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_fd_fused.json"


def _unfused_launches(n):
    """The pre-fusion jnp path as four separate compiled launches."""
    k_spec = jax.jit(lambda kr: causal_spectrum(kr))
    k_rfft = jax.jit(lambda x: jnp.fft.rfft(x.astype(jnp.float32),
                                            n=2 * n, axis=1))
    k_mul = jax.jit(lambda xhat, khat: xhat * khat.T[None])
    k_irfft = jax.jit(lambda yhat: jnp.fft.irfft(yhat, n=2 * n,
                                                 axis=1)[:, :n])

    def run(x, khat_real):
        khat = k_spec(khat_real)
        xhat = k_rfft(x)
        yhat = k_mul(xhat, khat)
        return k_irfft(yhat)

    return run


def _fwd_bwd_rows(sizes, d=64, b=4, iters=5):
    fwd_rows, bwd_rows = [], []
    for n in sizes:
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (b, n, d))
        khat_real = jax.random.normal(jax.random.PRNGKey(1), (d, n + 1))

        fused = jax.jit(lambda x, kr: ops.fd_tno(x, kr))
        unf_launch = _unfused_launches(n)
        unf_mono = jax.jit(lambda x, kr: jnp.fft.irfft(
            jnp.fft.rfft(x.astype(jnp.float32), n=2 * n, axis=1)
            * causal_spectrum(kr).T[None], n=2 * n, axis=1)[:, :n])

        t_f, t_l, t_m = time_fns_interleaved(
            [fused, unf_launch, unf_mono], x, khat_real, iters=iters)
        speedup = t_l / t_f
        report(f"fd_fused/n{n}/fused", t_f * 1e3, "ms",
               "single-op Hilbert+multiply+FFT pipeline")
        report(f"fd_fused/n{n}/unfused_4launch", t_l * 1e3, "ms",
               "per-stage launches, spectrum crosses HBM each hop")
        report(f"fd_fused/n{n}/unfused_monolithic", t_m * 1e3, "ms")
        report(f"fd_fused/n{n}/speedup_vs_4launch", speedup, "x",
               "fused must beat the unfused jnp path (ISSUE 4)")
        fwd_rows.append({
            "n": n, "b": b, "d": d,
            "fused_ms": t_f * 1e3,
            "unfused_4launch_ms": t_l * 1e3,
            "unfused_monolithic_ms": t_m * 1e3,
            "speedup_vs_4launch": speedup,
        })

        g_fused = jax.jit(jax.grad(
            lambda x, kr: jnp.sum(ops.fd_tno(x, kr)), argnums=(0, 1)))
        g_mono = jax.jit(jax.grad(
            lambda x, kr: jnp.sum(unf_mono(x, kr)), argnums=(0, 1)))
        t_gf, t_gm = time_fns_interleaved([g_fused, g_mono], x, khat_real,
                                          iters=iters)
        report(f"fd_fused/n{n}/bwd_fused", t_gf * 1e3, "ms")
        report(f"fd_fused/n{n}/bwd_unfused", t_gm * 1e3, "ms")
        report(f"fd_fused/n{n}/bwd_over_fwd", t_gf / t_f, "x",
               "linear op: expect ~2-3x, blow-up = residual bug")
        bwd_rows.append({
            "n": n, "b": b, "d": d,
            "fused_grad_ms": t_gf * 1e3,
            "unfused_grad_ms": t_gm * 1e3,
            "bwd_speedup_vs_unfused": t_gm / t_gf,
            "bwd_over_fwd": t_gf / t_f,
        })
    return fwd_rows, bwd_rows


def _decode_rows(gen_len=2048, d=64, b=1, c=None, iters=4):
    """Streaming vs hist-replay decode of one FD mixer at gen_len tokens.

    hist-replay is measured generously: the causal kernel is realised
    ONCE outside the loop (the production hist path re-evaluates the RPE
    spectrum every step on top of the O(n·d) replay)."""
    c = c or backend.fd_stream_block()
    cfg = fd_mod.FDConfig(d=d, causal=True)
    params, _ = unbox(fd_mod.fd_init(jax.random.PRNGKey(0), cfg))
    k_causal = fd_mod.fd_kernel_time(params, cfg, gen_len)[:, :gen_len]
    u_seq = jax.random.normal(jax.random.PRNGKey(1), (gen_len, b, d))
    ts = jnp.arange(gen_len, dtype=jnp.int32)

    @jax.jit
    def hist_decode(u_seq, k):
        hist0 = jnp.zeros((b, gen_len, d), jnp.float32)
        idx = jnp.arange(gen_len)

        def body(hist, inp):
            t, u_t = inp
            hist = jax.lax.dynamic_update_slice(hist, u_t[:, None],
                                                (0, t, 0))
            tau = t - idx
            kmat = jnp.where(tau >= 0,
                             jnp.take(k, jnp.clip(tau, 0, gen_len - 1),
                                      axis=1), 0.0)
            y = jnp.einsum("bsd,ds->bd", hist, kmat)
            return hist, y

        _, ys = jax.lax.scan(body, hist0, (ts, u_seq))
        return ys

    @jax.jit
    def stream_decode(u_seq, k):
        cache0 = fd_stream.fd_stream_cache(k, b, gen_len, c)

        def body(cache, inp):
            t, u_t = inp
            y, cache = fd_stream.stream_step(cache, u_t, t)
            return cache, y

        _, ys = jax.lax.scan(body, cache0, (ts, u_seq))
        return ys

    # parity first: the two schemes must be the same operator
    diff = float(jnp.abs(hist_decode(u_seq, k_causal)
                         - stream_decode(u_seq, k_causal)).max())
    t_h, t_s = time_fns_interleaved([hist_decode, stream_decode],
                                    u_seq, k_causal, iters=iters, warmup=1)
    hist_tok_s = gen_len / t_h
    stream_tok_s = gen_len / t_s
    report(f"fd_decode/gen{gen_len}/hist_tok_s", hist_tok_s, "tok/s",
           "O(n*d)-per-token hist replay (generous: kernel precomputed)")
    report(f"fd_decode/gen{gen_len}/stream_tok_s", stream_tok_s, "tok/s",
           "overlap-save ring + tail refresh every C steps")
    report(f"fd_decode/gen{gen_len}/speedup", t_h / t_s, "x",
           "streaming must beat hist-replay >= 2x (ISSUE 4)")
    report(f"fd_decode/gen{gen_len}/max_abs_diff", diff, "",
           "stream == hist (exact block scheme)")
    return [{
        "gen_len": gen_len, "b": b, "d": d, "C": c,
        "hist_ms_per_tok": t_h / gen_len * 1e3,
        "stream_ms_per_tok": t_s / gen_len * 1e3,
        "hist_tok_s": hist_tok_s,
        "stream_tok_s": stream_tok_s,
        "speedup": t_h / t_s,
        "max_abs_diff": diff,
    }]


def _write_json(fwd_rows, bwd_rows, decode_rows):
    payload = {
        "bench": "fd_fused",
        "platform": backend.platform(),
        "use_pallas_default": backend.use_pallas_default(),
        "results": fwd_rows,
        "bwd": bwd_rows,
        "decode": decode_rows,
    }
    try:
        _JSON_PATH.write_text(json.dumps(payload, indent=1) + "\n")
    except OSError as e:
        report("fd_fused/json_write_error", 0, "", repr(e))


def run(smoke: bool = False):
    sizes = [2048] if smoke else [2048, 8192]
    fwd_rows, bwd_rows = _fwd_bwd_rows(sizes, iters=8 if smoke else 10)
    decode_rows = _decode_rows(iters=3 if smoke else 5)
    _write_json(fwd_rows, bwd_rows, decode_rows)


if __name__ == "__main__":
    run()
