"""Benchmark harness — one module per paper table/figure. CSV to stdout:
``name,value,unit,derived-claim``.

  bench_tno_variants        Figure 1 (+par.5.1/5.2 speed ratios)
  bench_ski_components      Figure 11 (sparse vs low-rank split) + the
                            fused-vs-unfused SKI pipeline tracking
                            (writes BENCH_ski_fused.json at the repo root)
  bench_fd_fused            causal FD-TNO: fused vs per-stage pipeline +
                            streaming vs hist-replay decode
                            (writes BENCH_fd_fused.json at the repo root)
  bench_engine              continuous-batching engine vs sequential
                            serving at S ∈ {1,4,16} slots
                            (writes BENCH_engine.json at the repo root)
  bench_appendix_b          Appendix B (causal-SKI negative result)
  bench_pretrain_parity     Table 1 stand-in (causal quality parity)
  bench_lra_style           Table 2 stand-in (long-range classification)
  bench_length_extrapolation Fig 7a + par.3.2.2 (inverse warp / FD grids)
  bench_decay_classes       Appendix E.3 (smoothness => decay, quantified)

``--smoke`` runs a fast perf-regression gate (CI): only the fused-vs-
unfused SKI comparison at n=2048 with reduced iterations.

Roofline terms for the production mesh come from the dry-run
(repro.launch.dryrun / results/*.json), not from this harness.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="fast subset: fused SKI + fused FD perf gates only")
    args = ap.parse_args()

    print("name,value,unit,derived")
    if args.smoke:
        from benchmarks import (bench_engine, bench_fd_fused,
                                bench_ski_components)
        t0 = time.time()
        bench_ski_components.run(smoke=True)
        print(f"ski_components/_elapsed,{time.time() - t0:.1f},s,")
        t0 = time.time()
        bench_fd_fused.run(smoke=True)
        print(f"fd_fused/_elapsed,{time.time() - t0:.1f},s,")
        t0 = time.time()
        bench_engine.run(smoke=True)
        print(f"engine/_elapsed,{time.time() - t0:.1f},s,")
        return

    from benchmarks import (bench_appendix_b, bench_complexity,
                            bench_decay_classes, bench_engine,
                            bench_fd_fused, bench_length_extrapolation,
                            bench_lra_style, bench_pretrain_parity,
                            bench_ski_components, bench_tno_variants)
    modules = [
        ("complexity", bench_complexity),
        ("tno_variants", bench_tno_variants),
        ("ski_components", bench_ski_components),
        ("fd_fused", bench_fd_fused),
        ("engine", bench_engine),
        ("appendix_b", bench_appendix_b),
        ("pretrain_parity", bench_pretrain_parity),
        ("lra_style", bench_lra_style),
        ("length_extrapolation", bench_length_extrapolation),
        ("decay_classes", bench_decay_classes),
    ]
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.run()
        except Exception as e:              # report, keep the harness alive
            print(f"{name}/ERROR,0,,{e!r}", file=sys.stderr)
            print(f"{name}/ERROR,0,error,{type(e).__name__}")
        print(f"{name}/_elapsed,{time.time() - t0:.1f},s,")


if __name__ == "__main__":
    main()
