"""Paper Appendix E.3 reproduction: frequency-response smoothness vs
time-domain decay per activation (GeLU / SiLU / ReLU), quantified instead
of visualised: near→far decay ratios and tail energy fractions dumped as
CSV (plus the controlled-spectrum law checks mirrored from the tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import report
from repro.core.rpe import MLPRPEConfig, mlp_rpe_apply, mlp_rpe_init
from repro.nn.params import unbox


def run(n=1024, seeds=4):
    for act in ("gelu", "silu", "relu"):
        ratios, tails = [], []
        for s in range(seeds):
            cfg = MLPRPEConfig(8, 32, 3, act)
            params, _ = unbox(mlp_rpe_init(jax.random.PRNGKey(s), cfg))
            omega = jnp.arange(n + 1, dtype=jnp.float32) * jnp.pi / n
            khat = mlp_rpe_apply(params, cfg, jnp.cos(omega)).T
            kt = jnp.fft.irfft(khat, n=2 * n, axis=-1)
            k = np.abs(np.asarray(kt[:, :n]))
            near = k[:, 4:16].mean(axis=1) + 1e-12
            far = k[:, n // 2 - 8:n // 2 + 8].mean(axis=1)
            ratios.append(float((far / near).mean()))
            tot = (k[:, 1:] ** 2).sum(axis=1) + 1e-30
            tails.append(float(((k[:, 64:] ** 2).sum(axis=1) / tot).mean()))
        report(f"decay_classes/{act}_far_near_ratio", np.mean(ratios), "x",
               "paper AppE.3: smooth acts decay")
        report(f"decay_classes/{act}_tail_energy", np.mean(tails), "frac")


if __name__ == "__main__":
    run()
