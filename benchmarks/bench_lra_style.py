"""Paper Table 2 stand-in: long-range classification quality per variant.

LRA data is unavailable offline; the pipeline's ``lra_match`` task is a
long-range binary classification (sentinels at positions 1 and n-2 must be
compared across the sequence). Bidirectional TNN / SKI-TNN / FD-TNN models
train for a fixed budget; accuracies land in the paper's qualitative
ordering territory (all far above chance, within a few points of each
other). Paper claim checked: SKI/FD reach TNN-level accuracy with the same
budget while being faster per step (speed covered by bench_tno_variants).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import report
from repro.configs import get_config, reduce_for_smoke
from repro.data.pipeline import DataConfig, batch_at
from repro.models.context import Ctx
from repro.models.transformer import forward, init_model
from repro.nn.params import unbox
from repro.optim import adamw


def _cls_loss(params, cfg, batch):
    logits, _ = forward(params, cfg, Ctx(), batch)     # (b, n, V)
    final = logits[:, -1, :2].astype(jnp.float32)      # 2-way head
    labels = batch["labels"][:, 0]
    lse = jax.nn.logsumexp(final, axis=-1)
    ll = jnp.take_along_axis(final, labels[:, None], axis=1)[:, 0]
    return jnp.mean(lse - ll)


def _accuracy(params, cfg, batch):
    logits, _ = forward(params, cfg, Ctx(), batch)
    pred = jnp.argmax(logits[:, -1, :2], axis=-1)
    return float(jnp.mean((pred == batch["labels"][:, 0]).astype(jnp.float32)))


def run(steps=60, seq_len=128, batch=32):
    results = {}
    for variant in ("tno", "ski", "fd"):
        cfg = reduce_for_smoke(
            get_config("tnn-lm-wt103"), n_layers=2, d_model=64,
            vocab=64, tno_rank=16, tno_filter=8)
        cfg = dataclasses.replace(cfg, pattern=((variant, "dense"),),
                                  scan_layers=False)
        params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
        ocfg = adamw.OptConfig(lr=1e-3, warmup_steps=10, total_steps=steps)
        opt = adamw.init(ocfg, params)
        dcfg = DataConfig(vocab=64, seq_len=seq_len, global_batch=batch,
                          kind="lra_match", seed=0)

        @jax.jit
        def train_step(params, opt, batch):
            loss, grads = jax.value_and_grad(
                lambda p: _cls_loss(p, cfg, batch))(params)
            opt, params, _ = adamw.step(ocfg, opt, grads, params)
            return params, opt, loss

        for step in range(steps):
            b = {k: jnp.asarray(v) for k, v in batch_at(dcfg, step).items()}
            params, opt, loss = train_step(params, opt, b)
        test = {k: jnp.asarray(v)
                for k, v in batch_at(dcfg, 10_000).items()}
        acc = _accuracy(params, cfg, test)
        results[variant] = acc
        report(f"lra_style/acc_{variant}", 100 * acc, "%",
               "paper Tab2 stand-in (chance=50)")
    return results


if __name__ == "__main__":
    run()
