"""Paper Figure 1 / §5 speed claims: TNO variant step-time ratios.

Measures the token-mixer forward (+backward) wall time for the baseline
TNO vs SKI-TNO vs FD-TNO at several sequence lengths, causal and
bidirectional — the paper's headline claims, as same-host ratios:

* FD-TNO causal faster than TNO causal (paper: 10-15%);
* FD-TNO bidirectional faster than TNO (one fewer FFT; paper: up to 80%
  at 6-layer RPE — we use 3-layer, expect smaller but >0 gains);
* SKI-TNO bidirectional faster than TNO (paper: 25-30% full-model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import report, time_fn
from repro.core.tno import TNOConfig, tno_apply, tno_init
from repro.nn.params import unbox


def _step_fn(cfg):
    def loss(params, x):
        return jnp.sum(tno_apply(params, cfg, x) ** 2)
    return jax.jit(jax.grad(loss))


def run():
    d, b = 64, 4
    key = jax.random.PRNGKey(0)
    for n in (512, 2048):
        x = jax.random.normal(key, (b, n, d))
        times = {}
        for variant in ("tno", "ski", "fd"):
            for causal in (True, False):
                if variant == "ski" and causal:
                    continue            # paper: SKI is bidirectional-only
                cfg = TNOConfig(d=d, variant=variant, causal=causal,
                                rank=64, filter_size=32, rpe_layers=3)
                params, _ = unbox(tno_init(key, cfg))
                t = time_fn(_step_fn(cfg), params, x)
                times[(variant, causal)] = t
                tag = "causal" if causal else "bidir"
                report(f"tno_variant/{variant}_{tag}_n{n}", t * 1e3, "ms")
        for causal, tag in ((True, "causal"), (False, "bidir")):
            base = times[("tno", causal)]
            fd = times[("fd", causal)]
            report(f"tno_variant/fd_speedup_{tag}_n{n}",
                   100.0 * (base - fd) / base, "%",
                   "paper Fig1: FD faster than TNO")
        base = times[("tno", False)]
        skis = times[("ski", False)]
        report(f"tno_variant/ski_speedup_bidir_n{n}",
               100.0 * (base - skis) / base, "%",
               "paper Fig10: SKI faster than TNO (bidir)")


if __name__ == "__main__":
    run()
