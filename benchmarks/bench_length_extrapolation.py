"""Paper §3.2.2 + Figure 7a: length extrapolation.

* SKI inverse time warp: k(t) = RPE(sign(t)·λ^|t|) turns unseen long lags
  into *interpolation* near x=0 — evaluate a trained SKI kernel at 4× the
  training length and check values stay bounded/continuous.
* FD grid refinement: evaluating the frequency MLP on a finer ω grid
  extrapolates the kernel to longer sequences — quality measured as NLL at
  2× the training length for an FD model (must stay close to train-length
  NLL; paper Fig 7a shows flat PPL-vs-length).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import report
from repro.configs import get_config, reduce_for_smoke
from repro.core.ski import SKIConfig, inducing_gram_coeffs, ski_init
from repro.data.pipeline import DataConfig, batch_at
from repro.models.context import Ctx
from repro.models.transformer import init_model, loss_fn
from repro.nn.params import unbox
from repro.optim import adamw


def run(steps=60, seq_len=64, vocab=256):
    # --- warp boundedness at 4x length
    cfg = SKIConfig(d=8, rank=16, filter_size=8)
    params, _ = unbox(ski_init(jax.random.PRNGKey(0), cfg))
    k_long = inducing_gram_coeffs(params, cfg, 16, (256 - 1) / 15)
    report("extrapolation/ski_kernel_long_max",
           float(jnp.abs(k_long).max()), "abs",
           "bounded at 4x length (interp, not extrap)")
    assert np.isfinite(np.asarray(k_long)).all()

    # --- FD model NLL at train length vs 2x length
    acfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"), n_layers=2,
                            d_model=64, vocab=vocab)
    acfg = dataclasses.replace(acfg, scan_layers=False)
    params, _ = unbox(init_model(jax.random.PRNGKey(0), acfg))
    ocfg = adamw.OptConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
    opt = adamw.init(ocfg, params)
    dcfg = DataConfig(vocab=vocab, seq_len=seq_len, global_batch=16,
                      kind="synthetic", seed=0)

    @jax.jit
    def train_step(params, opt, b):
        (loss, metr), grads = jax.value_and_grad(
            lambda p: loss_fn(p, acfg, Ctx(), b), has_aux=True)(params)
        opt, params, _ = adamw.step(ocfg, opt, grads, params)
        return params, opt, metr["nll"]

    for step in range(steps):
        b = {k: jnp.asarray(v) for k, v in batch_at(dcfg, step).items()}
        params, opt, _ = train_step(params, opt, b)

    def eval_nll(slen):
        dc = DataConfig(vocab=vocab, seq_len=slen, global_batch=16,
                        kind="synthetic", seed=1)
        b = {k: jnp.asarray(v) for k, v in batch_at(dc, 0).items()}
        _, metr = loss_fn(params, acfg, Ctx(), b)
        return float(metr["nll"])

    nll_train_len = eval_nll(seq_len)
    nll_2x = eval_nll(2 * seq_len)
    report("extrapolation/fd_nll_train_len", nll_train_len, "nll")
    report("extrapolation/fd_nll_2x_len", nll_2x, "nll",
           "paper Fig7a: flat PPL vs inference length")


if __name__ == "__main__":
    run()
