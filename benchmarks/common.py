"""Shared benchmark utilities: wall-clock timing on CPU with jit warmup.

CPU wall-times are meaningful as *ratios between variants measured on the
same host* (paper's speedup claims are reproduced as such ratios); absolute
TPU numbers come from the dry-run roofline instead (EXPERIMENTS §Roofline).
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters=5, warmup=2):
    """Median wall seconds per call of a jit'd fn (blocks on results)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def report(name: str, value, unit: str, derived: str = ""):
    print(f"{name},{value:.6g},{unit},{derived}")
