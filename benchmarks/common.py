"""Shared benchmark utilities: wall-clock timing on CPU with jit warmup.

CPU wall-times are meaningful as *ratios between variants measured on the
same host* (paper's speedup claims are reproduced as such ratios); absolute
TPU numbers come from the dry-run roofline instead (EXPERIMENTS §Roofline).
"""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters=5, warmup=2):
    """Median wall seconds per call of a jit'd fn (blocks on results)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_fns_interleaved(fns, *args, iters=7, warmup=2):
    """Best wall seconds per call for several variants, measured in
    alternating rounds (A B C, A B C, ...) with min-of-rounds — robust to
    the load drift on shared hosts that sequential medians are not."""
    for fn in fns:
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    best = [float("inf")] * len(fns)
    for _ in range(iters):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def report(name: str, value, unit: str, derived: str = ""):
    print(f"{name},{value:.6g},{unit},{derived}")
