"""Paper Table 1 stand-in: causal LM pretraining quality parity.

Wikitext-103 is unavailable offline; the deterministic Zipf-Markov corpus
(local bigram + long-range copy structure) stands in. The paper's claim is
*parity*: FD-TNN matches TNN perplexity (24.56 vs 24.61 on wt103). Here:
train TNN / FD-TNN / SKI-TNN for the same budget; final PPLs must be far
below the unigram baseline and within a few percent of each other.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import report
from repro.configs import get_config, reduce_for_smoke
from repro.data.pipeline import DataConfig, batch_at
from repro.models.context import Ctx
from repro.models.transformer import init_model, loss_fn
from repro.nn.params import unbox
from repro.optim import adamw


def run(steps=80, seq_len=128, batch=16, vocab=256):
    ppls = {}
    for variant in ("tno", "fd", "ski"):
        cfg = reduce_for_smoke(
            get_config("tnn-lm-wt103"), n_layers=2, d_model=64, vocab=vocab,
            tno_rank=16, tno_filter=8)
        cfg = dataclasses.replace(cfg, pattern=((variant, "dense"),),
                                  scan_layers=False)
        params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
        ocfg = adamw.OptConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
        opt = adamw.init(ocfg, params)
        dcfg = DataConfig(vocab=vocab, seq_len=seq_len, global_batch=batch,
                          kind="synthetic", seed=0)

        @jax.jit
        def train_step(params, opt, b):
            (loss, metr), grads = jax.value_and_grad(
                lambda p: loss_fn(p, cfg, Ctx(), b), has_aux=True)(params)
            opt, params, _ = adamw.step(ocfg, opt, grads, params)
            return params, opt, metr["nll"]

        for step in range(steps):
            b = {k: jnp.asarray(v) for k, v in batch_at(dcfg, step).items()}
            params, opt, nll = train_step(params, opt, b)
        # eval on held-out steps
        evals = []
        for step in range(90_000, 90_005):
            b = {k: jnp.asarray(v) for k, v in batch_at(dcfg, step).items()}
            _, metr = loss_fn(params, cfg, Ctx(), b)
            evals.append(float(metr["nll"]))
        ppls[variant] = float(np.exp(np.mean(evals)))
        report(f"pretrain_parity/ppl_{variant}", ppls[variant], "ppl",
               "paper Tab1 stand-in")
    spread = (max(ppls.values()) - min(ppls.values())) / min(ppls.values())
    report("pretrain_parity/ppl_spread", 100 * spread, "%",
           "paper: FD matches TNN (small spread)")
    return ppls


if __name__ == "__main__":
    run()
