"""Complexity accounting (paper §3.2.1/§3.3): compiled HLO FLOPs of each
TNO variant vs sequence length — the backend-independent form of the
paper's O(n log n) → O(n + r log r) claim (single-core CPU wall-clock
constants do not transfer; TPU wall-clock needs hardware; FLOPs are
invariant). Expect: SKI FLOPs grow ~linearly in n and sit far below TNO;
FD ≈ TNO minus the kernel-side FFT and the 2n-1 RPE MLP evaluations."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import report
from repro.core.tno import TNOConfig, tno_apply, tno_init
from repro.nn.params import unbox


def _flops(cfg, n, d=64, b=4):
    params, _ = unbox(tno_init(jax.random.PRNGKey(0), cfg))
    x = jax.ShapeDtypeStruct((b, n, d), jnp.float32)
    pa = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    comp = jax.jit(lambda p, x: tno_apply(p, cfg, x)).lower(pa, x).compile()
    return float(comp.cost_analysis().get("flops", -1))


def run():
    d = 64
    flops = {}
    for n in (2048, 8192, 32768):
        for variant in ("tno", "ski", "fd"):
            cfg = TNOConfig(d=d, variant=variant, causal=False, rank=64,
                            filter_size=32, rpe_layers=3)
            f = _flops(cfg, n, d=d)
            flops[(variant, n)] = f
            report(f"complexity/{variant}_flops_n{n}", f, "flops")
    for n in (8192, 32768):
        report(f"complexity/ski_vs_tno_n{n}",
               flops[("tno", n)] / max(flops[("ski", n)], 1), "x",
               "paper 3.2.1: SKI's O(n+r log r) < O(n log n)")
    # linearity: SKI flops at 4x n should be ~4x (not 4x·log-factor)
    growth = flops[("ski", 32768)] / max(flops[("ski", 8192)], 1)
    report("complexity/ski_growth_8k_to_32k", growth, "x",
           "~4 = linear in n")


if __name__ == "__main__":
    run()
