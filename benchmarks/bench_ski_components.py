"""Paper Figure 11 + fused-pipeline tracking.

Part 1 (Figure 11): times the SKI-TNO with (a) both components, (b)
low-rank only, (c) sparse only — reproducing the paper's observation that
the low-rank path is the primary bottleneck but the sparse conv still adds
substantial time.

Part 2 (this repo's perf trajectory): fused two-pass SKI pipeline vs the
4-kernel unfused pipeline at n ∈ {2048, 8192}. The unfused baseline is
measured as it executes in a kernel-per-op runtime — four separately
compiled launches with the (b, n, d) activation streamed between them —
which is exactly the memory-movement overhead the fusion removes (paper
§3.2: their sparse PyTorch path lost the asymptotic win the same way). A
monolithic single-jit unfused number is reported alongside for reference.
Results land in BENCH_ski_fused.json at the repo root.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import pathlib

import jax
import jax.numpy as jnp

from benchmarks.common import report, time_fn, time_fns_interleaved
from repro.core import toeplitz
from repro.core.ski import (SKIConfig, inducing_gram_coeffs, make_inducing,
                            ski_init, ski_plan, ski_tno_apply)
from repro.kernels import backend, ops
from repro.nn.params import unbox

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_ski_fused.json"


def _fig11(params, cfg, x, n):
    t_both = time_fn(jax.jit(lambda p, x: ski_tno_apply(p, cfg, x)),
                     params, x)

    def low_only(p, x):
        r = cfg.rank
        idx_lo, w_lo, h = make_inducing(n, r)
        z = ops.interp_reduce(x, idx_lo, w_lo, r, use_pallas=False)
        a_coef = inducing_gram_coeffs(p, cfg, r, h)
        zt = toeplitz.toeplitz_matvec(a_coef[None], jnp.swapaxes(z, 1, 2))
        return ops.interp_expand(jnp.swapaxes(zt, 1, 2), idx_lo, w_lo,
                                 use_pallas=False)

    t_low = time_fn(jax.jit(low_only), params, x)
    t_sparse = time_fn(
        jax.jit(lambda p, x: ops.short_conv(x, p["filt"], False,
                                            use_pallas=False)), params, x)

    report("ski_components/both", t_both * 1e3, "ms")
    report("ski_components/low_rank_only", t_low * 1e3, "ms",
           "paper Fig11: low rank dominates")
    report("ski_components/sparse_only", t_sparse * 1e3, "ms",
           "paper Fig11: conv adds substantial time")


def _unfused_launches(cfg, n, a_coef):
    """The seed 4-kernel pipeline as four separate compiled launches: conv,
    reduce, Gram matvec, expand(+add) — (b, n, d) crosses HBM between each.
    ``a_coef`` is precomputed (same footing as the fused variant's plan)."""
    r = cfg.rank
    idx_lo, w_lo, _ = make_inducing(n, r)
    k_conv = jax.jit(lambda p, x: ops.short_conv(x, p["filt"], False,
                                                 use_pallas=False))
    k_reduce = jax.jit(lambda x: ops.interp_reduce(x, idx_lo, w_lo, r,
                                                   use_pallas=False))
    k_gram = jax.jit(lambda z: jnp.swapaxes(toeplitz.toeplitz_matvec(
        a_coef[None], jnp.swapaxes(z, 1, 2)), 1, 2))
    k_expand = jax.jit(lambda z2, y_sparse: y_sparse + ops.interp_expand(
        z2, idx_lo, w_lo, use_pallas=False))

    def run(p, x):
        y_sparse = k_conv(p, x)
        z = k_reduce(x)
        z2 = k_gram(z)
        return k_expand(z2, y_sparse)

    return run


def _grad_fused_vs_unfused(sizes, d=64, b=4, iters=5):
    """PR 2: time jax.grad through the fused custom-VJP pipeline vs the
    unfused 4-kernel pipeline (one jit each, loss = sum(y), plan built
    inside the differentiated function so parameter grads flow through the
    Gram/RPE precomputation). Appended as the "bwd" section of
    BENCH_ski_fused.json; the CI perf gate covers it alongside forward."""
    rows = []
    for n in sizes:
        cfg_f = SKIConfig(d=d, rank=64, filter_size=32, fused=True)
        cfg_u = dataclasses.replace(cfg_f, fused=False)
        key = jax.random.PRNGKey(0)
        params, _ = unbox(ski_init(key, cfg_f))
        x = jax.random.normal(key, (b, n, d))

        def make_grad(cfg):
            def loss(p, x):
                plan = ski_plan(p, cfg, n)
                return jnp.sum(ski_tno_apply(p, cfg, x, plan=plan))
            return jax.jit(jax.grad(loss))

        t_fwd = time_fn(
            jax.jit(lambda p, x, c=cfg_f: jnp.sum(
                ski_tno_apply(p, c, x, plan=ski_plan(p, c, n)))),
            params, x, iters=iters)
        t_f, t_u = time_fns_interleaved(
            [make_grad(cfg_f), make_grad(cfg_u)], params, x, iters=iters)
        speedup = t_u / t_f
        report(f"ski_fused/n{n}/bwd_fused", t_f * 1e3, "ms",
               "grad of fused two-pass pipeline")
        report(f"ski_fused/n{n}/bwd_unfused", t_u * 1e3, "ms",
               "grad of 4-kernel unfused pipeline")
        report(f"ski_fused/n{n}/bwd_speedup", speedup, "x",
               "fused backward must not fall behind unfused (ISSUE 2)")
        report(f"ski_fused/n{n}/bwd_over_fwd", t_f / t_fwd, "x",
               "backward cost ratio (linear ops: expect ~2-3x)")
        rows.append({
            "n": n, "b": b, "d": d, "rank": 64, "filter_size": 32,
            "fused_grad_ms": t_f * 1e3,
            "unfused_grad_ms": t_u * 1e3,
            "fused_fwd_ms": t_fwd * 1e3,
            "bwd_speedup_vs_unfused": speedup,
            "bwd_over_fwd": t_f / t_fwd,
        })
    return rows


def _fused_vs_unfused(sizes, d=64, b=4, iters=5):
    rows = []
    for n in sizes:
        cfg_f = SKIConfig(d=d, rank=64, filter_size=32, fused=True)
        cfg_u = dataclasses.replace(cfg_f, fused=False)
        key = jax.random.PRNGKey(0)
        params, _ = unbox(ski_init(key, cfg_f))
        x = jax.random.normal(key, (b, n, d))

        # all three variants get the same precomputed per-forward plan
        # (core/block.py builds it outside the ops either way), so the
        # timed region is pipeline execution only
        plan_f = ski_plan(params, cfg_f, n)
        plan_u = ski_plan(params, cfg_u, n)
        # interleaved min-of-rounds: variants alternate within each round so
        # host load drift hits all three equally (sequential medians on a
        # shared CPU can swing 30%+ between variants)
        t_fused, t_unf_launch, t_unf_mono = time_fns_interleaved([
            jax.jit(lambda p, x: ski_tno_apply(p, cfg_f, x, plan=plan_f)),
            _unfused_launches(cfg_u, n, plan_u["a_coef"]),
            jax.jit(lambda p, x: ski_tno_apply(p, cfg_u, x, plan=plan_u)),
        ], params, x, iters=iters)

        speedup = t_unf_launch / t_fused
        report(f"ski_fused/n{n}/fused", t_fused * 1e3, "ms",
               "two-pass fused pipeline")
        report(f"ski_fused/n{n}/unfused_4launch", t_unf_launch * 1e3, "ms",
               "seed 4-kernel pipeline, per-op launches")
        report(f"ski_fused/n{n}/unfused_monolithic", t_unf_mono * 1e3, "ms",
               "4-kernel pipeline under one jit")
        report(f"ski_fused/n{n}/speedup_vs_4launch", speedup, "x",
               "fused must beat unfused (ISSUE 1)")
        rows.append({
            "n": n, "b": b, "d": d, "rank": 64, "filter_size": 32,
            "fused_ms": t_fused * 1e3,
            "unfused_4launch_ms": t_unf_launch * 1e3,
            "unfused_monolithic_ms": t_unf_mono * 1e3,
            "speedup_vs_4launch": speedup,
        })
    return rows


def _large_r(b=2, d=16, n=8192, iters=4):
    """ISSUE 3: fwd + bwd across the rank regimes, r ∈ {64, 512, 2048,
    8192}. Each row times the fused pipeline twice: as the dense-Gram
    variant (where the (d, r, r) materialisation is feasible — r ≤ 2048
    here; at 8192 it would be 4 GB) and as the dispatched coefficient
    variant (windowed/fft — on this CPU host both execute the reference
    coefficient pipeline, FFT Gram; the windowed/fft split is a
    kernel-level VMEM strategy with identical reference semantics).
    Lands in BENCH_ski_fused.json "large_r"; CI gates that the windowed
    variant beats the dense-Gram path at r ≥ 2048.
    """
    rows = []
    key = jax.random.PRNGKey(0)
    for r in (64, 512, 2048, 8192):
        cfg = SKIConfig(d=d, rank=r, filter_size=32)
        params, _ = unbox(ski_init(key, cfg))
        x = jax.random.normal(key, (b, n, d))
        variant = backend.ski_rank_variant(r, d)
        coef_variant = variant if variant != "dense" else "windowed"

        def fwd(p, x, v):
            plan = ski_plan(p, cfg, n, variant=v)
            return jnp.sum(ski_tno_apply(p, cfg, x, plan=plan))

        def make_grad(v):
            return jax.jit(jax.grad(functools.partial(fwd, v=v)))

        fns = [jax.jit(functools.partial(fwd, v=coef_variant)),
               make_grad(coef_variant)]
        dense_ok = r <= 2048            # (d, r, r) fits on the bench host
        if dense_ok:
            fns += [jax.jit(functools.partial(fwd, v="dense")),
                    make_grad("dense")]
        t = time_fns_interleaved(fns, params, x, iters=iters, warmup=1)
        coef_fwd, coef_grad = t[0], t[1]
        dense_fwd, dense_grad = (t[2], t[3]) if dense_ok else (None, None)

        report(f"ski_large_r/r{r}/{coef_variant}_fwd", coef_fwd * 1e3, "ms",
               "coefficient-Gram fused pipeline")
        report(f"ski_large_r/r{r}/{coef_variant}_grad", coef_grad * 1e3,
               "ms")
        row = {"r": r, "n": n, "b": b, "d": d,
               "variant_default": variant,
               "coef_variant": coef_variant,
               "coef_fwd_ms": coef_fwd * 1e3,
               "coef_grad_ms": coef_grad * 1e3,
               "dense_fwd_ms": dense_fwd and dense_fwd * 1e3,
               "dense_grad_ms": dense_grad and dense_grad * 1e3}
        if dense_ok:
            row["fwd_speedup_vs_dense"] = dense_fwd / coef_fwd
            row["grad_speedup_vs_dense"] = dense_grad / coef_grad
            report(f"ski_large_r/r{r}/fwd_speedup_vs_dense",
                   row["fwd_speedup_vs_dense"], "x",
                   "windowed must beat dense-Gram at r >= 2048 (ISSUE 3)")
        rows.append(row)
    return rows


def _write_json(rows, bwd_rows, large_r_rows):
    payload = {
        "bench": "ski_fused_vs_unfused",
        "platform": backend.platform(),
        "use_pallas_default": backend.use_pallas_default(),
        "results": rows,
        "bwd": bwd_rows,
        "large_r": large_r_rows,
    }
    try:
        _JSON_PATH.write_text(json.dumps(payload, indent=1) + "\n")
    except OSError as e:
        report("ski_fused/json_write_error", 0, "", repr(e))


def run(smoke: bool = False):
    d, b, n = 64, 4, 2048
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, n, d))
    cfg = SKIConfig(d=d, rank=64, filter_size=32)
    params, _ = unbox(ski_init(key, cfg))

    if not smoke:
        # the Fig11 split decomposes the UNFUSED pipeline (its low/sparse
        # arms are the unfused component kernels) — keep 'both' coherent
        _fig11(params, dataclasses.replace(cfg, fused=False), x, n)
    sizes = [2048] if smoke else [2048, 8192]
    rows = _fused_vs_unfused(sizes, iters=10 if smoke else 12)
    bwd_rows = _grad_fused_vs_unfused(sizes, iters=5 if smoke else 8)
    large_r_rows = _large_r(iters=3 if smoke else 5)
    _write_json(rows, bwd_rows, large_r_rows)


if __name__ == "__main__":
    run()
