"""Paper Figure 11: SKI low-rank-only vs sparse+low-rank cost split.

Times the SKI-TNO with (a) both components, (b) low-rank only, (c) sparse
only — reproducing the paper's observation that the low-rank path is the
primary bottleneck but the sparse conv still adds substantial time."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import report, time_fn
from repro.core.ski import SKIConfig, ski_init, ski_tno_apply
from repro.core import toeplitz
from repro.kernels import ops
from repro.nn.params import unbox


def run():
    d, b, n = 64, 4, 2048
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (b, n, d))
    cfg = SKIConfig(d=d, rank=64, filter_size=32)
    params, _ = unbox(ski_init(key, cfg))

    t_both = time_fn(jax.jit(lambda p, x: ski_tno_apply(p, cfg, x)),
                     params, x)

    from repro.core.ski import inducing_gram_coeffs, make_inducing

    def low_only(p, x):
        r = cfg.rank
        idx_lo, w_lo, h = make_inducing(n, r)
        z = ops.interp_reduce(x, idx_lo, w_lo, r, use_pallas=False)
        a_coef = inducing_gram_coeffs(p, cfg, r, h)
        zt = toeplitz.toeplitz_matvec(a_coef[None], jnp.swapaxes(z, 1, 2))
        return ops.interp_expand(jnp.swapaxes(zt, 1, 2), idx_lo, w_lo,
                                 use_pallas=False)

    t_low = time_fn(jax.jit(low_only), params, x)
    t_sparse = time_fn(
        jax.jit(lambda p, x: ops.short_conv(x, p["filt"], False,
                                            use_pallas=False)), params, x)

    report("ski_components/both", t_both * 1e3, "ms")
    report("ski_components/low_rank_only", t_low * 1e3, "ms",
           "paper Fig11: low rank dominates")
    report("ski_components/sparse_only", t_sparse * 1e3, "ms",
           "paper Fig11: conv adds substantial time")


if __name__ == "__main__":
    run()
