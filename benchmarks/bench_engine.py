"""Continuous-batching engine throughput (ISSUE 5).

Aggregate decode tok/s of the slot-based engine (repro.serving_engine)
vs *sequential* single-request serving (``launch/serve.generate`` per
request, warm compiled step — StepBuilder memoises the jitted serve
step, so the sequential baseline pays tracing once, not per request) at
S ∈ {1, 4, 16} concurrent slots. Same requests, same length bucket
(max_len), greedy decode both sides; per-request **token-exact parity**
is recorded alongside the timing — the speedup must come from batching,
never from changed math.

Both drivers run a warm pass first (compile) and are then timed for
``rounds`` alternating passes with min-of-rounds (benchmarks/common.py
discipline: robust to shared-host load drift).

Results land in BENCH_engine.json; the CI gate requires S=16 aggregate
throughput ≥ 4x sequential with parity=true on every row (measured ~8x
on CPU smoke shapes — the batch amortises the per-step layer scan and
small-matmul dispatch that dominate single-row decode).
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import report
from repro.configs import get_config, reduce_for_smoke
from repro.kernels import backend
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.launch.steps import StepBuilder
from repro.models.transformer import init_model
from repro.nn.params import unbox
from repro.serving_engine import Engine, Request, Scheduler

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_engine.json"


def _requests(cfg, n, prompt_len, gen_len, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32)
               for _ in range(n)]
    # staggered budgets exercise eviction/recycle inside the timed region
    gens = [gen_len - 4 * (i % 4) for i in range(n)]
    return prompts, gens


def _row(cfg, params, sb, slots, prompt_len, gen_len, max_len, rounds=2):
    prompts, gens = _requests(cfg, slots, prompt_len, gen_len)
    n_new = sum(gens)

    def seq_pass():
        outs = []
        for pr, g in zip(prompts, gens):
            toks = generate(sb, params, jnp.asarray(pr)[None], g,
                            max_len=max_len)
            outs.append(np.asarray(toks)[0, prompt_len:])
        return outs

    eng = Engine(cfg, params, slots=slots, max_len=max_len)

    def eng_pass():
        sched = Scheduler(eng)
        for i, (pr, g) in enumerate(zip(prompts, gens)):
            sched.submit(Request(uid=f"r{i}", prompt=pr, max_new=g))
        results, _ = sched.run()
        return [np.asarray(results[f"r{i}"]) for i in range(slots)]

    solo = seq_pass()                           # warm (compile) + reference
    got = eng_pass()
    parity = all(np.array_equal(g, s) for g, s in zip(got, solo))

    t_seq = t_eng = float("inf")
    for _ in range(rounds):                     # interleaved min-of-rounds
        t0 = time.perf_counter()
        seq_pass()
        t_seq = min(t_seq, time.perf_counter() - t0)
        t0 = time.perf_counter()
        eng_pass()
        t_eng = min(t_eng, time.perf_counter() - t0)

    seq_tok_s, eng_tok_s = n_new / t_seq, n_new / t_eng
    report(f"engine/S{slots}/seq_tok_s", seq_tok_s, "tok/s",
           "sequential generate, warm jitted step")
    report(f"engine/S{slots}/engine_tok_s", eng_tok_s, "tok/s",
           "continuous-batching engine, aggregate")
    report(f"engine/S{slots}/speedup", t_seq / t_eng, "x",
           "S=16 must be >= 4x (ISSUE 5)")
    report(f"engine/S{slots}/parity", float(parity), "bool",
           "token-exact per request vs solo decode")
    return {
        "slots": slots, "requests": slots, "prompt_len": prompt_len,
        "gen_lens": gens, "max_len": max_len, "tokens": n_new,
        "seq_s": t_seq, "engine_s": t_eng,
        "seq_tok_s": seq_tok_s, "engine_tok_s": eng_tok_s,
        "speedup": t_seq / t_eng, "parity": bool(parity),
        "decode_traces": eng.trace_counts["generate"],
    }


def run(smoke: bool = False):
    # match the stream block to the prompt bucket so prefill rides whole
    # C-blocks (one rfft per prompt) on both sides of the comparison
    os.environ.setdefault("REPRO_FD_STREAM_C", "16")
    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"), dtype="float32",
                           param_dtype="float32")
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    mesh = make_host_mesh()
    sb = StepBuilder(cfg, mesh)
    prompt_len, gen_len = 16, 48 if smoke else 64
    max_len = prompt_len + gen_len
    rows = []
    with mesh:
        for slots in (1, 4, 16):
            rows.append(_row(cfg, params, sb, slots, prompt_len, gen_len,
                             max_len, rounds=2 if smoke else 3))
    payload = {
        "bench": "engine",
        "platform": backend.platform(),
        "arch": cfg.name,
        "results": rows,
    }
    try:
        _JSON_PATH.write_text(json.dumps(payload, indent=1) + "\n")
    except OSError as e:
        report("engine/json_write_error", 0, "", repr(e))


if __name__ == "__main__":
    print("name,value,unit,derived")
    run()
