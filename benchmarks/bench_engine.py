"""Continuous-batching engine throughput + latency (ISSUEs 5, 7, 9).

Four sections, all landing in BENCH_engine.json:

* ``results`` — aggregate decode tok/s of the slot-based engine
  (repro.serving_engine) vs *sequential* single-request serving
  (``launch/serve.generate`` per request, warm compiled step —
  StepBuilder memoises the jitted serve step, so the sequential
  baseline pays tracing once, not per request) at S ∈ {1, 4, 16}
  concurrent slots. Same requests, same length bucket (max_len), greedy
  decode both sides; per-request **token-exact parity** is recorded
  alongside the timing — the speedup must come from batching, never
  from changed math. CI gate: S=16 ≥ 4x with parity=true on every row.
* ``latency`` — an **open-loop Poisson arrival trace** (exponential
  inter-arrival times from a seeded rng, submitted by a second thread
  while the scheduler idles in ``run(stop=...)``): per-request TTFT
  (submit → first streamed token) and TPOT (mean gap between streamed
  tokens) measured at the ``on_token`` callback — i.e. *through* the
  async detokenise worker, which is what a client observes — reduced to
  p50/p99 per slot count. CI gate: present and finite (absolute wall
  times are load-dependent; the percentile *columns* are the contract).
* ``prefill`` — pure-admission throughput (max_new=1 requests: prefill
  + first token, no decode occupancy) of packed batch prefill
  (prefill_pack=4) vs the sequential b=1 admission loop
  (prefill_pack=1) at S=16, same bucketed executables both sides. CI
  gate: packed ≥ 1.5x.
* ``obs`` — observability overhead at S=16: the identical engine drain
  with the metrics registry + span tracer (JSONL streaming to disk)
  enabled vs disabled. CI gate: ``overhead_frac`` < 0.05 (ISSUE 9 —
  instrumentation must be cheap enough to leave on in production).

Both drivers of every timed comparison run a warm pass first (compile)
and are then timed for ``rounds`` alternating passes with min-of-rounds
(benchmarks/common.py discipline: robust to shared-host load drift).
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import report
from repro.configs import get_config, reduce_for_smoke
from repro.kernels import backend
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.launch.steps import StepBuilder
from repro.models.transformer import init_model
from repro.nn.params import unbox
from repro.serving_engine import Engine, Request, Scheduler

_JSON_PATH = pathlib.Path(__file__).resolve().parents[1] / "BENCH_engine.json"


def _requests(cfg, n, prompt_len, gen_len, seed=0):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32)
               for _ in range(n)]
    # staggered budgets exercise eviction/recycle inside the timed region
    gens = [gen_len - 4 * (i % 4) for i in range(n)]
    return prompts, gens


def _row(cfg, params, sb, slots, prompt_len, gen_len, max_len, rounds=2):
    prompts, gens = _requests(cfg, slots, prompt_len, gen_len)
    n_new = sum(gens)

    def seq_pass():
        outs = []
        for pr, g in zip(prompts, gens):
            toks = generate(sb, params, jnp.asarray(pr)[None], g,
                            max_len=max_len)
            outs.append(np.asarray(toks)[0, prompt_len:])
        return outs

    eng = Engine(cfg, params, slots=slots, max_len=max_len)

    def eng_pass():
        sched = Scheduler(eng)
        for i, (pr, g) in enumerate(zip(prompts, gens)):
            sched.submit(Request(uid=f"r{i}", prompt=pr, max_new=g))
        results, _ = sched.run()
        return [np.asarray(results[f"r{i}"]) for i in range(slots)]

    solo = seq_pass()                           # warm (compile) + reference
    got = eng_pass()
    parity = all(np.array_equal(g, s) for g, s in zip(got, solo))

    t_seq = t_eng = float("inf")
    for _ in range(rounds):                     # interleaved min-of-rounds
        t0 = time.perf_counter()
        seq_pass()
        t_seq = min(t_seq, time.perf_counter() - t0)
        t0 = time.perf_counter()
        eng_pass()
        t_eng = min(t_eng, time.perf_counter() - t0)

    seq_tok_s, eng_tok_s = n_new / t_seq, n_new / t_eng
    report(f"engine/S{slots}/seq_tok_s", seq_tok_s, "tok/s",
           "sequential generate, warm jitted step")
    report(f"engine/S{slots}/engine_tok_s", eng_tok_s, "tok/s",
           "continuous-batching engine, aggregate")
    report(f"engine/S{slots}/speedup", t_seq / t_eng, "x",
           "S=16 must be >= 4x (ISSUE 5)")
    report(f"engine/S{slots}/parity", float(parity), "bool",
           "token-exact per request vs solo decode")
    return {
        "slots": slots, "requests": slots, "prompt_len": prompt_len,
        "gen_lens": gens, "max_len": max_len, "tokens": n_new,
        "seq_s": t_seq, "engine_s": t_eng,
        "seq_tok_s": seq_tok_s, "engine_tok_s": eng_tok_s,
        "speedup": t_seq / t_eng, "parity": bool(parity),
        "decode_traces": eng.trace_counts["generate"],
    }


def _latency_row(cfg, params, slots, prompt_len, gen_len, max_len,
                 n_req, rate_hz, seed=0):
    """Open-loop Poisson trace: a submitter thread feeds the scheduler at
    ``rate_hz`` mean arrivals/s while it serves in run(stop=...) online
    mode; TTFT/TPOT are measured at callback delivery (post detok
    worker) and reduced to p50/p99."""
    eng = Engine(cfg, params, slots=slots, max_len=max_len)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32)
               for _ in range(n_req)]
    gens = [gen_len - (i % 4) for i in range(n_req)]

    # warm pass: compile prefill/insert/generate outside the timed trace
    warm = Scheduler(eng)
    warm.submit(Request(uid="warm", prompt=prompts[0], max_new=2))
    warm.run()

    t_submit, t_first, t_last, counts = {}, {}, {}, {}

    def on_token(uid, tok):
        now = time.perf_counter()
        if uid not in t_first:
            t_first[uid] = now
        t_last[uid] = now
        counts[uid] = counts.get(uid, 0) + 1

    sched = Scheduler(eng)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_hz, n_req))
    done = {"v": False}

    def submitter():
        start = time.perf_counter()
        for i in range(n_req):
            delay = start + arrivals[i] - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            uid = f"r{i}"
            t_submit[uid] = time.perf_counter()
            sched.submit(Request(uid=uid, prompt=prompts[i],
                                 max_new=gens[i], on_token=on_token))
        done["v"] = True

    th = threading.Thread(target=submitter)
    t0 = time.perf_counter()
    th.start()
    results, _ = sched.run(stop=lambda: done["v"])
    th.join()
    wall = time.perf_counter() - t0

    ttft = np.array([t_first[u] - t_submit[u] for u in t_submit])
    tpot = np.array([(t_last[u] - t_first[u]) / (counts[u] - 1)
                     for u in t_submit if counts[u] > 1])
    n_tok = sum(len(v) for v in results.values())
    row = {
        "slots": slots, "requests": n_req, "rate_hz": rate_hz,
        "prompt_len": prompt_len, "gen_lens": gens, "tokens": n_tok,
        "wall_s": wall, "tok_s": n_tok / wall,
        "ttft_p50_s": float(np.percentile(ttft, 50)),
        "ttft_p99_s": float(np.percentile(ttft, 99)),
        "tpot_p50_s": float(np.percentile(tpot, 50)),
        "tpot_p99_s": float(np.percentile(tpot, 99)),
        "packed_prefills": sched.packed_prefills,
    }
    for k in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s"):
        report(f"engine/S{slots}/{k[:-2]}", row[k] * 1e3, "ms",
               f"Poisson trace rate={rate_hz}/s, n={n_req}")
    return row


def _prefill_row(cfg, params, slots, prompt_len, n_req, max_len,
                 rounds=3, seed=0):
    """Pure-admission throughput: max_new=1 requests finish at their
    first (prefill-sampled) token, so the drain time is admission work
    only — packed batch prefill vs the sequential b=1 loop, same
    bucketed executables."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab, (prompt_len,)).astype(np.int32)
               for _ in range(n_req)]
    engines = {"packed": Engine(cfg, params, slots=slots, max_len=max_len),
               "b1": Engine(cfg, params, slots=slots, max_len=max_len)}
    packs = {"packed": 4, "b1": 1}
    # build each engine's decode state ONCE outside the timed drains:
    # init_state materialises the full S-slot cache (~10x the cost of a
    # single admission) and max_new=1 requests never touch it, so paying
    # it per drain would just dilute the packed-vs-b1 admission ratio
    states = {name: eng.init_state() for name, eng in engines.items()}

    def drain(name, tag):
        sched = Scheduler(engines[name], prefill_pack=packs[name])
        for i, pr in enumerate(prompts):
            sched.submit(Request(uid=f"{tag}{i}", prompt=pr, max_new=1))
        results, states[name] = sched.run(states[name])
        return results

    got_packed = drain("packed", "w")            # warm both executables
    got_b1 = drain("b1", "x")
    # packed admission must not change the (greedy) first token
    parity = all(got_packed[f"w{i}"] == got_b1[f"x{i}"]
                 for i in range(n_req))

    times = {"packed": float("inf"), "b1": float("inf")}
    tags = {"packed": "tp", "b1": "tq"}
    for r in range(rounds):
        for name in ("packed", "b1"):
            t0 = time.perf_counter()
            drain(name, f"{tags[name]}{r}_")
            times[name] = min(times[name], time.perf_counter() - t0)
    speedup = times["b1"] / times["packed"]
    report(f"engine/S{slots}/prefill_packed_req_s",
           n_req / times["packed"], "req/s", "packed admission (pack=4)")
    report(f"engine/S{slots}/prefill_b1_req_s",
           n_req / times["b1"], "req/s", "sequential b=1 admission")
    report(f"engine/S{slots}/prefill_pack_speedup", speedup, "x",
           "must be >= 1.5x at S=16 (ISSUE 7)")
    report(f"engine/S{slots}/prefill_parity", float(parity), "bool",
           "packed first tokens == sequential first tokens")
    return {
        "slots": slots, "requests": n_req, "prompt_len": prompt_len,
        "packed_s": times["packed"], "b1_s": times["b1"],
        "packed_req_s": n_req / times["packed"],
        "b1_req_s": n_req / times["b1"],
        "speedup": speedup, "parity": bool(parity),
    }


def _obs_row(cfg, params, slots, prompt_len, gen_len, max_len, rounds=3):
    """Observability overhead at S=16 (ISSUE 9 gate, extended by ISSUE
    10): the identical engine drain with the full obs stack on —
    metrics registry, span tracer streaming JSONL to disk, *and* the
    kernel tier (compile watchdog via the engine registry + periodic
    memory-gauge sampling), chrome export excluded (it runs after
    serving) — vs off. Interleaved min-of-rounds; the CI contract is
    overhead_frac < 5% with the kernel tier enabled.

    A final instrumented pass feeds ``devstats.attribute_engine``:
    ``attributed_coverage`` is the fraction of that drain's wall time
    accounted for by the scheduler's device-call histograms (the basis
    of the per-kernel seconds split). CI contract: ≥ 0.8 at S=16."""
    import tempfile

    from repro.obs import devstats as obs_devstats
    from repro.obs import metrics as obs_metrics
    from repro.obs import tracing as obs_tracing

    # Both contracts are steady-state claims. At the smoke gen_len a
    # pass drains in ~0.2s, where per-pass fixed costs (admission,
    # tracer file open/close, scheduler construction) and runner noise
    # read as several percent of fake overhead and ~0.67 coverage; 4x
    # the generation amortises them (measured: overhead ~1%, coverage
    # ~0.9 — the same numbers a production-length drain shows).
    gen_len = gen_len * 4
    max_len = prompt_len + gen_len
    prompts, gens = _requests(cfg, slots, prompt_len, gen_len)
    n_new = sum(gens)
    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    passes = {"n": 0}
    # instrumented passes construct the engine with its registry so the
    # compile watchdog + trace_counts mirror land there; the base engine
    # stays fully uninstrumented (NullRegistry)
    eng_base = Engine(cfg, params, slots=slots, max_len=max_len,
                      metrics=obs_metrics.NULL_REGISTRY)
    reg = obs_metrics.Registry()
    eng_obs = Engine(cfg, params, slots=slots, max_len=max_len,
                     metrics=reg)

    def one_pass(obs: bool, registry=None):
        passes["n"] += 1
        if obs:
            kw = {"metrics": registry if registry is not None else reg,
                  "tracer": obs_tracing.Tracer(
                      os.path.join(tmp, f"t{passes['n']}.jsonl")),
                  "mem_sample_every": 8}
            sched = Scheduler(eng_obs, **kw)
        else:
            sched = Scheduler(eng_base,
                              metrics=obs_metrics.NULL_REGISTRY)
        for i, (pr, g) in enumerate(zip(prompts, gens)):
            sched.submit(Request(uid=f"r{i}", prompt=pr, max_new=g))
        sched.run()
        if obs:
            kw["tracer"].close()

    one_pass(False)                              # warm (compile) both paths
    one_pass(True)
    t_base = t_obs = float("inf")
    for _ in range(rounds):                      # interleaved min-of-rounds
        t0 = time.perf_counter()
        one_pass(False)
        t_base = min(t_base, time.perf_counter() - t0)
        t0 = time.perf_counter()
        one_pass(True)
        t_obs = min(t_obs, time.perf_counter() - t0)

    # attribution coverage on a dedicated pass: fresh registry so the
    # histogram sums cover exactly one measured drain
    reg_attr = obs_metrics.Registry()
    t0 = time.perf_counter()
    one_pass(True, registry=reg_attr)
    t_attr = time.perf_counter() - t0
    attr = obs_devstats.attribute_engine(eng_obs, reg_attr, drain_s=t_attr)
    coverage = attr["coverage"] or 0.0

    overhead = t_obs / t_base - 1.0
    report(f"engine/S{slots}/obs_off_tok_s", n_new / t_base, "tok/s",
           "metrics+trace disabled (NullRegistry, no tracer)")
    report(f"engine/S{slots}/obs_on_tok_s", n_new / t_obs, "tok/s",
           "registry + tracer + kernel tier (watchdog, mem gauges)")
    report(f"engine/S{slots}/obs_overhead", overhead * 100, "%",
           "must be < 5% (ISSUE 9; kernel tier on since ISSUE 10)")
    report(f"engine/S{slots}/obs_attr_coverage", coverage, "frac",
           "device-call seconds / drain wall; must be >= 0.8 (ISSUE 10)")
    return {
        "slots": slots, "tokens": n_new,
        "base_s": t_base, "obs_s": t_obs,
        "overhead_frac": overhead,
        "attributed_coverage": coverage,
        "attributed_device_s": attr["device_s"],
        "kernel_rows": attr["rows"],
        "compiles": eng_obs.compile_watch.counts(),
    }


def run(smoke: bool = False):
    # match the stream block to the prompt bucket so prefill rides whole
    # C-blocks (one rfft per prompt) on both sides of the comparison
    os.environ.setdefault("REPRO_FD_STREAM_C", "16")
    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"), dtype="float32",
                           param_dtype="float32")
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    mesh = make_host_mesh()
    sb = StepBuilder(cfg, mesh)
    prompt_len, gen_len = 16, 48 if smoke else 64
    max_len = prompt_len + gen_len
    rows = []
    lat_rows = []
    with mesh:
        for slots in (1, 4, 16):
            rows.append(_row(cfg, params, sb, slots, prompt_len, gen_len,
                             max_len, rounds=2 if smoke else 3))
        for slots in (4, 16):
            lat_rows.append(_latency_row(
                cfg, params, slots, prompt_len,
                gen_len=12 if smoke else 24, max_len=max_len,
                n_req=2 * slots, rate_hz=4.0))
        prefill_row = _prefill_row(
            cfg, params, slots=16, prompt_len=prompt_len,
            n_req=16, max_len=max_len, rounds=2 if smoke else 3)
        # the overhead gate compares ~1s drains on shared CI hosts where
        # scheduler-noise bursts reach several percent; min-of-5 gives
        # each side enough samples to land in a clean window
        obs_row = _obs_row(cfg, params, slots=16, prompt_len=prompt_len,
                           gen_len=gen_len, max_len=max_len, rounds=5)
    payload = {
        "bench": "engine",
        "platform": backend.platform(),
        "arch": cfg.name,
        "results": rows,
        "latency": lat_rows,
        "prefill": prefill_row,
        "obs": obs_row,
    }
    try:
        _JSON_PATH.write_text(json.dumps(payload, indent=1) + "\n")
    except OSError as e:
        report("engine/json_write_error", 0, "", repr(e))


if __name__ == "__main__":
    print("name,value,unit,derived")
    run()
