"""Roofline analysis (EXPERIMENTS §Roofline): three terms per (arch ×
shape) on the single-pod mesh, derived from the dry-run artifacts.

    compute    = FLOPs_dev / 197e12            (bf16 MXU peak per chip)
    memory     = HLO_bytes_dev / 819e9         (HBM bandwidth per chip)
    collective = coll_bytes_dev / 50e9         (ICI per link)

All inputs are PER-DEVICE (verified: XLA cost_analysis reports post-SPMD
per-device numbers) with while-loop undercount corrected by the unrolled
probe extrapolation (dryrun.py). MODEL_FLOPS uses 6·N·D (dense) /
6·N_active·D (MoE) for train, 2·N·D for decode/prefill token counts.

  PYTHONPATH=src python -m benchmarks.roofline results/dryrun_single_pod.json
"""
from __future__ import annotations

import json
import sys

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e class)
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link
N_DEV = 256


def model_flops(cfg, shape_kind, seq_len, global_batch):
    pc = cfg.param_count()
    n_active = pc["active"]
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2 * n_active * tokens
    # decode: one new token per row
    return 2 * n_active * global_batch


def analyze(cells, *, with_probes=True):
    from repro.configs import get_config
    from repro.launch.steps import SHAPES
    rows = []
    for c in cells:
        if "error" in c:
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "error": c["error"]})
            continue
        probe = c.get("probe", {}).get("extrapolated", {})
        flops_dev = probe.get("flops", c["flops"])
        bytes_dev = probe.get("hlo_bytes", c["hlo_bytes"])
        coll_dev = probe.get("collective_bytes_total",
                             c["collective_bytes"].get("total", 0))
        t_comp = flops_dev / PEAK_FLOPS
        t_mem = bytes_dev / HBM_BW
        t_coll = coll_dev / ICI_BW
        dominant = max((("compute", t_comp), ("memory", t_mem),
                        ("collective", t_coll)), key=lambda kv: kv[1])[0]
        cfg = get_config(c["arch"])
        shape = SHAPES[c["shape"]]
        mf = model_flops(cfg, shape.kind, shape.seq_len, shape.global_batch)
        mf_dev = mf / c["devices"]
        useful = mf_dev / max(flops_dev, 1)
        # roofline fraction: useful work over the time the dominant term
        # implies (= achievable MFU bound for this artifact)
        t_star = max(t_comp, t_mem, t_coll)
        frac = (mf_dev / PEAK_FLOPS) / max(t_star, 1e-30)
        mem = c["memory"]
        hbm = ((mem["argument_size"] or 0) + (mem["temp_size"] or 0)
               + (mem["output_size"] or 0)) / 2 ** 30
        rows.append({
            "arch": c["arch"], "shape": c["shape"],
            "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
            "dominant": dominant, "useful_ratio": useful,
            "roofline_frac": frac, "hbm_gib": hbm,
        })
    return rows


def main(path="results/dryrun_single_pod.json"):
    cells = json.load(open(path))
    rows = analyze(cells)
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'dom':>10s} {'useful':>7s} {'frac':>6s} "
           f"{'HBM GiB':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "error" in r:
            print(f"{r['arch']:24s} {r['shape']:12s} ERROR {r['error'][:60]}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
              f"{r['collective_s']:9.4f} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.3f} {r['roofline_frac']:6.3f} "
              f"{r['hbm_gib']:8.2f}")
    return rows


if __name__ == "__main__":
    main(*sys.argv[1:])
