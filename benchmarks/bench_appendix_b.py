"""Appendix B negative result: causal masking negates SKI's benefit.

Compares the causal low-rank SKI action (cumulative-sum algorithm of
Katharopoulos et al., as analysed in the paper's Appendix B) against the
FD-TNO causal mixer at equal d. The paper's conclusion — the cumsum path
loses to the FFT path for moderate n — must reproduce on this backend
(the O(n·r·d) work and (b,n,r,d) intermediate are backend-independent).
"""
from __future__ import annotations

import jax

from benchmarks.common import report, time_fn
from repro.core.causal_ski import causal_ski_lowrank
from repro.core.fd import FDConfig, fd_init, fd_tno_apply
from repro.core.ski import SKIConfig, ski_init
from repro.nn.params import unbox


def run():
    d, b, r = 32, 2, 64
    key = jax.random.PRNGKey(0)
    for n in (512, 2048):
        x = jax.random.normal(key, (b, n, d))
        scfg = SKIConfig(d=d, rank=r, filter_size=16)
        sparams, _ = unbox(ski_init(key, scfg))
        t_cumsum = time_fn(
            jax.jit(lambda p, x: causal_ski_lowrank(p, scfg, x)), sparams, x)
        fcfg = FDConfig(d=d, causal=True, rpe_layers=3)
        fparams, _ = unbox(fd_init(key, fcfg))
        t_fd = time_fn(
            jax.jit(lambda p, x: fd_tno_apply(p, fcfg, x)), fparams, x)
        report(f"appendix_b/causal_ski_cumsum_n{n}", t_cumsum * 1e3, "ms")
        report(f"appendix_b/fd_causal_n{n}", t_fd * 1e3, "ms")
        report(f"appendix_b/cumsum_slowdown_n{n}", t_cumsum / t_fd, "x",
               "paper App.B: causal SKI loses -> use FD for causal")


if __name__ == "__main__":
    run()
