"""Per-Pallas-kernel allclose sweeps against the ref.py pure-jnp oracles.

Every kernel runs in interpret mode (kernel body executed in Python on
CPU) across shape × dtype sweeps; tolerances are fp32-accumulation level.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ski import make_inducing
from repro.kernels import ops, ref
from tests.conftest import assert_allclose


def _x(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# ------------------------------------------------------------- short conv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,n,d,m", [
    (1, 256, 128, 4), (2, 512, 128, 8), (2, 256, 256, 16), (1, 1024, 128, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_short_conv_matches_ref(b, n, d, m, causal, dtype):
    x = _x(0, (b, n, d), dtype)
    filt = _x(1, (d, m), dtype)
    got = ops.short_conv(x, filt, causal, use_pallas=True)
    want = ref.short_conv_ref(x, filt, causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    assert_allclose(got, want, rtol=tol, atol=tol)


def test_short_conv_is_banded_toeplitz():
    """The conv equals multiplication by an m-diagonal Toeplitz matrix —
    the paper's T_sparse definition (§3.2)."""
    b, n, d, m = 1, 64, 4, 8
    x = _x(0, (b, n, d), jnp.float32)
    filt = _x(1, (d, m), jnp.float32)
    y = ref.short_conv_ref(x, filt, causal=False)
    left = m // 2
    i = jnp.arange(n)
    lag = i[:, None] - i[None, :]
    k_idx = lag + left
    valid = (k_idx >= 0) & (k_idx < m)
    t_sp = jnp.where(valid[None], filt[:, jnp.clip(k_idx, 0, m - 1)], 0.0)
    want = jnp.einsum("dnm,bmd->bnd", t_sp, x)
    assert_allclose(y, want)


# --------------------------------------------------------- interp matvecs
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,n,d,r", [
    (1, 256, 128, 9), (2, 512, 128, 33), (2, 512, 256, 65), (1, 2048, 128, 17),
])
def test_interp_reduce_matches_ref(b, n, d, r, dtype):
    x = _x(0, (b, n, d), dtype)
    idx_lo, w_lo, h = make_inducing(n, r)
    got = ops.interp_reduce(x, idx_lo, w_lo, r, use_pallas=True)
    want = ref.interp_reduce_ref(x, idx_lo, w_lo, r)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,n,d,r", [
    (1, 256, 128, 9), (2, 512, 128, 33), (1, 1024, 256, 65),
])
def test_interp_expand_matches_ref(b, n, d, r, dtype):
    z = _x(0, (b, r, d), dtype)
    idx_lo, w_lo, h = make_inducing(n, r)
    got = ops.interp_expand(z, idx_lo, w_lo, use_pallas=True)
    want = ref.interp_expand_ref(z, idx_lo, w_lo)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    assert_allclose(got, want, rtol=tol, atol=tol)


def test_interp_matrices_match_dense_W():
    """Pallas hat-weight regeneration == materialised W (oracle)."""
    n, r = 512, 17
    idx_lo, w_lo, h = make_inducing(n, r)
    w = ref.dense_interp_matrix(idx_lo, w_lo, r)                 # (n, r)
    x = _x(0, (1, n, 128), jnp.float32)
    z = ops.interp_reduce(x, idx_lo, w_lo, r, use_pallas=True)
    assert_allclose(z[0], w.T @ x[0], rtol=1e-3, atol=1e-3)
    zz = _x(1, (1, r, 128), jnp.float32)
    y = ops.interp_expand(zz, idx_lo, w_lo, use_pallas=True)
    assert_allclose(y[0], w @ zz[0], rtol=1e-3, atol=1e-3)


def test_interp_W_rows_sum_to_one():
    """Interpolation weights are a partition of unity (each row of W sums
    to 1) — required for the SKI approximation to preserve constants."""
    n, r = 300, 11
    idx_lo, w_lo, h = make_inducing(n, r)
    w = ref.dense_interp_matrix(idx_lo, w_lo, r)
    assert_allclose(w.sum(axis=1), np.ones(n))


# --------------------------------------------------------------- SSD scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bt,n,h,p,g,s,chunk", [
    (1, 64, 2, 8, 1, 8, 16), (2, 128, 4, 16, 2, 16, 32),
    (1, 96, 4, 8, 4, 8, 32),  # n not multiple of chunk
])
def test_ssd_chunked_matches_sequential(bt, n, h, p, g, s, chunk, dtype):
    x = _x(0, (bt, n, h, p), dtype)
    dt = jax.nn.softplus(_x(1, (bt, n, h), jnp.float32))
    a = -jnp.exp(0.1 * _x(2, (h,), jnp.float32))
    b = _x(3, (bt, n, g, s), dtype)
    c = _x(4, (bt, n, g, s), dtype)
    dsk = jnp.ones((h,))
    want = ref.ssd_scan_ref(x, dt, a, b, c, dsk)
    got = ops.ssd_scan(x, dt, a, b, c, dsk, chunk=chunk, use_pallas=False)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("bt,n,h,p,g,s,chunk", [
    (1, 64, 2, 8, 1, 8, 16), (2, 128, 4, 16, 2, 16, 32),
])
def test_ssd_pallas_matches_sequential(bt, n, h, p, g, s, chunk):
    x = _x(0, (bt, n, h, p), jnp.float32)
    dt = jax.nn.softplus(_x(1, (bt, n, h), jnp.float32))
    a = -jnp.exp(0.1 * _x(2, (h,), jnp.float32))
    b = _x(3, (bt, n, g, s), jnp.float32)
    c = _x(4, (bt, n, g, s), jnp.float32)
    dsk = jnp.ones((h,))
    want = ref.ssd_scan_ref(x, dt, a, b, c, dsk)
    got = ops.ssd_scan(x, dt, a, b, c, dsk, chunk=chunk, use_pallas=True)
    assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_ssd_decode_step_matches_scan():
    """Serving recurrence == one step of the training scan."""
    from repro.kernels.ssd_chunked import ssd_decode_step
    bt, n, h, p, g, s = 1, 8, 2, 4, 1, 8
    x = _x(0, (bt, n, h, p), jnp.float32)
    dt = jax.nn.softplus(_x(1, (bt, n, h), jnp.float32))
    a = -jnp.exp(0.1 * _x(2, (h,), jnp.float32))
    b = _x(3, (bt, n, g, s), jnp.float32)
    c = _x(4, (bt, n, g, s), jnp.float32)
    dsk = 0.5 * jnp.ones((h,))
    want = ref.ssd_scan_ref(x, dt, a, b, c, dsk)
    state = jnp.zeros((bt, h, p, s), jnp.float32)
    ys = []
    for t in range(n):
        state, y = ssd_decode_step(state, x[:, t], dt[:, t], a, b[:, t],
                                   c[:, t], dsk)
        ys.append(y)
    got = jnp.stack(ys, axis=1)
    assert_allclose(got, want, rtol=1e-4, atol=1e-4)
