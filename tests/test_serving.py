"""Serving parity matrix: for every decode-supported mixer family
({tno, fd, attention, mamba}), prefill + token-by-token decode must
reproduce the one-shot training-style forward logits position-by-position,
at atol-tiered fp32/bf16 precision. (The FD streaming-vs-hist parity lives
in tests/test_fd_stream.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models.context import Ctx
from repro.models import serving
from repro.models.transformer import forward, init_model
from repro.nn.params import unbox

# one arch per mixer family (smoke-reduced); tnn archs are the paper's own
MIXER_ARCHS = {
    "tno": "tnn-lm-wt103",
    "fd": "fd-tnn-lm-wt103",
    "attention": "stablelm-3b",
    "mamba": "mamba2-2.7b",
}
TOL = {"float32": dict(rtol=2e-2, atol=2e-2),
       "bfloat16": dict(rtol=2e-1, atol=2e-1)}


def _decode_all(params, cfg, toks, cache):
    got = []
    b, s = toks.shape
    for t in range(s):
        logits, cache = serving.decode_step(
            params, cfg, Ctx(decode=True), {"tokens": toks[:, t:t + 1]},
            cache, jnp.int32(t))
        got.append(logits[:, 0])
    return jnp.stack(got, 1)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("mixer", sorted(MIXER_ARCHS))
def test_decode_matches_forward_per_mixer(mixer, dtype):
    cfg = reduce_for_smoke(get_config(MIXER_ARCHS[mixer]), dtype=dtype,
                           param_dtype=dtype)
    assert any(m == mixer for m, _ in cfg.layers_spec), cfg.layers_spec
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    b, s = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    want, _ = forward(params, cfg, Ctx(), {"tokens": toks, "labels": toks})
    # parameter-aware cache: fd gets the streaming cache, others unchanged
    cache = serving.init_cache(cfg, b, s, params=params)
    got = _decode_all(params, cfg, toks, cache)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_fd_decode_matches_forward_across_blocks(monkeypatch):
    """FD streaming decode vs one-shot forward with a sequence spanning
    several C-blocks plus a partial block (C=4, s=11)."""
    monkeypatch.setenv("REPRO_FD_STREAM_C", "4")
    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"), dtype="float32",
                           param_dtype="float32")
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    b, s = 2, 11
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    want, _ = forward(params, cfg, Ctx(), {"tokens": toks, "labels": toks})
    cache = serving.init_cache(cfg, b, s, params=params)
    assert serving.stream_block_of(cache) == 4
    got = _decode_all(params, cfg, toks, cache)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_init_cache_without_params_keeps_legacy_layout():
    """Shape-only callers (dry-run input specs) must keep getting the
    parameter-free hist cache for fd mixers — eval_shape safe."""
    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"))
    cache = jax.eval_shape(lambda: serving.init_cache(cfg, 2, 16))
    leaves = jax.tree_util.tree_leaves_with_path(cache)
    names = {getattr(p[-1], "key", "") for p, _ in leaves}
    assert "hist" in names and "ring" not in names and "kcoef" not in names


@pytest.mark.parametrize("mixer", ["tno", "fd"])
def test_hist_plan_realised_once_per_layer_bucket(mixer, monkeypatch):
    """Plan reuse (ISSUE 5 satellite): with a params-aware cache the
    per-layer kernel realisation (RPE spectrum / coefficient eval) runs
    exactly once per (sub-layer, length-bucket) at init — NOT once per
    decode step — and the memoised decode stays correct."""
    if mixer == "fd":
        monkeypatch.setenv("REPRO_FD_STREAM", "0")   # force hist fallback
    cfg = reduce_for_smoke(get_config(MIXER_ARCHS[mixer]), dtype="float32",
                           param_dtype="float32")
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    b, s = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    want, _ = forward(params, cfg, Ctx(), {"tokens": toks, "labels": toks})

    serving.PLAN_EVALS[mixer] = 0
    cache = serving.init_cache(cfg, b, s, params=params)
    # one realisation trace per sub-layer slot (scan blocks share one
    # vmapped trace), none during decode
    assert serving.PLAN_EVALS[mixer] == cfg.period
    got = _decode_all(params, cfg, toks, cache)
    assert serving.PLAN_EVALS[mixer] == cfg.period
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **TOL["float32"])

    # the params-less cache keeps the legacy per-step evaluation (and the
    # counter proves it is actually counting)
    serving.PLAN_EVALS[mixer] = 0
    legacy = serving.init_cache(cfg, b, s)
    _decode_all(params, cfg, toks, legacy)
    assert serving.PLAN_EVALS[mixer] == s * cfg.period


def test_decode_step_vector_cur_len_matches_scalar():
    """decode_step with a (b,) position vector of equal entries is
    bit-identical to the scalar call (the lockstep case is the ragged
    case broadcast) — for every decode-supported mixer family."""
    for mixer, arch in MIXER_ARCHS.items():
        cfg = reduce_for_smoke(get_config(arch), dtype="float32",
                               param_dtype="float32")
        params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
        b, s = 2, 6
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                  cfg.vocab)
        c_s = serving.init_cache(cfg, b, s, params=params)
        c_v = jax.tree.map(lambda x: x, c_s)
        for t in range(s):
            lg_s, c_s = serving.decode_step(
                params, cfg, Ctx(decode=True), {"tokens": toks[:, t:t + 1]},
                c_s, jnp.int32(t))
            lg_v, c_v = serving.decode_step(
                params, cfg, Ctx(decode=True), {"tokens": toks[:, t:t + 1]},
                c_v, jnp.full((b,), t, jnp.int32))
            np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v),
                                          err_msg=f"{mixer} t={t}")
