"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_allclose(a, b, rtol=2e-4, atol=2e-4, err_msg=""):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol, err_msg=err_msg)
