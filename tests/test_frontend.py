"""Scheduler front-end (ISSUE 7): bucketed/packed prefill, async
detokenise, sampled decode, snapshot-with-worker.

Contracts under test:
* bucket ladder — geometric rungs, C-aligned, one ``prefill_bucket``
  trace per (batch, bucket, n_tok) triple and NOT one per prompt length;
* packed prefill — every row of a packed batch prefill is bitwise the
  cache (and greedy first token) of a b=1 prefill of that prompt alone,
  and scheduler-level packed admission is token-exact vs sequential;
* async detok — callbacks preserve emit order through the worker, a
  raising callback detaches without losing recorded tokens, and a
  tiny-capacity queue (backpressure) still delivers every token;
* sampled decode — seeded streams are reproducible and slot-placement
  independent; T=0 with seeds attached is bit-equal to greedy;
* snapshot/restore — preempting from the worker thread itself still
  yields a token-exact resume (the snapshot drains the worker first).
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.models.transformer import init_model
from repro.nn.params import unbox
from repro.serving_engine import Engine, Request, Scheduler
from repro.serving_engine.state import BATCH_AXIS_FROM_END, take_row

ARCH = "fd-tnn-lm-wt103"


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config(ARCH), dtype="float32",
                           param_dtype="float32")
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    return cfg, params


@pytest.fixture(autouse=True)
def _stream_c(monkeypatch):
    monkeypatch.setenv("REPRO_FD_STREAM_C", "4")


def _prompts(cfg, plens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
            for p in plens]


# ------------------------------------------------------- bucket ladder
def test_bucket_ladder_shape(setup):
    cfg, params = setup
    eng = Engine(cfg, params, slots=1, max_len=16, bucket0=4)
    assert eng.buckets == [4, 8, 16]
    assert eng.bucket_for(1) == 4 and eng.bucket_for(4) == 4
    assert eng.bucket_for(5) == 8 and eng.bucket_for(16) == 16
    # bucket0 is rounded up to the stream block C
    assert Engine(cfg, params, slots=1, max_len=16,
                  bucket0=3).buckets == [4, 8, 16]
    # disabled ladder: everything is off-bucket (per-length fallback)
    off = Engine(cfg, params, slots=1, max_len=16, use_buckets=False)
    assert off.bucket_for(4) is None


def test_prefill_retraces_per_bucket_not_per_length(setup):
    """Ragged lengths inside one bucket share ONE executable; only a
    bucket change (or the aligned fast path n_tok=0) compiles again."""
    cfg, params = setup
    eng = Engine(cfg, params, slots=1, max_len=16, bucket0=4)
    for p in (2, 3):                     # same (B=1, Lb=4, n_tok=4)
        eng.prefill(_prompts(cfg, [p], seed=p)[0])
    assert eng.trace_counts["prefill_bucket"] == 1, eng.trace_counts
    eng.prefill(_prompts(cfg, [4])[0])   # aligned fast path: n_tok=0
    assert eng.trace_counts["prefill_bucket"] == 2, eng.trace_counts
    for p in (5, 6, 7):                  # next rung (B=1, Lb=8, n_tok=4)
        eng.prefill(_prompts(cfg, [p], seed=p)[0])
    assert eng.trace_counts["prefill_bucket"] == 3, eng.trace_counts
    # the per-length fallback stayed cold: bucketed prompts never touch it
    assert eng.trace_counts["decode1"] == 0, eng.trace_counts
    assert eng.trace_counts["chunk1"] == 0, eng.trace_counts


def test_packed_prefill_traces_once_per_batch_size(setup):
    cfg, params = setup
    eng = Engine(cfg, params, slots=4, max_len=16, bucket0=4)
    for seed in (0, 1):                  # two packs, same (B=3, Lb=8, n_tok=4)
        eng.prefill_packed(_prompts(cfg, [3, 6, 5], seed=seed))
    assert eng.trace_counts["prefill_bucket"] == 1, eng.trace_counts
    eng.prefill_packed(_prompts(cfg, [2, 3], seed=2))   # B=2: new executable
    assert eng.trace_counts["prefill_bucket"] == 2, eng.trace_counts


# ------------------------------------------------- packed prefill parity
def test_packed_rows_bitwise_equal_b1_prefill(setup):
    """Row i of prefill_packed == a b=1 prefill of prompt i alone: same
    greedy first token AND bitwise-identical per-slot cache leaves."""
    cfg, params = setup
    eng = Engine(cfg, params, slots=4, max_len=16, bucket0=4)
    prompts = _prompts(cfg, [3, 6, 5, 8], seed=7)   # ragged + one aligned
    packed, first, plens = eng.prefill_packed(prompts)
    first = np.asarray(first)
    for i, pr in enumerate(prompts):
        solo_cache, solo_first, _ = eng.prefill(pr)
        assert first[i] == int(solo_first), i
        row = take_row(packed, i)

        def check(path, a, b, i=i):
            names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
            if BATCH_AXIS_FROM_END.get(names[-1] if names else "") is None:
                return a                  # shared constant leaf
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"row {i} leaf {names[-1]}")
            return a
        jax.tree_util.tree_map_with_path(check, row, solo_cache)


def test_scheduler_packed_admission_token_exact(setup):
    """End-to-end: packed admission (prefill_pack=4) produces the exact
    token streams of sequential b=1 admission (prefill_pack=1)."""
    cfg, params = setup
    prompts = _prompts(cfg, [3, 7, 5, 9, 4, 6], seed=11)
    gens = [8, 5, 10, 6, 7, 9]

    def serve(pack):
        eng = Engine(cfg, params, slots=4, max_len=32)
        sched = Scheduler(eng, prefill_pack=pack)
        for i, (pr, g) in enumerate(zip(prompts, gens)):
            sched.submit(Request(uid=f"r{i}", prompt=pr, max_new=g))
        res, _ = sched.run()
        return res, sched

    packed_res, packed_sched = serve(4)
    seq_res, seq_sched = serve(1)
    assert packed_sched.packed_prefills >= 1
    assert seq_sched.packed_prefills == 0
    assert packed_res == seq_res


def test_off_ladder_prompts_fall_back_to_sequential(setup):
    """With bucketing disabled every admission takes the per-length
    loop; results still match the bucketed engine exactly."""
    cfg, params = setup
    prompts = _prompts(cfg, [3, 6, 5], seed=13)

    def serve(**kw):
        eng = Engine(cfg, params, slots=4, max_len=24, **kw)
        sched = Scheduler(eng)
        for i, pr in enumerate(prompts):
            sched.submit(Request(uid=f"r{i}", prompt=pr, max_new=6))
        res, _ = sched.run()
        return res, eng, sched

    res_b, eng_b, _ = serve()
    res_o, eng_o, sched_o = serve(use_buckets=False)
    assert res_b == res_o
    assert eng_b.trace_counts["prefill_bucket"] >= 1
    assert eng_o.trace_counts["prefill_bucket"] == 0
    assert sched_o.packed_prefills == 0          # nothing was packable


# ----------------------------------------------------------- async detok
def test_detok_ordering_and_detach_on_raise(setup):
    """Callbacks fire in emit order through the worker; a raising
    callback is detached (callback_error) without losing the request's
    recorded tokens or disturbing its neighbours."""
    cfg, params = setup
    prompts = _prompts(cfg, [3, 5, 4], seed=17)
    order, streamed = [], {}

    def good(uid, tok):
        order.append((uid, tok))
        streamed.setdefault(uid, []).append(tok)

    def bad(uid, tok):
        streamed.setdefault(uid, []).append(tok)
        if len(streamed[uid]) == 3:
            raise RuntimeError("client hung up")

    eng = Engine(cfg, params, slots=3, max_len=24)
    sched = Scheduler(eng, detok_async=True)
    sched.submit(Request(uid="a", prompt=prompts[0], max_new=8,
                         on_token=good))
    sched.submit(Request(uid="b", prompt=prompts[1], max_new=8,
                         on_token=bad))
    sched.submit(Request(uid="c", prompt=prompts[2], max_new=8,
                         on_token=good))
    res, _ = sched.run()

    assert sched.outcomes["b"].callback_error is not None
    assert "client hung up" in sched.outcomes["b"].callback_error
    assert sched.outcomes["b"].status == "ok"    # stream kept recording
    assert len(res["b"]) == 8
    assert streamed["b"] == res["b"][:3]         # detached after the raise
    for uid in ("a", "c"):
        assert sched.outcomes[uid].status == "ok"
        assert streamed[uid] == res[uid]
        # per-uid callback order is the emit order
        assert [t for u, t in order if u == uid] == res[uid]


def test_detok_backpressure_tiny_queue(setup):
    """detok_cap=1 with a slow callback: the scheduler blocks on put
    instead of buffering unboundedly, and still delivers every token in
    order by the time run() returns (exit drain)."""
    import time as _time
    cfg, params = setup
    prompts = _prompts(cfg, [3, 4], seed=19)
    streamed = {}

    def slow(uid, tok):
        _time.sleep(0.001)
        streamed.setdefault(uid, []).append(tok)

    eng = Engine(cfg, params, slots=2, max_len=24)
    sched = Scheduler(eng, detok_async=True, detok_cap=1)
    for i, pr in enumerate(prompts):
        sched.submit(Request(uid=f"r{i}", prompt=pr, max_new=10,
                             on_token=slow))
    res, _ = sched.run()
    for i in range(2):
        assert streamed[f"r{i}"] == res[f"r{i}"], i


def test_detok_sync_mode_still_works(setup):
    """detok_async=False is the PR 6 inline path — same observables."""
    cfg, params = setup
    prompts = _prompts(cfg, [3], seed=23)
    streamed = []
    eng = Engine(cfg, params, slots=1, max_len=16)
    sched = Scheduler(eng, detok_async=False)
    sched.submit(Request(uid="r0", prompt=prompts[0], max_new=6,
                         on_token=lambda u, t: streamed.append(t)))
    res, _ = sched.run()
    assert streamed == res["r0"]


# --------------------------------------------------------- sampled decode
def test_sampled_seeded_reproducible_and_placement_independent(setup):
    """Same request seeds → identical sampled streams, run to run AND
    across different slot counts / submission orders (the key lanes
    derive from the request seed, never from slot placement)."""
    cfg, params = setup
    prompts = _prompts(cfg, [3, 6, 5, 4], seed=29)
    seeds = [101, 202, 303, 404]

    def serve(slots, order):
        eng = Engine(cfg, params, slots=slots, max_len=24,
                     temperature=0.7, top_k=8)
        sched = Scheduler(eng)
        for i in order:
            sched.submit(Request(uid=f"r{i}", prompt=prompts[i],
                                 max_new=7, seed=seeds[i]))
        res, _ = sched.run()
        return res

    a = serve(2, [0, 1, 2, 3])
    b = serve(2, [0, 1, 2, 3])           # rerun: bitwise reproducible
    c = serve(4, [3, 1, 0, 2])           # different placement
    assert a == b
    assert a == c
    # distinct seeds actually decorrelate (same prompt, two seeds)
    eng = Engine(cfg, params, slots=2, max_len=24, temperature=0.9)
    sched = Scheduler(eng)
    sched.submit(Request(uid="x", prompt=prompts[0], max_new=12, seed=1))
    sched.submit(Request(uid="y", prompt=prompts[0], max_new=12, seed=2))
    res, _ = sched.run()
    assert res["x"] != res["y"]


def test_sampled_t0_equals_greedy(setup):
    """temperature=0 with request seeds attached is bit-equal to the
    greedy engine: seeds are inert outside the sampling branch."""
    cfg, params = setup
    prompts = _prompts(cfg, [3, 6], seed=31)

    def serve(**eng_kw):
        eng = Engine(cfg, params, slots=2, max_len=24, **eng_kw)
        sched = Scheduler(eng)
        for i, pr in enumerate(prompts):
            sched.submit(Request(uid=f"r{i}", prompt=pr, max_new=9,
                                 seed=555 + i))
        res, _ = sched.run()
        return res

    assert serve(temperature=0.0) == serve()


def test_sampled_validation(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="temperature"):
        Engine(cfg, params, slots=1, max_len=16, temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        Engine(cfg, params, slots=1, max_len=16, top_k=-1)


# ------------------------------------------------ snapshot + worker live
def test_snapshot_restore_with_worker_live(setup, tmp_path):
    """Preempt mid-run FROM the detok worker thread (the callback calls
    preempt()), restore in a fresh scheduler, and the union of streamed
    tokens across both runs is exactly the uninterrupted baseline —
    the final snapshot drains the worker before capturing."""
    cfg, params = setup
    prompts = _prompts(cfg, [3, 5, 4], seed=37)
    gens = [10, 8, 12]

    def fleet(cbs):
        return [Request(uid=f"r{i}", prompt=pr, max_new=g,
                        on_token=cbs.get(f"r{i}"))
                for i, (pr, g) in enumerate(zip(prompts, gens))]

    # uninterrupted baseline
    sched = Scheduler(Engine(cfg, params, slots=2, max_len=24))
    for r in fleet({}):
        sched.submit(r)
    baseline, _ = sched.run()

    streamed1 = {}
    sched1 = Scheduler(Engine(cfg, params, slots=2, max_len=24),
                       snapshot_dir=str(tmp_path), snapshot_every=2,
                       detok_async=True)

    def cb1(uid, tok):
        streamed1.setdefault(uid, []).append(tok)
        if sum(map(len, streamed1.values())) == 9:
            sched1.preempt()             # from the worker thread

    for r in fleet({u: cb1 for u in ("r0", "r1", "r2")}):
        sched1.submit(r)
    sched1.run()
    assert sched1.preempted
    partial = sum(map(len, sched1.results.values()))
    assert partial < sum(map(len, baseline.values()))

    streamed2 = {}

    def cb2(uid, tok):
        streamed2.setdefault(uid, []).append(tok)

    sched2 = Scheduler(Engine(cfg, params, slots=2, max_len=24),
                       snapshot_dir=str(tmp_path), detok_async=True)
    assert sched2.try_restore(
        callbacks={u: cb2 for u in ("r0", "r1", "r2")})
    resumed, _ = sched2.run()
    for uid in baseline:
        assert sched2.outcomes[uid].status == "ok"
        assert resumed[uid] == baseline[uid], uid
        # every token streamed exactly once across the two runs
        assert (streamed1.get(uid, []) + streamed2.get(uid, [])
                == baseline[uid]), uid


def test_request_seed_snapshot_roundtrip(setup, tmp_path):
    """A queued sampled request's seed survives snapshot/restore: the
    resumed stream equals the uninterrupted one."""
    cfg, params = setup
    prompts = _prompts(cfg, [3, 4], seed=41)

    def fleet():
        return [Request(uid=f"r{i}", prompt=pr, max_new=8, seed=777 + i)
                for i, pr in enumerate(prompts)]

    def engine():
        return Engine(cfg, params, slots=1, max_len=16, temperature=0.8)

    sched = Scheduler(engine())
    for r in fleet():
        sched.submit(r)
    baseline, _ = sched.run()

    counter = {"n": 0}
    sched1 = Scheduler(engine(), snapshot_dir=str(tmp_path),
                       snapshot_every=1)

    def kill(uid, tok):
        counter["n"] += 1
        if counter["n"] == 3:
            sched1.preempt()

    for r in fleet():
        r.on_token = kill
        sched1.submit(r)
    sched1.run()
    assert sched1.preempted

    sched2 = Scheduler(engine(), snapshot_dir=str(tmp_path))
    assert sched2.try_restore()
    resumed, _ = sched2.run()
    assert resumed == baseline
