"""Custom-VJP training path: Pallas backward kernels vs reference autodiff.

These are the tests the CI ``grad-parity`` job runs with forced-Pallas
dispatch (interpret mode on CPU — custom_vjp bypasses the pallas_call
autodiff limitation, so the backward is CI-testable without a TPU).

Tolerances are the PR-2 acceptance gates: max relative error
(max|pallas − ref| / max|ref|) ≤ 1e-5 for fp32, ≤ 2e-2 for bf16 with
fp32 accumulation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ski
from repro.core.block import TNNBlockConfig, tnn_block_apply, tnn_block_init
from repro.core.tno import TNOConfig
from repro.kernels import backend, ops, ref, ski_vjp
from repro.kernels.ski_grad import conv_tap_grad_pallas, gram_grad_pallas
from repro.nn.layers import cast_params
from repro.nn.params import unbox

TOL = {jnp.float32: 1e-5, jnp.bfloat16: 2e-2}


def rel_err(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return float(np.abs(got - want).max() / (np.abs(want).max() + 1e-12))


def _setup(d=8, rank=9, m=6, seed=0):
    cfg = ski.SKIConfig(d=d, rank=rank, filter_size=m)
    params, _ = unbox(ski.ski_init(jax.random.PRNGKey(seed), cfg))
    return cfg, params


# ----------------------------------------------- fused op: grad parity
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("n,d,r,m", [
    (64, 16, 9, 6),
    (75, 20, 11, 4),        # ragged n and d (pad + slice on both axes)
])
def test_fused_tno_grad_parity(n, d, r, m, causal, dtype):
    """jax.grad of the custom-VJP kernel op == jax.grad of the reference
    path, for every cotangent (x, a_dense, filt)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, n, d)).astype(dtype)
    a = jax.random.normal(jax.random.PRNGKey(1), (d, r, r))
    filt = jax.random.normal(jax.random.PRNGKey(2), (d, m)) * 0.1
    idx_lo, w_lo, _ = ski.make_inducing(n, r)

    def loss(x, a, f, use_pallas):
        y = ops.ski_fused_tno(x, a, f, idx_lo, w_lo, r, causal,
                              use_pallas=use_pallas)
        return jnp.sum(jnp.sin(y.astype(jnp.float32)))

    gp = jax.grad(lambda *args: loss(*args, True), argnums=(0, 1, 2))(
        x, a, filt)
    gr = jax.grad(lambda *args: loss(*args, False), argnums=(0, 1, 2))(
        x, a, filt)
    for name, p, q in zip(("x", "a_dense", "filt"), gp, gr):
        assert rel_err(p, q) <= TOL[dtype], (name, rel_err(p, q))


def test_fused_tno_grad_dtypes_preserved():
    n, d, r, m = 64, 16, 9, 6
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n, d), jnp.bfloat16)
    a = jax.random.normal(jax.random.PRNGKey(1), (d, r, r))      # fp32
    filt = jax.random.normal(jax.random.PRNGKey(2), (d, m))      # fp32
    idx_lo, w_lo, _ = ski.make_inducing(n, r)
    gx, ga, gf = jax.grad(
        lambda x, a, f: ops.ski_fused_tno(
            x, a, f, idx_lo, w_lo, r, False,
            use_pallas=True).astype(jnp.float32).sum(),
        argnums=(0, 1, 2))(x, a, filt)
    # cotangents land in the primal dtypes (bf16 signal, fp32 params)
    assert gx.dtype == jnp.bfloat16
    assert ga.dtype == jnp.float32 and gf.dtype == jnp.float32


# -------------------------------------- standalone ops: grad parity
@pytest.mark.parametrize("causal", [False, True])
def test_short_conv_pallas_grad_parity(causal):
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 77, 20))
    filt = jax.random.normal(jax.random.PRNGKey(1), (20, 8))
    gp = jax.grad(lambda x, f: jnp.sin(ops.short_conv(
        x, f, causal, use_pallas=True)).sum(), argnums=(0, 1))(x, filt)
    gr = jax.grad(lambda x, f: jnp.sin(ref.short_conv_ref(
        x, f, causal)).sum(), argnums=(0, 1))(x, filt)
    for p, q in zip(gp, gr):
        assert rel_err(p, q) <= 1e-5


def test_interp_pallas_grad_parity():
    n, d, r = 130, 18, 11
    idx_lo, w_lo, _ = ski.make_inducing(n, r)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, n, d))
    gp = jax.grad(lambda x: jnp.sin(ops.interp_reduce(
        x, idx_lo, w_lo, r, use_pallas=True)).sum())(x)
    gr = jax.grad(lambda x: jnp.sin(ref.interp_reduce_ref(
        x, idx_lo, w_lo, r)).sum())(x)
    assert rel_err(gp, gr) <= 1e-5
    z = jax.random.normal(jax.random.PRNGKey(1), (2, r, d))
    gp = jax.grad(lambda z: jnp.sin(ops.interp_expand(
        z, idx_lo, w_lo, use_pallas=True)).sum())(z)
    gr = jax.grad(lambda z: jnp.sin(ref.interp_expand_ref(
        z, idx_lo, w_lo)).sum())(z)
    assert rel_err(gp, gr) <= 1e-5


def test_unfused_pallas_pipeline_trainable():
    """fused=False + forced Pallas: reduce/conv/expand each train through
    their own custom VJPs (no pallas autodiff error, parity vs ref)."""
    cfg, params = _setup(d=6, rank=7, m=4)
    cfg_p = ski.SKIConfig(d=6, rank=7, filter_size=4, fused=False,
                          use_pallas=True)
    cfg_r = ski.SKIConfig(d=6, rank=7, filter_size=4, fused=False,
                          use_pallas=False)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 60, 6))
    gp = jax.grad(lambda p: ski.ski_tno_apply(p, cfg_p, x).sum())(params)
    gr = jax.grad(lambda p: ski.ski_tno_apply(p, cfg_r, x).sum())(params)
    for p, q in zip(jax.tree.leaves(gp), jax.tree.leaves(gr)):
        assert rel_err(p, q) <= 1e-5


# ------------------------------------------ backward kernels vs oracles
@pytest.mark.parametrize("left", [0, 3, 7])
def test_conv_tap_grad_kernel_matches_oracle(left):
    m = 8
    g = jax.random.normal(jax.random.PRNGKey(0), (2, 100, 24))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 100, 24))
    got = conv_tap_grad_pallas(g, x, m, left, interpret=True)
    want = ref.conv_tap_grad_ref(g, x, m, left)
    assert rel_err(got, want) <= 1e-5


def test_gram_grad_kernel_matches_oracle():
    gz = jax.random.normal(jax.random.PRNGKey(0), (3, 11, 20))  # ragged r, d
    z = jax.random.normal(jax.random.PRNGKey(1), (3, 11, 20))
    got = gram_grad_pallas(gz, z, interpret=True)
    want = ref.gram_grad_ref(gz, z)
    assert got.shape == want.shape == (20, 11, 11)
    assert rel_err(got, want) <= 1e-5


# ------------------------------- dispatch: kernel path, no silent fallback
def _block_setup(use_pallas, d_model=16):
    cfg = TNNBlockConfig(d_model=d_model, tno=TNOConfig(
        d=d_model, variant="ski", causal=True, rank=8, filter_size=4,
        use_pallas=use_pallas))
    params, _ = unbox(tnn_block_init(jax.random.PRNGKey(0), cfg))
    return cfg, params


def test_tnn_block_grad_takes_kernel_path():
    """The acceptance gate: jax.grad of a TNN block under forced-Pallas
    dispatch resolves to the custom-VJP kernel path — asserted via the
    trace-time counters, no silent reference fallback — and matches the
    reference-path gradients to 1e-5."""
    cfg_p, params = _block_setup(use_pallas=True)
    cfg_r, _ = _block_setup(use_pallas=False)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 16))
    ski_vjp.reset_counters()
    gp = jax.grad(lambda p: tnn_block_apply(p, cfg_p, x).sum())(params)
    assert ski_vjp.counters["fwd"] >= 1, "fused kernel fwd not traced"
    assert ski_vjp.counters["bwd_kernel"] >= 1, \
        "backward did not take the kernel path"
    assert ski_vjp.counters["bwd_ref"] == 0, "silent reference fallback"
    gr = jax.grad(lambda p: tnn_block_apply(p, cfg_r, x).sum())(params)
    for p, q in zip(jax.tree.leaves(gp), jax.tree.leaves(gr)):
        assert rel_err(p, q) <= 1e-5


def test_tnn_block_bf16_grads_finite_with_fp32_accum():
    cfg_p, params = _block_setup(use_pallas=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 48, 16), jnp.bfloat16)
    pb = cast_params(params, jnp.bfloat16)
    g = jax.grad(lambda p: tnn_block_apply(p, cfg_p, x).astype(
        jnp.float32).sum())(pb)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_pallas_grad_override_env(monkeypatch):
    """REPRO_PALLAS_GRAD=0 keeps the Pallas forward but swaps the backward
    to the reference cotangent formulas — observable via the counters and
    numerically equivalent."""
    n, d, r, m = 64, 16, 9, 6
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n, d))
    a = jax.random.normal(jax.random.PRNGKey(1), (d, r, r))
    filt = jax.random.normal(jax.random.PRNGKey(2), (d, m)) * 0.1
    idx_lo, w_lo, _ = ski.make_inducing(n, r)

    def loss(x):
        return ops.ski_fused_tno(x, a, filt, idx_lo, w_lo, r, False,
                                 use_pallas=True).sum()

    monkeypatch.setenv("REPRO_PALLAS_GRAD", "0")
    ski_vjp.reset_counters()
    g_ref_path = jax.grad(loss)(x)
    assert ski_vjp.counters["bwd_ref"] == 1
    assert ski_vjp.counters["bwd_kernel"] == 0
    monkeypatch.setenv("REPRO_PALLAS_GRAD", "auto")
    ski_vjp.reset_counters()
    g_kernel = jax.grad(loss)(x)
    assert ski_vjp.counters["bwd_kernel"] == 1
    assert rel_err(g_kernel, g_ref_path) <= 1e-5
    # programmatic override mirrors the env knob
    monkeypatch.delenv("REPRO_PALLAS_GRAD", raising=False)
    backend.set_default_pallas_grad(False)
    try:
        assert backend.resolve_pallas_grad() is False
    finally:
        backend.set_default_pallas_grad(None)
    assert backend.resolve_pallas_grad() is True


def test_describe_mentions_grad_policy():
    s = backend.describe()
    assert "pallas_grad=" in s and "use_pallas=" in s


# ----------------------------------------- end-to-end: one training step
def test_sgd_step_decreases_loss_on_kernel_path():
    """A few SGD steps through the custom-VJP path actually train."""
    cfg, params = _setup(d=8, rank=9, m=4)
    cfg = ski.SKIConfig(d=8, rank=9, filter_size=4, use_pallas=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 8))
    y_tgt = jnp.roll(x, 1, axis=1)

    def loss(p):
        return jnp.mean((ski.ski_tno_apply(p, cfg, x) - y_tgt) ** 2)

    l0 = float(loss(params))
    for _ in range(5):
        g = jax.grad(loss)(params)
        params = jax.tree.map(lambda p, g: p - 0.05 * g, params, g)
    assert float(loss(params)) < l0
