"""Causal FD-TNO fused pipeline (kernels/fd_fused.py): oracle parity for
each Pallas kernel, fwd + grad parity of the differentiable op against the
jnp reference (interpret mode, the SKI grad-parity tiers: fp32 ≤ 1e-5,
bf16 ≤ 2e-2), exact causality of the realised operator, and the
no-silent-fallback counter contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fd as fd_mod
from repro.core.hilbert import causal_spectrum
from repro.kernels import backend, fd_fused, ops, ref
from repro.nn.params import unbox

GRAD_TOL = {jnp.dtype(jnp.float32): 1e-5, jnp.dtype(jnp.bfloat16): 2e-2}


def _rel(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return float(np.abs(got - want).max() / (np.abs(want).max() + 1e-12))


@pytest.fixture(autouse=True)
def _reset_counters():
    fd_fused.reset_counters()
    yield


# ------------------------------------------------------ kernel vs oracle
@pytest.mark.parametrize("d,n", [(8, 16), (5, 33), (12, 64), (3, 7)])
def test_hilbert_window_matches_ref(d, n):
    kt = jax.random.normal(jax.random.PRNGKey(n), (d, 2 * n))
    got = fd_fused.hilbert_window_pallas(kt, n, interpret=True)
    want = ref.hilbert_window_ref(kt, n)
    assert _rel(got, want) <= 1e-6
    # window zeroes the negative lags exactly (t > n)
    assert float(jnp.abs(got[:, n + 1:]).max()) == 0.0


def test_hilbert_window_grad_is_window():
    """Diagonal window ⇒ the VJP is the same window applied to the
    cotangent (self-adjoint)."""
    d, n = 4, 12
    kt = jax.random.normal(jax.random.PRNGKey(0), (d, 2 * n))
    g = jax.random.normal(jax.random.PRNGKey(1), (d, 2 * n))
    _, vjp = jax.vjp(
        lambda k: fd_fused.hilbert_window_pallas(k, n, interpret=True), kt)
    (dk,) = vjp(g)
    assert _rel(dk, ref.hilbert_window_ref(g, n)) <= 1e-6


@pytest.mark.parametrize("b,f,d", [(2, 17, 8), (1, 65, 12), (3, 9, 3)])
def test_spectral_multiply_matches_ref(b, f, d):
    ks = jax.random.split(jax.random.PRNGKey(f), 4)
    xr, xi = (jax.random.normal(ks[i], (b, f, d)) for i in range(2))
    kr, ki = (jax.random.normal(ks[2 + i], (f, d)) for i in range(2))
    yr, yi = fd_fused.fd_spectral_multiply_pallas(xr, xi, kr, ki,
                                                  interpret=True)
    wr, wi = ref.fd_spectral_multiply_ref(xr, xi, kr, ki)
    assert _rel(yr, wr) <= 1e-6 and _rel(yi, wi) <= 1e-6


@pytest.mark.parametrize("b,f,d", [(2, 17, 8), (4, 33, 5)])
def test_khat_grad_matches_ref(b, f, d):
    ks = jax.random.split(jax.random.PRNGKey(b * f), 4)
    gr, gi, xr, xi = (jax.random.normal(k, (b, f, d)) for k in ks)
    dr, di = fd_fused.fd_khat_grad_pallas(gr, gi, xr, xi, interpret=True)
    wr, wi = ref.fd_khat_grad_ref(gr, gi, xr, xi)
    assert _rel(dr, wr) <= 1e-6 and _rel(di, wi) <= 1e-6


# ---------------------------------------------------- op fwd/grad parity
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,n,d", [(2, 32, 8), (1, 33, 5), (2, 64, 16)])
def test_fd_tno_fwd_matches_oracle(b, n, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, n, d)).astype(dtype)
    khat = jax.random.normal(jax.random.PRNGKey(1), (d, n + 1)).astype(dtype)
    got = ops.fd_tno(x, khat, use_pallas=True, interpret=True)
    want = ref.fd_tno_ref(x, khat)
    assert got.dtype == dtype
    assert _rel(got, want) <= GRAD_TOL[jnp.dtype(dtype)]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,n,d", [(2, 32, 8), (1, 17, 5)])
def test_fd_tno_grad_matches_oracle(b, n, d, dtype):
    """jax.grad through the Pallas op (kernel backward: conjugated-spectrum
    multiply + khat reduction) vs plain autodiff of the jnp oracle."""
    x = jax.random.normal(jax.random.PRNGKey(2), (b, n, d)).astype(dtype)
    khat = jax.random.normal(jax.random.PRNGKey(3), (d, n + 1)).astype(dtype)

    def loss(fn):
        return lambda x_, k_: jnp.sum(jnp.sin(fn(x_, k_).astype(jnp.float32)))

    g_pl = jax.grad(loss(lambda x_, k_: ops.fd_tno(
        x_, k_, use_pallas=True, interpret=True)), argnums=(0, 1))(x, khat)
    g_rf = jax.grad(loss(ref.fd_tno_ref), argnums=(0, 1))(x, khat)
    tol = GRAD_TOL[jnp.dtype(dtype)]
    assert _rel(g_pl[0], g_rf[0]) <= tol, "dx mismatch"
    assert _rel(g_pl[1], g_rf[1]) <= tol, "dkhat mismatch"
    # no-silent-fallback contract: the differentiated forward and the
    # kernel backward both ran, the reference backward did not
    assert fd_fused.counters["fwd"] == 1
    assert fd_fused.counters["bwd_kernel"] == 1
    assert fd_fused.counters["bwd_ref"] == 0


def test_fd_tno_grad_ref_escape_hatch():
    """REPRO_PALLAS_GRAD=0 keeps the Pallas forward but swaps the backward
    to the jnp reference formulas — and the counters record it."""
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 4))
    khat = jax.random.normal(jax.random.PRNGKey(5), (4, 17))
    backend.set_default_pallas_grad(False)
    try:
        g = jax.grad(lambda x_: jnp.sum(
            ops.fd_tno(x_, khat, use_pallas=True, interpret=True)))(x)
    finally:
        backend.set_default_pallas_grad(None)
    g_want = jax.grad(lambda x_: jnp.sum(ref.fd_tno_ref(x_, khat)))(x)
    assert _rel(g, g_want) <= 1e-5
    assert fd_fused.counters["bwd_ref"] == 1
    assert fd_fused.counters["bwd_kernel"] == 0


def test_ops_dispatch_ref_path_leaves_counters():
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 8, 4))
    khat = jax.random.normal(jax.random.PRNGKey(7), (4, 9))
    y = ops.fd_tno(x, khat, use_pallas=False)
    assert _rel(y, ref.fd_tno_ref(x, khat)) == 0.0
    assert fd_fused.counters["fwd"] == 0


# ------------------------------------------------------------- causality
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [16, 33])
def test_fd_tno_operator_is_exactly_causal(n, dtype):
    """An impulse at position t0 must produce nothing before t0 (within
    dtype eps — the analytic window zeroes negative lags exactly, not to
    FFT-leakage level)."""
    d, t0 = 6, n // 2
    khat = jax.random.normal(jax.random.PRNGKey(n), (d, n + 1)).astype(dtype)
    x = jnp.zeros((1, n, d), dtype).at[0, t0, :].set(1.0)
    y = np.asarray(ops.fd_tno(x, khat, use_pallas=True, interpret=True),
                   np.float32)
    scale = max(float(np.abs(y).max()), 1.0)
    eps = 1e-5 if dtype == jnp.float32 else 1e-2
    assert np.abs(y[0, :t0]).max() <= eps * scale


@pytest.mark.parametrize("n", [16, 31])
def test_realised_time_kernel_is_exactly_causal(n):
    """The time kernel the op realises — irfft of its causal spectrum —
    vanishes on negative lags (k[τ<0] ≡ 0 within dtype eps)."""
    d = 4
    khat = jax.random.normal(jax.random.PRNGKey(n), (d, n + 1))
    kr, ki = fd_fused.causal_khat_planes(khat, interpret=True)
    k_time = np.asarray(jnp.fft.irfft((kr + 1j * ki).T, n=2 * n, axis=-1))
    scale = max(float(np.abs(k_time).max()), 1.0)
    assert np.abs(k_time[:, n + 1:]).max() <= 1e-5 * scale
    # and it agrees with the hilbert-module construction
    spec = np.asarray(causal_spectrum(khat))
    np.testing.assert_allclose(np.asarray(kr), spec.T.real, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ki), spec.T.imag, rtol=1e-5,
                               atol=1e-5)


# -------------------------------------------------- core/fd integration
def test_fd_tno_apply_routes_causal_through_op():
    """core.fd.fd_tno_apply (causal) == the legacy complex-multiply path,
    and the plan carries khat_real for the fused op."""
    from repro.core.tno import TNOConfig, tno_init, tno_plan, tno_apply
    cfg = fd_mod.FDConfig(d=6, causal=True)
    params, _ = unbox(fd_mod.fd_init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 6))
    y = fd_mod.fd_tno_apply(params, cfg, x)
    khat = fd_mod.kernel_spectrum(params, cfg, 24)
    xhat = jnp.fft.rfft(x, n=48, axis=1)
    y_legacy = jnp.fft.irfft(xhat * khat.T[None], n=48, axis=1)[:, :24]
    assert _rel(y, y_legacy) <= 1e-6

    tcfg = TNOConfig(d=6, variant="fd", causal=True)
    tp, _ = unbox(tno_init(jax.random.PRNGKey(2), tcfg))
    plan = tno_plan(tp, tcfg, 24)
    assert "khat_real" in plan and plan["khat_real"].shape == (6, 25)
    assert _rel(tno_apply(tp, tcfg, x, plan=plan),
                tno_apply(tp, tcfg, x)) == 0.0


def test_kernel_spectrum_real_rejects_bidirectional():
    cfg = fd_mod.FDConfig(d=4, causal=False)
    params, _ = unbox(fd_mod.fd_init(jax.random.PRNGKey(0), cfg))
    with pytest.raises(ValueError):
        fd_mod.kernel_spectrum_real(params, cfg, 8)


def test_fd_block_grad_parity_pallas_vs_ref():
    """jax.grad through a whole causal FD GTU block: Pallas (interpret)
    path vs reference path — parameter grads flow through the RPE and
    match (the training-path acceptance gate)."""
    from repro.core.tno import TNOConfig, tno_init, tno_plan, tno_apply
    cfg_p = TNOConfig(d=8, variant="fd", causal=True, use_pallas=True)
    cfg_r = TNOConfig(d=8, variant="fd", causal=True, use_pallas=False)
    params, _ = unbox(tno_init(jax.random.PRNGKey(0), cfg_p))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))

    def loss(cfg):
        def f(p):
            plan = tno_plan(p, cfg, 16)
            return jnp.sum(jnp.sin(tno_apply(p, cfg, x, plan=plan)))
        return f

    g_p = jax.grad(loss(cfg_p))(params)
    g_r = jax.grad(loss(cfg_r))(params)
    flat_p, _ = jax.tree_util.tree_flatten(g_p)
    flat_r, _ = jax.tree_util.tree_flatten(g_r)
    for a, b in zip(flat_p, flat_r):
        assert _rel(a, b) <= 1e-5
