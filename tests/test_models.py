"""Per-architecture smoke tests (reduced configs, one fwd + one train step
on CPU, shapes + finite outputs) and decode-path consistency checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models.context import Ctx
from repro.models.serving import decode_step, init_cache
from repro.models.transformer import forward, init_model, loss_fn
from repro.nn.params import unbox
from repro.optim import adamw

ASSIGNED = [
    "jamba-1.5-large-398b", "grok-1-314b", "granite-moe-3b-a800m",
    "phi3-medium-14b", "qwen2-72b", "gemma3-4b", "stablelm-3b",
    "paligemma-3b", "whisper-medium", "mamba2-2.7b",
]


def _smoke_batch(cfg, b=2, s=32):
    batch = {"tokens": jnp.ones((b, s), jnp.int32),
             "labels": jnp.ones((b, s), jnp.int32)}
    if cfg.kind == "prefix_vlm":
        batch["patches"] = 0.1 * jnp.ones((b, cfg.n_prefix, cfg.d_model))
    if cfg.kind == "encdec":
        batch["enc_embed"] = 0.1 * jnp.ones((b, s, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    batch = _smoke_batch(cfg)
    logits, aux = forward(params, cfg, Ctx(), batch)
    assert logits.shape == (2, 32, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits)))

    # one optimizer step moves the loss
    ocfg = adamw.OptConfig(lr=1e-2, warmup_steps=1, total_steps=10)
    opt = adamw.init(ocfg, params)
    lf = lambda p: loss_fn(p, cfg, Ctx(), batch)
    (l0, _), grads = jax.value_and_grad(lf, has_aux=True)(params)
    opt, params2, metrics = adamw.step(ocfg, opt, grads, params)
    (l1, _), _ = jax.value_and_grad(lf, has_aux=True)(params2)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    # one aggressive step need not decrease (MoE capacity drops re-route
    # tokens); multi-step convergence is asserted in the quality benches.
    assert float(l1) != float(l0), (arch, float(l0))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ["qwen2-72b", "gemma3-4b", "mamba2-2.7b",
                                  "jamba-1.5-large-398b", "whisper-medium",
                                  "granite-moe-3b-a800m"])
def test_decode_matches_forward(arch):
    """Autoregressive decode logits must match teacher-forced forward
    logits position-by-position (same params, same tokens)."""
    # ample MoE capacity: the GShard path drops order-dependently, so
    # teacher-forced forward and one-token decode only agree without drops
    # fp32 isolates algorithmic parity from bf16 accumulation noise
    cfg = reduce_for_smoke(get_config(arch), moe_capacity_factor=8.0,
                           dtype="float32", param_dtype="float32")
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    b, s = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.kind == "encdec":
        batch["enc_embed"] = 0.1 * jnp.ones((b, s, cfg.d_model))
    if cfg.kind == "prefix_vlm":
        pytest.skip("prefix patches precede text; decode parity covered "
                    "by decoder-only archs")
    want, _ = forward(params, cfg, Ctx(), batch)

    cache = init_cache(cfg, b, s)
    dec_batch = {}
    if cfg.kind == "encdec":
        from repro.models.serving import encode
        dec_batch["enc_out"] = encode(params, cfg, Ctx(), batch["enc_embed"])
    got = []
    for t in range(s):
        dec_batch["tokens"] = toks[:, t:t + 1]
        logits, cache = decode_step(params, cfg, Ctx(decode=True), dec_batch,
                                    cache, t)
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_gqa_vs_mha_equivalence():
    """GQA with kv repeated == MHA when kv weights are tiled — guards the
    repeat-kv rewrite of SDPA."""
    from repro.models import attention as attn
    cfg_gqa = reduce_for_smoke(get_config("qwen2-72b"), n_heads=4,
                               n_kv_heads=2, head_dim=16)
    p, _ = unbox(attn.attn_init(jax.random.PRNGKey(0), cfg_gqa))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg_gqa.d_model))
    y = attn.attn_apply(p, cfg_gqa, Ctx(), x)

    cfg_mha = dataclasses.replace(cfg_gqa, n_kv_heads=4)
    hd = cfg_gqa.head_dim
    wk = p["wk"].reshape(cfg_gqa.d_model, 2, hd)
    wk_t = jnp.repeat(wk, 2, axis=1).reshape(cfg_gqa.d_model, 4 * hd)
    wv = p["wv"].reshape(cfg_gqa.d_model, 2, hd)
    wv_t = jnp.repeat(wv, 2, axis=1).reshape(cfg_gqa.d_model, 4 * hd)
    p2 = dict(p, wk=wk_t, wv=wv_t,
              bk=jnp.repeat(p["bk"].reshape(2, hd), 2, 0).reshape(-1),
              bv=jnp.repeat(p["bv"].reshape(2, hd), 2, 0).reshape(-1))
    y2 = attn.attn_apply(p2, cfg_mha, Ctx(), x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_masks_far_tokens():
    from repro.models.attention import mask_for
    m = mask_for("local", jnp.arange(16), jnp.arange(16), window=4)
    m = np.asarray(m)
    assert m[10, 10] and m[10, 7] and not m[10, 6] and not m[5, 9]


def test_prefix_mask_bidirectional_over_prefix():
    from repro.models.attention import mask_for
    m = np.asarray(mask_for("prefix", jnp.arange(8), jnp.arange(8), prefix=3))
    assert m[0, 2]            # prefix sees prefix (future within prefix)
    assert m[5, 3] and not m[3, 5]   # text is causal


def test_moe_matches_dense_expert_sum():
    """Sorted ragged-dot MoE == explicit per-token expert loop (the
    dropless path; the capacity path is compared separately below)."""
    from repro.models import moe
    cfg = reduce_for_smoke(get_config("granite-moe-3b-a800m"),
                           n_experts=4, top_k=2, d_model=32, d_ff=16,
                           moe_impl="ragged")
    p, _ = unbox(moe.moe_init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    got, aux = moe.moe_apply(p, cfg, Ctx(), x)

    x2d = x.reshape(-1, 32)
    logits = x2d @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    want = jnp.zeros_like(x2d)
    for t in range(x2d.shape[0]):
        acc = jnp.zeros((32,))
        for j in range(2):
            e = int(ids[t, j])
            h = jax.nn.silu(x2d[t] @ p["w_gate"][e]) * (x2d[t] @ p["w_up"][e])
            acc = acc + w[t, j] * (h @ p["w_down"][e])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(got.reshape(-1, 32)),
                               np.asarray(want), rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.0


def test_moe_capacity_matches_ragged_when_unsaturated():
    """With ample capacity the GShard path must equal the dropless path."""
    from repro.models import moe
    cfg = reduce_for_smoke(get_config("granite-moe-3b-a800m"),
                           n_experts=4, top_k=2, d_model=32, d_ff=16,
                           moe_impl="ragged")
    p, _ = unbox(moe.moe_init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    want, _ = moe.moe_apply(p, cfg, Ctx(), x)
    cfg_cap = dataclasses.replace(cfg, moe_impl="capacity",
                                  moe_capacity_factor=8.0)
    got, _ = moe.moe_apply(p, cfg_cap, Ctx(), x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_overflow_tokens():
    """Capacity 0-ish forces drops: output must differ from dropless and
    stay finite (degraded, not broken)."""
    from repro.models import moe
    cfg = reduce_for_smoke(get_config("granite-moe-3b-a800m"),
                           n_experts=4, top_k=2, d_model=32, d_ff=16,
                           moe_impl="capacity", moe_capacity_factor=0.3)
    p, _ = unbox(moe.moe_init(jax.random.PRNGKey(0), cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    got, _ = moe.moe_apply(p, cfg, Ctx(), x)
    assert np.all(np.isfinite(np.asarray(got)))


def test_mixer_override_tnoizes_attention_arch():
    """The paper's technique as a drop-in mixer for an assigned arch."""
    cfg = reduce_for_smoke(get_config("phi3-medium-14b"))
    cfg = dataclasses.replace(cfg, mixer_override="fd")
    assert all(m == "fd" for m, _ in cfg.layers_spec)
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    batch = _smoke_batch(cfg)
    logits, _ = forward(params, cfg, Ctx(), batch)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_param_count_analytic_matches_actual():
    """6ND roofline accounting depends on param_count(): verify against
    real leaf sizes (embedding + layers; exact for dense/moe/ssm/tno)."""
    for arch in ["qwen2-72b", "granite-moe-3b-a800m", "mamba2-2.7b",
                 "phi3-medium-14b"]:
        cfg = reduce_for_smoke(get_config(arch))
        params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        pc = cfg.param_count()["total"]
        # analytic counts exclude norms/biases/router-etc: within 5%
        assert abs(actual - pc) / actual < 0.05, (arch, actual, pc)
