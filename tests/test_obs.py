"""Observability layer (repro.obs, ISSUE 9).

Contracts under test:

* registry semantics — counter monotonicity, label memoisation, kind
  collisions, cumulative histogram buckets, Prometheus text exposition
  well-formedness, JSON dump, the off-by-default NullRegistry, the
  REPRO_METRICS process default, MirroredCounts delta mirroring;
* span tracing — begin/end/instant ordering through a real scheduler
  run (packed admission + async detok), the validate_spans contract
  (positive and negative), Chrome trace_event export validity;
* chaos — injector firings land as tagged ``fault`` instants and
  labeled counters; faulted/quarantined/expired requests end with the
  matching terminal span status; preemption closes spans as
  ``preempted`` and a resumed run re-begins them;
* plumbing — engine trace_counts mirror into the registry, trainer
  step metrics, the REPRO_LOG_LEVEL logger knob, tools/obs_report.py.
"""
import json
import logging
import os
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models.transformer import init_model
from repro.nn.params import unbox
from repro.obs import log as obs_log
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.obs.metrics import MirroredCounts, NULL_REGISTRY, Registry
from repro.obs.tracing import Tracer, chrome_trace, validate_spans
from repro.serving_engine import (Engine, FaultInjector, FaultSpec,
                                  Request, Scheduler)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PLENS = [3, 6, 5, 2]
GENS = [6, 7, 8, 6]
MAX_LEN = 32


@pytest.fixture(scope="module")
def env():
    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"),
                           dtype="float32", param_dtype="float32")
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
               for p in PLENS]
    return {"cfg": cfg, "params": params, "prompts": prompts}


def _fleet(prompts, uid_prefix="r", gens=GENS, **kw):
    return [Request(uid=f"{uid_prefix}{i}", prompt=pr, max_new=g, **kw)
            for i, (pr, g) in enumerate(zip(prompts, gens))]


# ============================================================== registry
def test_counter_inc_and_labels():
    reg = Registry()
    c = reg.counter("req_total", "requests", ("status",))
    c.labels(status="ok").inc()
    c.labels(status="ok").inc(2)
    c.labels(status="error").inc()
    assert c.get(status="ok") == 3
    assert c.get(status="error") == 1
    # same label set memoises to the same child
    assert c.labels(status="ok") is c.labels(status="ok")
    with pytest.raises(ValueError):
        c.labels(status="ok").inc(-1)       # counters are monotone
    with pytest.raises(ValueError):
        c.labels(wrong="x")                 # undeclared label name


def test_gauge_and_histogram_semantics():
    reg = Registry()
    g = reg.gauge("depth")
    g.set(5)
    g.dec(2)
    g.inc()
    assert g.get() == 4
    h = reg.histogram("lat", buckets=(0.1, 1.0, 10.0))
    for x in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(x)
    ch = h.labels()
    # cumulative le semantics: bucket[i] counts every x <= le
    assert ch.bucket_counts == [1, 3, 4]
    assert ch.count == 5 and ch.sum == pytest.approx(56.05)
    with pytest.raises(TypeError):
        g.observe(1.0)
    with pytest.raises(TypeError):
        h.set(1.0)


def test_registration_idempotent_and_collision():
    reg = Registry()
    a = reg.counter("x_total", "x")
    assert reg.counter("x_total") is a
    with pytest.raises(ValueError):
        reg.gauge("x_total")                       # kind collision
    with pytest.raises(ValueError):
        reg.counter("x_total", labelnames=("a",))  # labelnames collision
    with pytest.raises(ValueError):
        reg.counter("bad name")                    # exposition identifier


def test_render_prometheus_exposition():
    reg = Registry()
    reg.counter("req_total", "requests served", ("code",)).labels(
        code="200").inc(17)
    reg.gauge("depth", "queue depth").set(3)
    reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0)).observe(0.5)
    text = reg.render_prometheus()
    lines = text.strip().splitlines()
    assert "# HELP req_total requests served" in lines
    assert "# TYPE req_total counter" in lines
    assert 'req_total{code="200"} 17' in lines
    assert "# TYPE lat_seconds histogram" in lines
    assert 'lat_seconds_bucket{le="0.1"} 0' in lines
    assert 'lat_seconds_bucket{le="1"} 1' in lines
    assert 'lat_seconds_bucket{le="+Inf"} 1' in lines
    assert "lat_seconds_count 1" in lines
    assert any(ln.startswith("lat_seconds_sum 0.5") for ln in lines)
    # every non-comment line is "name[{labels}] value"
    for ln in lines:
        if not ln.startswith("#"):
            assert len(ln.rsplit(" ", 1)) == 2, ln


def test_json_dump_roundtrip(tmp_path):
    reg = Registry()
    reg.counter("n_total").inc(4)
    reg.histogram("h", buckets=(1.0,)).observe(0.5)
    path = tmp_path / "m.json"
    reg.dump_json(str(path))
    data = json.loads(path.read_text())
    assert data["metrics"]["n_total"]["series"][0]["value"] == 4
    h = data["metrics"]["h"]["series"][0]
    assert h["counts"] == [1] and h["count"] == 1


def test_null_registry_is_noop(tmp_path):
    c = NULL_REGISTRY.counter("anything", "x", ("a",))
    c.inc()
    c.labels(a="b").inc()
    c.observe(3.0)        # no kind checking on the shared noop: all quiet
    assert c.get() == 0.0
    assert NULL_REGISTRY.render_prometheus() == ""
    NULL_REGISTRY.dump_json(str(tmp_path / "m.json"))
    assert json.loads((tmp_path / "m.json").read_text())["metrics"] == {}


def test_default_registry_env_gate(monkeypatch):
    obs_metrics.set_default_registry(None)
    monkeypatch.delenv("REPRO_METRICS", raising=False)
    try:
        assert obs_metrics.default_registry() is NULL_REGISTRY
        obs_metrics.set_default_registry(None)
        monkeypatch.setenv("REPRO_METRICS", "1")
        reg = obs_metrics.default_registry()
        assert isinstance(reg, Registry)
        assert obs_metrics.default_registry() is reg     # sticky
    finally:
        obs_metrics.set_default_registry(None)


def test_registry_thread_safety():
    reg = Registry()
    c = reg.counter("n_total", "x", ("t",))
    h = reg.histogram("h_seconds")

    def work(tid):
        for _ in range(1000):
            c.labels(t=str(tid % 2)).inc()
            h.observe(0.01)
    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.get(t="0") + c.get(t="1") == 8000
    assert h.get() == 8000


def test_mirrored_counts():
    reg = Registry()
    c = reg.counter("traces_total", "x", ("fn",))
    d = MirroredCounts({"a": 0, "b": 0}, c, "fn")
    d["a"] += 1
    d["a"] += 2
    d["b"] += 1
    assert d == {"a": 3, "b": 1}                   # dict reads unchanged
    assert c.get(fn="a") == 3 and c.get(fn="b") == 1
    d["a"] = 0                                     # resets never decrement
    assert c.get(fn="a") == 3


# ================================================================ tracer
def test_tracer_jsonl_stream_and_chrome(tmp_path):
    path = tmp_path / "t.jsonl"
    clk = {"t": 0.0}

    def clock():
        clk["t"] += 0.25
        return clk["t"]

    tr = Tracer(str(path), clock=clock)
    tr.begin("request", "u1", prompt_len=4)
    tr.begin("queue", "u1")
    tr.end("queue", "u1")
    tr.instant("first_token", "u1")
    tr.counter("queue_depth", 2)
    tr.end("request", "u1", status="ok")
    tr.close()
    loaded = obs_tracing.load_jsonl(str(path))
    assert loaded == tr.events
    spans = validate_spans(loaded)
    assert spans["u1"][0]["status"] == "ok"
    assert spans["u1"][0]["children"] == {"queue": 1, "first_token": 1}

    chrome = chrome_trace(loaded)
    evs = chrome["traceEvents"]
    # pid/ts on every event; engine + one request thread, both named
    assert all("pid" in e and "ph" in e for e in evs)
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert names == {"engine", "req u1"}
    ph = [e["ph"] for e in evs if e.get("cat") == "serving"]
    assert ph == ["B", "B", "E", "i", "C", "E"]
    # timestamps rebased to first event and scaled to µs
    ts = [e["ts"] for e in evs if e.get("cat") == "serving"]
    assert ts[0] == 0 and ts[1] == pytest.approx(0.25e6)
    json.dumps(chrome)                             # serialisable as-is


def test_validate_spans_rejects_incomplete():
    t0 = {"ts": 0.0, "ph": "B", "name": "request", "uid": "u"}
    with pytest.raises(ValueError, match="unclosed"):
        validate_spans([t0])
    with pytest.raises(ValueError, match="non-terminal"):
        validate_spans([t0, {"ts": 1.0, "ph": "E", "name": "request",
                             "uid": "u"}])
    with pytest.raises(ValueError, match="no queue span"):
        validate_spans([t0, {"ts": 1.0, "ph": "E", "name": "request",
                             "uid": "u", "attrs": {"status": "ok"}}])
    with pytest.raises(ValueError, match="end without begin"):
        validate_spans([{"ts": 0.0, "ph": "E", "name": "prefill",
                         "uid": "u"}])
    with pytest.raises(ValueError, match="re-begun"):
        validate_spans([t0, dict(t0)])


# ===================================================== scheduler + spans
def test_scheduler_span_tree_packed_and_async_detok(env):
    """A real run (packed admission, async detok callbacks) leaves one
    complete span tree per request: queue -> prefill -> decode children,
    first_token + (max_new - 1) token instants, status ok — and the
    registry's TTFT/prefill/step series agree with scheduler stats."""
    reg = Registry()
    tr = Tracer()
    eng = Engine(env["cfg"], env["params"], slots=2, max_len=MAX_LEN,
                 metrics=reg)
    streamed = {}
    sched = Scheduler(eng, metrics=reg, tracer=tr, detok_async=True)
    for r in _fleet(env["prompts"],
                    on_token=lambda u, t: streamed.setdefault(u, [])
                    .append(t)):
        sched.submit(r)
    results, _ = sched.run()

    spans = validate_spans(tr.events)
    assert sorted(spans) == [f"r{i}" for i in range(len(PLENS))]
    for i, g in enumerate(GENS):
        recs = spans[f"r{i}"]
        assert len(recs) == 1
        rec = recs[0]
        assert rec["status"] == "ok"
        assert rec["children"]["queue"] == 1
        assert rec["children"]["prefill"] == 1
        assert rec["children"]["decode"] == 1
        assert rec["tokens"] == g == len(results[f"r{i}"])
        assert rec["children"]["first_token"] == 1
    # ordering within each uid's track: queue closes before prefill opens
    for i in range(len(PLENS)):
        uid = f"r{i}"
        seq = [(e["ph"], e["name"]) for e in tr.events
               if e.get("uid") == uid]
        assert seq.index(("E", "queue")) < seq.index(("B", "prefill"))
        assert seq.index(("E", "prefill")) < seq.index(("B", "decode"))
        assert seq[-1] == ("E", "request")
    # packed admission was traced as such (2 slots -> first wave packs 2)
    packed = [e for e in tr.events if e["name"] == "prefill"
              and e["ph"] == "B" and e.get("attrs", {}).get("packed")]
    assert len(packed) >= 2
    # registry cross-checks
    assert reg.get("repro_requests_submitted_total").get() == len(PLENS)
    assert reg.get("repro_requests_finished_total").get(
        status="ok") == len(PLENS)
    assert reg.get("repro_ttft_seconds").get() == len(PLENS)
    assert reg.get("repro_decode_steps_total").get() == sched.steps
    assert reg.get("repro_decode_step_seconds").get() == sched.steps
    assert reg.get("repro_packed_prefill_waves_total").get() == \
        sched.packed_prefills
    by_mode = reg.get("repro_prefills_total")
    assert (by_mode.get(mode="packed") + by_mode.get(mode="single")
            == sched.prefills)
    # engine trace_counts mirrored under the same registry
    traces = reg.get("repro_engine_traces_total")
    assert traces.get(fn="generate") == eng.trace_counts["generate"] >= 1
    # async detok settled: callbacks saw every token
    for uid, toks in results.items():
        assert streamed[uid] == toks


def test_chaos_run_spans_and_fault_tags(env):
    """Scripted faults land as tagged trace instants + labeled counters;
    the poisoned request's span tree ends status=error, survivors ok."""
    reg = Registry()
    tr = Tracer()
    eng = Engine(env["cfg"], env["params"], slots=2, max_len=MAX_LEN)
    inj = FaultInjector(specs=[
        FaultSpec(site="prefill", uid="r1", count=99),   # persistent
        FaultSpec(site="decode", at=1),                  # transient
    ])
    sched = Scheduler(eng, injector=inj, metrics=reg, tracer=tr,
                      backoff_base=0.0, max_retries=2)
    for r in _fleet(env["prompts"]):
        sched.submit(r)
    results, _ = sched.run()

    spans = validate_spans(tr.events)
    statuses = {u: recs[-1]["status"] for u, recs in spans.items()}
    for uid, o in sched.outcomes.items():
        assert statuses[uid] == o.status   # trace terminus == Outcome
    assert statuses["r1"] == "error"
    assert sum(s == "ok" for s in statuses.values()) == len(PLENS) - 1

    faults = [e for e in tr.events if e["name"] == "fault"]
    assert len(faults) == inj.fired == 4   # 3 prefill (retries) + 1 decode
    prefill_faults = [e for e in faults
                     if e["attrs"]["site"] == "prefill"]
    assert all(e["uid"] == "r1" and e["attrs"]["spec"] == "spec0"
               and e["attrs"]["action"] == "raise"
               for e in prefill_faults)
    retries = [e for e in tr.events if e["name"] == "retry"]
    assert len(retries) == sched.retries == 3
    assert reg.get("repro_faults_injected_total").get(
        site="prefill", action="raise", spec="spec0") == 3
    assert reg.get("repro_retries_total").get(site="prefill") == 2
    assert reg.get("repro_retries_total").get(site="decode") == 1
    assert reg.get("repro_requests_finished_total").get(status="error") == 1


def test_preempt_closes_spans_and_restore_resumes(env, tmp_path):
    """preempt() ends every open span with status=preempted; a restored
    scheduler sharing the tracer re-begins them (resumed=True) and the
    combined trace validates with every request ending ok."""
    reg = Registry()
    tr = Tracer()
    snap = str(tmp_path / "snap")
    eng = Engine(env["cfg"], env["params"], slots=2, max_len=MAX_LEN)
    sched = Scheduler(eng, metrics=reg, tracer=tr, snapshot_dir=snap)
    n = {"tok": 0}

    def kill_soon(u, t):
        n["tok"] += 1
        if n["tok"] == 5:
            sched.preempt()
    for r in _fleet(env["prompts"], on_token=kill_soon):
        sched.submit(r)
    sched.run()
    assert sched.preempted
    spans = validate_spans(tr.events)        # complete despite preemption
    pre = {u: recs[-1]["status"] for u, recs in spans.items()}
    assert "preempted" in pre.values()
    assert reg.get("repro_requests_finished_total").get(
        status="preempted") == 0   # preemption is not a _finish

    sched2 = Scheduler(eng, metrics=reg, tracer=tr, snapshot_dir=snap)
    assert sched2.try_restore()
    results, _ = sched2.run()
    spans = validate_spans(tr.events)
    for i, g in enumerate(GENS):
        recs = spans[f"r{i}"]
        assert recs[-1]["status"] == "ok"
        # token-exact across the preemption: instants sum to the budget
        assert sum(r["tokens"] for r in recs) == g
        if len(recs) > 1:                     # resumed requests re-begun
            assert recs[-1]["attrs"].get("resumed") is True
        assert len(results[f"r{i}"]) == g


def test_expired_request_span(env):
    clk = {"t": 0.0}

    def tick(u, t):
        clk["t"] += 1.0
    reqs = _fleet(env["prompts"][:2], gens=[10, 10], on_token=tick)
    reqs[0].deadline = 5.0
    reg = Registry()
    tr = Tracer()
    sched = Scheduler(Engine(env["cfg"], env["params"], slots=2,
                             max_len=MAX_LEN),
                      clock=lambda: clk["t"], backoff_base=0.0,
                      metrics=reg, tracer=tr)
    for r in reqs:
        sched.submit(r)
    sched.run()
    assert sched.outcomes["r0"].status == "expired"
    spans = validate_spans(tr.events)
    assert spans["r0"][-1]["status"] == "expired"
    assert spans["r0"][-1]["children"].get("expired") == 1
    assert reg.get("repro_evictions_total").get(reason="deadline") == 1


# ============================================================== trainer
def test_trainer_metrics(tmp_path):
    from repro.data.pipeline import DataConfig
    from repro.runtime.trainer import Trainer, TrainerConfig

    def train_step(state, batch):
        return state + 1, {"loss": 1.0 / (state + 1.0)}

    reg = Registry()
    boom = {"armed": True}

    def failure_hook(step, attempt):
        if step == 2 and attempt == 0 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected")

    cfg = TrainerConfig(total_steps=5, max_retries=1,
                        undonated_retry_copy=False, log_every=0)
    tr = Trainer(cfg, train_step,
                 DataConfig(vocab=16, global_batch=2, seq_len=4, seed=0),
                 failure_hook=failure_hook, metrics=reg)
    state, step = tr.run(jax.numpy.float32(0.0))
    assert step == 5
    assert reg.get("repro_train_steps_total").get() == 5
    assert reg.get("repro_train_retries_total").get() == 1
    assert reg.get("repro_train_step_seconds").get() == 5
    assert reg.get("repro_train_loss").get() > 0
    assert reg.get("repro_train_tokens_per_s").get() > 0


# ================================================================ logger
def test_log_level_knob(monkeypatch):
    monkeypatch.delenv("REPRO_LOG_LEVEL", raising=False)
    assert obs_log.default_level() == logging.WARNING   # under pytest
    monkeypatch.setenv("REPRO_LOG_LEVEL", "DEBUG")
    assert obs_log.default_level() == logging.DEBUG
    monkeypatch.setenv("REPRO_LOG_LEVEL", "15")
    assert obs_log.default_level() == 15
    monkeypatch.setenv("REPRO_LOG_LEVEL", "bogus")
    with pytest.raises(ValueError):
        obs_log.default_level()


def test_logger_emits_and_set_level():
    import io
    lg = obs_log.get_logger("testsub")
    root = obs_log.get_logger()
    buf = io.StringIO()
    h = logging.StreamHandler(buf)
    h.setFormatter(root.handlers[0].formatter)  # the [repro.<sub>] format
    root.addHandler(h)
    obs_log.set_level("INFO")
    try:
        lg.info("hello from obs")
        assert "[repro.testsub] hello from obs" in buf.getvalue()
        obs_log.set_level(logging.WARNING)
        lg.info("now below level")
        assert "now below level" not in buf.getvalue()
    finally:
        root.removeHandler(h)
        obs_log.set_level(obs_log.default_level())
    with pytest.raises(ValueError):
        obs_log.set_level("NOT_A_LEVEL")


def test_scheduler_default_log_is_quiet_under_pytest(env, capsys):
    sched = Scheduler(Engine(env["cfg"], env["params"], slots=2,
                             max_len=MAX_LEN))
    sched.log("should not appear on stdout")    # INFO < WARNING: dropped
    out = capsys.readouterr()
    assert "should not appear" not in out.out


# ============================================================ obs_report
def test_obs_report_cli(tmp_path, env):
    reg = Registry()
    trace_path = tmp_path / "t.jsonl"
    tr = Tracer(str(trace_path))
    sched = Scheduler(Engine(env["cfg"], env["params"], slots=2,
                             max_len=MAX_LEN),
                      metrics=reg, tracer=tr)
    for r in _fleet(env["prompts"][:2], gens=[4, 5]):
        sched.submit(r)
    sched.run()
    tr.close()
    prom = tmp_path / "m.prom"
    reg.dump_prometheus(str(prom))

    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "obs_report.py"),
         "--trace", str(trace_path), "--metrics", str(prom),
         "--chrome", str(tmp_path / "t.chrome.json"), "--check"],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "complete request span trees" in r.stdout
    chrome = json.loads((tmp_path / "t.chrome.json").read_text())
    assert chrome["traceEvents"]

    # the human report renders both artifacts
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "obs_report.py"),
         "--trace", str(trace_path), "--metrics", str(prom)],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "TTFT" in r.stdout and "repro_requests_submitted_total" in r.stdout

    # --check fails loudly on a truncated trace (killed-process prefix
    # with a dangling span)
    bad = tmp_path / "bad.jsonl"
    lines = trace_path.read_text().strip().splitlines()
    bad.write_text("\n".join(lines[:3]) + "\n")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "obs_report.py"),
         "--trace", str(bad), "--check"],
        capture_output=True, text=True)
    assert r.returncode == 1 and "FAIL" in r.stdout


# ============================================================= profiling
def test_profiling_noop_without_env(monkeypatch):
    from repro.obs import profiling as obs_prof
    monkeypatch.delenv("REPRO_PROFILE_DIR", raising=False)
    with obs_prof.session("x") as started:
        assert started is False
    with obs_prof.annotation("y"):
        pass


def test_profiling_session_writes_trace(monkeypatch, tmp_path):
    from repro.obs import profiling as obs_prof
    monkeypatch.setenv("REPRO_PROFILE_DIR", str(tmp_path))
    with obs_prof.session("x") as started:
        if not started:           # profiler unavailable in this build
            pytest.skip("jax.profiler could not start")
        with obs_prof.annotation("region"):
            jax.numpy.zeros(8).block_until_ready()
    assert any(tmp_path.rglob("*"))    # something was written
