"""Kernel-tier observability (repro.obs, ISSUE 10).

Contracts under test:

* cost model — Cost arithmetic, platform peaks (+ env overrides),
  roofline seconds/achieved-fraction math, plan-keyed dispatch on REAL
  ``ski_plan``/``tno_plan`` dicts, and the
  ``jit(...).lower().compile().cost_analysis()`` cross-check that pins
  the analytic estimators to XLA's own numbers on concrete shapes;
* compile watchdog — fresh traces counted + timed, retrace warnings
  past the declared budget, engine executables pinned to the shape
  family (a second identical fleet compiles nothing new);
* attribution — Chrome-trace aggregation, engine drain attribution
  coverage, memory gauges over a live fd DecodeState;
* bench history — drift gate passes flat/improving synthetic histories
  and fails a 20% regression; platform filtering;
* obs_report — histogram quantile interpolation and the span-vs-
  histogram TTFT/TPOT disagreement flag;
* lifecycle — the default tracer's atexit flush and the
  ``REPRO_METRICS_FILE`` final dump survive an exit without close();
  the train entrypoint emits both artifacts.
"""
import json
import math
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import ski
from repro.core.tno import TNOConfig, tno_init, tno_plan
from repro.models.transformer import init_model
from repro.nn.params import unbox
from repro.obs import compilewatch as obs_compile
from repro.obs import cost as obs_cost
from repro.obs import devstats as obs_devstats
from repro.obs.metrics import Registry
from repro.serving_engine import Engine, Request, Scheduler

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "tools"))

import bench_history  # noqa: E402  (tools/ is not a package)
import obs_report  # noqa: E402

PLENS = [3, 6, 5, 2]
GENS = [6, 7, 8, 6]
MAX_LEN = 32


@pytest.fixture(scope="module")
def env():
    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"),
                           dtype="float32", param_dtype="float32")
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
               for p in PLENS]
    return {"cfg": cfg, "params": params, "prompts": prompts}


def _fleet(prompts, uid_prefix="r", gens=GENS, **kw):
    return [Request(uid=f"{uid_prefix}{i}", prompt=pr, max_new=g, **kw)
            for i, (pr, g) in enumerate(zip(prompts, gens))]


# ============================================================ cost model
def test_cost_arithmetic():
    a = obs_cost.Cost(10.0, 4.0)
    b = obs_cost.Cost(5.0, 1.0)
    assert (a + b).flops == 15.0 and (a + b).bytes == 5.0
    assert a.scale(3).flops == 30.0 and a.scale(3).bytes == 12.0
    t = obs_cost.total({"x": a, "y": b})
    assert t.flops == 15.0 and t.bytes == 5.0


def test_peaks_platforms_and_env_override(monkeypatch):
    assert obs_cost.peaks("tpu").flops == obs_cost.TPU_PEAK_FLOPS
    assert obs_cost.peaks("tpu").collective_bw > 0
    monkeypatch.setenv("REPRO_CPU_PEAK_FLOPS", "1e11")
    monkeypatch.setenv("REPRO_CPU_PEAK_BW", "4e10")
    pk = obs_cost.peaks("cpu")
    assert pk.flops == 1e11 and pk.mem_bw == 4e10
    monkeypatch.setenv("REPRO_CPU_PEAK_FLOPS", "fast")
    with pytest.raises(ValueError, match="REPRO_CPU_PEAK_FLOPS"):
        obs_cost.peaks("cpu")


def test_roofline_seconds_and_fraction():
    pk = obs_cost.Peaks(flops=100.0, mem_bw=10.0)
    compute_bound = obs_cost.Cost(flops=1000.0, bytes=1.0)
    s = obs_cost.seconds(compute_bound, pk)
    assert s["dominant"] == "compute" and s["bound_s"] == 10.0
    memory_bound = obs_cost.Cost(flops=1.0, bytes=1000.0)
    s = obs_cost.seconds(memory_bound, pk)
    assert s["dominant"] == "memory" and s["bound_s"] == 100.0
    # measured exactly at the roof -> 1.0; 10x slower -> 0.1
    assert obs_cost.achieved_fraction(compute_bound, 10.0, pk) \
        == pytest.approx(1.0)
    assert obs_cost.achieved_fraction(compute_bound, 100.0, pk) \
        == pytest.approx(0.1)
    assert math.isnan(obs_cost.achieved_fraction(compute_bound, 0.0, pk))


def test_ski_plan_cost_dispatch():
    """cost_of_plan keys off REAL ski_plan dicts and its kernel names
    track the plan's Gram variant."""
    cfg = ski.SKIConfig(d=8, rank=16, filter_size=4)
    params, _ = unbox(ski.ski_init(jax.random.PRNGKey(0), cfg))
    n = 64
    plan = ski.ski_plan(params, cfg, n)
    assert plan["variant"] == "dense"
    costs = obs_cost.cost_of_plan(plan, n=n, d=cfg.d, batch=2)
    assert set(costs) == {"interp_reduce", "ski_fused"}
    assert all(c.flops > 0 and c.bytes > 0 for c in costs.values())
    for variant, gram_key in (("windowed", "ski_windowed"),
                              ("fft", "ski_fft_gram")):
        p = ski.ski_plan(params, cfg, n, variant=variant)
        costs = obs_cost.cost_of_plan(p, n=n, d=cfg.d)
        assert set(costs) == {"interp_reduce", gram_key, "ski_expand2"}
    # the dense Gram costs more flops than the banded one at equal rank
    dense = obs_cost.gram_cost("dense", 64, 8)
    banded = obs_cost.gram_cost("windowed", 64, 8, bw=8)
    assert dense.flops > banded.flops
    with pytest.raises(ValueError, match="unknown gram variant"):
        obs_cost.gram_cost("sparse", 16, 8)


def test_fd_and_baseline_plan_cost():
    n = 24
    causal = TNOConfig(d=6, variant="fd", causal=True)
    p, _ = unbox(tno_init(jax.random.PRNGKey(0), causal))
    plan = tno_plan(p, causal, n)
    costs = obs_cost.cost_of_plan(plan, n=n, d=6)
    assert "hilbert_window" in costs         # causal: analytic completion
    assert {"rfft", "fd_mul"} <= set(costs)
    acausal = TNOConfig(d=6, variant="fd", causal=False)
    p2, _ = unbox(tno_init(jax.random.PRNGKey(1), acausal))
    costs2 = obs_cost.cost_of_plan(tno_plan(p2, acausal, n), n=n, d=6)
    assert "hilbert_window" not in costs2
    base = TNOConfig(d=6, variant="tno")
    p3, _ = unbox(tno_init(jax.random.PRNGKey(2), base))
    costs3 = obs_cost.cost_of_plan(tno_plan(p3, base, n), n=n, d=6)
    assert set(costs3) == {"toeplitz_fft"}
    with pytest.raises(ValueError, match="unrecognised plan keys"):
        obs_cost.cost_of_plan({"mystery": 1}, n=n, d=6)


def test_decode_step_cost_families(env):
    costs = obs_cost.decode_step_cost(env["cfg"], batch=4, max_len=MAX_LEN)
    # fd arch: every layer is a streaming fd mixer + projections + FFN
    assert {"embed", "fd_stream", "mixer_proj", "mlp", "lm_head"} \
        <= set(costs)
    assert "tno_hist" not in costs and "attention" not in costs
    assert obs_cost.total(costs).flops > 0
    # batch scales every per-token family linearly
    c1 = obs_cost.decode_step_cost(env["cfg"], batch=1, max_len=MAX_LEN)
    assert costs["mlp"].flops == pytest.approx(4 * c1["mlp"].flops)


# ------------------------------------------- XLA cost_analysis cross-check
def test_xla_cost_cross_check_matmul():
    """The estimator convention (2 flops per multiply-add) must agree
    with XLA's own cost_analysis on a plain matmul."""
    a = jnp.ones((32, 48), jnp.float32)
    b = jnp.ones((48, 16), jnp.float32)
    got = obs_cost.xla_cost(lambda x, y: x @ y, a, b)
    if got is None:
        pytest.skip("backend exposes no cost_analysis")
    analytic = 2.0 * 32 * 48 * 16
    assert analytic / 2 <= got["flops"] <= analytic * 2
    io_bytes = 4 * (32 * 48 + 48 * 16 + 32 * 16)
    assert got["bytes"] >= io_bytes / 4


def test_xla_cost_cross_check_short_conv():
    """short_conv_cost vs XLA on the repo's own depthwise conv op —
    within a small factor (XLA counts the padded/masked lanes too)."""
    from repro.kernels import ops
    b, n, m, d = 2, 64, 8, 8
    x = jnp.ones((b, n, d), jnp.float32)
    filt = jnp.ones((d, m), jnp.float32)
    got = obs_cost.xla_cost(
        lambda xx, ff: ops.short_conv(xx, ff, causal=True), x, filt)
    if got is None or got["flops"] <= 0:
        pytest.skip("backend exposes no cost_analysis for this op")
    est = obs_cost.short_conv_cost(n, m, d, b)
    ratio = est.flops / got["flops"]
    assert 0.1 <= ratio <= 10.0, (est.flops, got["flops"])


# ======================================================= compile watchdog
class _FakeLog:
    def __init__(self):
        self.warnings = []

    def warning(self, msg, *a):
        self.warnings.append(msg % a if a else msg)


def test_compilewatch_counts_time_and_warn():
    reg = Registry()
    log = _FakeLog()
    w = obs_compile.CompileWatch(metrics=reg, prefix="t.", logger=log)
    w.expect("f", 1)
    f = w.wrap("f", lambda x: x * 2)
    x4 = jnp.ones((4,))
    f(x4)
    f(x4)                                   # cached executable: no trace
    assert w.count("f") == 1 and not log.warnings
    f(jnp.ones((8,)))                       # new shape -> fresh trace
    assert w.count("f") == 2
    assert len(log.warnings) == 1
    assert "compile watchdog: t.f retraced" in log.warnings[0]
    c = reg.get("repro_compiles_total")
    assert c.get(fn="t.f") == 2
    h = reg.get("repro_compile_seconds").labels(fn="t.f")
    assert h.count == 2 and h.sum > 0       # both traces were timed


def test_compilewatch_untimed_mark():
    """A trace with no live call frame (AOT lower, warmup helpers) still
    counts, just without a latency observation."""
    reg = Registry()
    w = obs_compile.CompileWatch(metrics=reg)
    w._mark("g")
    assert w.count("g") == 1
    assert reg.get("repro_compiles_total").get(fn="g") == 1
    assert reg.get("repro_compile_seconds").labels(fn="g").count == 0


def test_engine_compiles_pinned_across_fleets(env):
    """Retrace pinning across the prefill bucket ladder: compiles track
    SHAPES, not request count — a second identical fleet through the
    same engine compiles nothing new."""
    eng = Engine(env["cfg"], env["params"], slots=4, max_len=MAX_LEN,
                 metrics=Registry())
    sched = Scheduler(eng)
    for r in _fleet(env["prompts"], "a"):
        sched.submit(r)
    results, state = sched.run()
    assert all(len(results[f"a{i}"]) == g for i, g in enumerate(GENS))
    first = eng.compile_watch.counts()
    assert first and first.get("generate", 0) >= 1
    sched2 = Scheduler(eng)
    for r in _fleet(env["prompts"], "b"):
        sched2.submit(r)
    results2, _ = sched2.run(state)
    assert all(len(results2[f"b{i}"]) == g for i, g in enumerate(GENS))
    assert eng.compile_watch.counts() == first
    # within the declared shape-family budgets: nothing warned
    for name, n in first.items():
        exp = eng.compile_watch._expected.get(name)
        assert exp is None or n <= exp, (name, n, exp)


# ============================================================ attribution
def test_aggregate_chrome_synthetic():
    P = obs_devstats.KERNEL_SCOPE_PREFIX
    events = [
        {"name": P + "fd_mul", "ph": "X", "dur": 1500.0},
        {"name": P + "fd_mul", "ph": "X", "dur": 500.0},
        {"name": P + "rfft", "ph": "B", "ts": 100.0, "pid": 1, "tid": 2},
        {"name": P + "rfft", "ph": "E", "ts": 400.0, "pid": 1, "tid": 2},
        {"name": "unrelated", "ph": "X", "dur": 9e9},
    ]
    got = obs_devstats.aggregate_chrome(events)
    assert got == {"fd_mul": pytest.approx(2e-3),
                   "rfft": pytest.approx(3e-4)}


def test_attribute_engine_coverage_and_memory(env):
    """The CPU-honest attribution path: engine-drain seconds split by
    analytic FLOP shares must account for most of the measured drain,
    and the memory gauges see the fd streaming cache."""
    reg = Registry()
    eng = Engine(env["cfg"], env["params"], slots=4, max_len=MAX_LEN,
                 metrics=reg)
    sched = Scheduler(eng, metrics=reg)
    for r in _fleet(env["prompts"]):
        sched.submit(r)
    t0 = time.perf_counter()
    _, state = sched.run()
    drain_s = time.perf_counter() - t0
    attr = obs_devstats.attribute_engine(eng, reg, drain_s=drain_s)
    assert attr["device_s"] > 0
    assert attr["coverage"] is not None and attr["coverage"] >= 0.5
    kernels = {row["kernel"] for row in attr["rows"]}
    assert "fd_stream" in kernels and "mlp" in kernels
    assert sum(row["frac"] for row in attr["rows"]) == pytest.approx(1.0)
    sec = reg.get("repro_kernel_seconds_total")
    assert sum(sec.get(kernel=k) for k in kernels) \
        == pytest.approx(attr["device_s"], rel=1e-6)
    fracs = reg.get("repro_kernel_roofline_frac")
    assert any(fracs.get(kernel=k) > 0 for k in kernels)

    mem = obs_devstats.sample_memory(reg, state)
    assert mem["repro_decode_cache_bytes"] > 0
    assert mem["repro_fd_stream_bytes"] > 0   # ring + spectra leaves
    assert mem["repro_fd_stream_bytes"] < mem["repro_decode_cache_bytes"]
    assert reg.get("repro_decode_cache_bytes").get() \
        == mem["repro_decode_cache_bytes"]
    # reuse dict: first call walks the pytree and fills the cache, later
    # calls republish the identical sizes without rewalking (the drain's
    # cache is fixed-shape — this keeps sampling off the hot path)
    reuse: dict = {}
    first = obs_devstats.sample_memory(reg, state, reuse=reuse)
    assert reuse["cache_bytes"] == first["repro_decode_cache_bytes"]
    reuse["cache_bytes"] += 1   # prove the cached value is what's used
    again = obs_devstats.sample_memory(reg, state, reuse=reuse)
    assert again["repro_decode_cache_bytes"] \
        == first["repro_decode_cache_bytes"] + 1


def test_mem_sample_every_env(monkeypatch):
    monkeypatch.delenv("REPRO_MEM_SAMPLE_EVERY", raising=False)
    assert obs_devstats.mem_sample_every() == 0
    monkeypatch.setenv("REPRO_MEM_SAMPLE_EVERY", "16")
    assert obs_devstats.mem_sample_every() == 16
    monkeypatch.setenv("REPRO_MEM_SAMPLE_EVERY", "often")
    with pytest.raises(ValueError, match="REPRO_MEM_SAMPLE_EVERY"):
        obs_devstats.mem_sample_every()


# =========================================================== bench history
def _engine_payload(tok_s=1000.0, speedup=15.0, prefill=2.0,
                    overhead=0.02, coverage=0.9, platform="cpu"):
    return {"bench": "engine", "platform": platform,
            "results": [{"slots": 16, "engine_tok_s": tok_s,
                         "speedup": speedup}],
            "prefill": {"speedup": prefill},
            "obs": {"overhead_frac": overhead,
                    "attributed_coverage": coverage}}


def _seed_history(tmp_path, payloads):
    for i, p in enumerate(payloads):
        bench_history.append_record(
            bench_history.make_record(p, sha=f"s{i}"), tmp_path)
    return bench_history.load_history("engine", tmp_path)


def test_drift_gate_flat_and_improving(tmp_path):
    hist = _seed_history(tmp_path, [_engine_payload()] * 3)
    flat = bench_history.make_record(_engine_payload(), sha="new")
    assert bench_history.check_drift(flat, hist) == []
    better = bench_history.make_record(
        _engine_payload(tok_s=1500.0, speedup=20.0, overhead=0.01,
                        coverage=0.95), sha="new")
    assert bench_history.check_drift(better, hist) == []


def test_drift_gate_fails_20pct_regression(tmp_path):
    hist = _seed_history(tmp_path, [_engine_payload()] * 3)
    worse = bench_history.make_record(
        _engine_payload(speedup=15.0 * 0.75), sha="bad")   # -25%
    failures = bench_history.check_drift(worse, hist)
    assert [f["metric"] for f in failures] == ["speedup_S16"]
    assert failures[0]["drift"] == pytest.approx(-0.25)
    # abs-slack metric: overhead rising past +0.05 fails too
    hot = bench_history.make_record(
        _engine_payload(overhead=0.09), sha="hot")
    failures = bench_history.check_drift(hot, hist)
    assert [f["metric"] for f in failures] == ["obs_overhead_frac"]


def test_drift_gate_platform_filter_and_empty(tmp_path):
    # only-TPU history never gates a CPU record (and vice versa)
    hist = _seed_history(
        tmp_path, [_engine_payload(speedup=100.0, platform="tpu")] * 3)
    cpu = bench_history.make_record(_engine_payload(speedup=1.0),
                                    sha="cpu")
    assert bench_history.check_drift(cpu, hist) == []
    assert bench_history.check_drift(cpu, []) == []   # first record wins


def test_bench_history_cli_roundtrip(tmp_path):
    payload = tmp_path / "BENCH_engine.json"
    payload.write_text(json.dumps(_engine_payload()))
    script = os.path.join(ROOT, "tools", "bench_history.py")
    hd = str(tmp_path / "hist")
    for _ in range(2):
        r = subprocess.run(
            [sys.executable, script, "--history-dir", hd,
             "append", str(payload)],
            capture_output=True, text=True)
        assert r.returncode == 0, r.stderr
    ok = subprocess.run(
        [sys.executable, script, "--history-dir", hd,
         "check", str(payload)], capture_output=True, text=True)
    assert ok.returncode == 0 and "drift gate OK" in ok.stdout
    payload.write_text(json.dumps(_engine_payload(speedup=15.0 * 0.7)))
    bad = subprocess.run(
        [sys.executable, script, "--history-dir", hd,
         "check", str(payload)], capture_output=True, text=True)
    assert bad.returncode == 1 and "DRIFT: speedup_S16" in bad.stdout
    show = subprocess.run(
        [sys.executable, script, "--history-dir", hd, "show"],
        capture_output=True, text=True)
    assert show.returncode == 0 and "engine (2 records)" in show.stdout


def test_extract_metrics_tolerates_missing_obs():
    payload = _engine_payload()
    del payload["obs"]
    m = bench_history.extract_metrics(payload)
    assert "obs_overhead_frac" not in m and "speedup_S16" in m
    with pytest.raises(SystemExit, match="unknown bench"):
        bench_history.extract_metrics({"bench": "nope"})


# ============================================================= obs_report
def test_hist_quantile_interpolation():
    buckets, cum = [1.0, 2.0, 4.0], [2, 6, 8]
    v, lo, hi = obs_report.hist_quantile(buckets, cum, 8, 50)
    assert (lo, hi) == (1.0, 2.0)
    assert v == pytest.approx(1.5)          # target 4 is halfway into b2
    v, lo, hi = obs_report.hist_quantile(buckets, cum, 10, 99)
    assert v == 4.0 and hi == float("inf")  # overflow bucket
    v, _, _ = obs_report.hist_quantile(buckets, cum, 0, 50)
    assert math.isnan(v)


def test_compare_latency_agreement_flag():
    buckets, cum = [0.01, 0.1, 1.0], [0, 10, 10]
    hists = {"repro_ttft_seconds": [({}, buckets, cum, 2.0, 10)]}
    # spans inside the containing bucket: agree
    report = {"ttft": [0.05] * 10}
    rows = obs_report.compare_latency(report, hists)
    assert len(rows) == 2 and all(r["agree"] for r in rows)
    # spans far outside any bucket width: flagged
    rows = obs_report.compare_latency({"ttft": [40.0] * 10}, hists)
    assert rows and not any(r["agree"] for r in rows)


def test_load_histograms_prom_and_json_agree(tmp_path):
    reg = Registry()
    h = reg.histogram("repro_ttft_seconds", "ttft",
                      buckets=(0.01, 0.1, 1.0))
    for x in (0.05, 0.06, 0.5):
        h.observe(x)
    pj, pp = str(tmp_path / "m.json"), str(tmp_path / "m.prom")
    reg.dump_json(pj)
    reg.dump_prometheus(pp)
    hj = obs_report.load_histograms(pj)["repro_ttft_seconds"][0]
    hp = obs_report.load_histograms(pp)["repro_ttft_seconds"][0]
    assert hj[1] == hp[1] == [0.01, 0.1, 1.0]
    assert hj[2] == hp[2] == [0, 2, 3]
    assert hj[4] == hp[4] == 3


# ============================================================== lifecycle
def _run_py(body, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.update(extra_env or {})
    return subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          capture_output=True, text=True, env=env)


def test_default_tracer_atexit_flush(tmp_path):
    """Fewer events than FLUSH_EVERY + exit without close(): the atexit
    hook must still land every event on disk (the satellite bugfix)."""
    path = str(tmp_path / "t.jsonl")
    r = _run_py("""
        from repro.obs import tracing
        t = tracing.default_tracer()
        assert t is not None and t.FLUSH_EVERY > 10
        for i in range(10):
            t.instant("tick", uid=str(i))
    """, {"REPRO_TRACE_FILE": path})
    assert r.returncode == 0, r.stderr
    lines = [ln for ln in open(path) if ln.strip()]
    assert len(lines) == 10
    assert json.loads(lines[-1])["uid"] == "9"


def test_metrics_file_env_final_dump(tmp_path):
    """REPRO_METRICS_FILE alone (no REPRO_METRICS) arms the default
    registry and dumps it at exit."""
    path = str(tmp_path / "m.prom")
    r = _run_py("""
        from repro.obs import metrics
        reg = metrics.default_registry()
        reg.counter("x_total", "x").inc(3)
    """, {"REPRO_METRICS_FILE": path})
    assert r.returncode == 0, r.stderr
    text = open(path).read()
    assert "x_total 3" in text


def test_train_entrypoint_emits_obs_artifacts(tmp_path):
    """--metrics-file/--trace-file parity with launch/serve.py."""
    mpath = str(tmp_path / "train.json")
    tpath = str(tmp_path / "train.jsonl")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "fd-tnn-lm-wt103", "--smoke", "--steps", "3",
         "--seq-len", "16", "--global-batch", "2",
         "--metrics-file", mpath, "--trace-file", tpath],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    doc = json.load(open(mpath))["metrics"]
    assert doc["repro_train_steps_total"]["series"][0]["value"] == 3
    compiles = doc["repro_compiles_total"]["series"]
    assert [(s["labels"]["fn"], s["value"]) for s in compiles] \
        == [("train.train_step", 1)]
    events = [json.loads(ln) for ln in open(tpath) if ln.strip()]
    steps = [e for e in events if e["name"] == "train_step"]
    assert len(steps) == 6                   # 3 steps x (B + E)
    assert {e["ph"] for e in steps} == {"B", "E"}
    assert os.path.exists(tpath + ".chrome.json")
