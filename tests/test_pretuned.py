"""Pretuned autotune tables (ISSUE 5 satellite): the shipped
kernels/pretuned/*.json seed block sizes when no explicit cache is set,
with precedence  user cache (REPRO_AUTOTUNE_CACHE / default path)
> pretuned > heuristic."""
import json
import os

import pytest

from repro.kernels import backend


@pytest.fixture(autouse=True)
def _fresh_cache(monkeypatch, tmp_path):
    # isolate every test from the developer's real ~/.cache file
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "unused.json"))
    backend.clear_cache(memory_only=True)
    yield
    backend.clear_cache(memory_only=True)


def _key(kernel="short_conv", n=64, d=32):
    return backend._key(kernel, n, d, "float32", True)


def test_pretuned_seeds_when_env_unset(monkeypatch, tmp_path):
    pdir = tmp_path / "pretuned"
    pdir.mkdir()
    key = _key()
    (pdir / "cpu_interpret.json").write_text(json.dumps(
        {"version": 1, "entries": {key: {"bn": 16, "bd": 16}}}))
    monkeypatch.setattr(backend, "PRETUNED_DIR", str(pdir))
    monkeypatch.delenv("REPRO_AUTOTUNE_CACHE", raising=False)
    monkeypatch.setenv("HOME", str(tmp_path))     # default cache path empty
    backend.clear_cache(memory_only=True)
    assert backend.get_blocks("short_conv", 64, 32, "float32", True) == (16, 16)


def test_env_cache_wins_and_disables_pretuned(monkeypatch, tmp_path):
    pdir = tmp_path / "pretuned"
    pdir.mkdir()
    key = _key()
    other = _key(n=128)
    (pdir / "cpu_interpret.json").write_text(json.dumps(
        {"entries": {key: {"bn": 16, "bd": 16},
                     other: {"bn": 24, "bd": 16}}}))
    monkeypatch.setattr(backend, "PRETUNED_DIR", str(pdir))
    env_cache = tmp_path / "mine.json"
    env_cache.write_text(json.dumps(
        {"version": 1, "entries": {key: {"bn": 32, "bd": 8}}}))
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(env_cache))
    backend.clear_cache(memory_only=True)
    # explicit cache entry wins over the pretuned one
    assert backend.get_blocks("short_conv", 64, 32, "float32", True) == (32, 8)
    # with an explicit cache set, pretuned entries are NOT consulted:
    # the other key falls back to the heuristic
    assert backend.get_blocks("short_conv", 128, 32, "float32", True) == \
        backend.heuristic_blocks("short_conv", 128, 32, True)


def test_missing_everywhere_falls_back_to_heuristic(monkeypatch, tmp_path):
    monkeypatch.setattr(backend, "PRETUNED_DIR", str(tmp_path / "nope"))
    backend.clear_cache(memory_only=True)
    assert backend.get_blocks("short_conv", 64, 32, "float32", True) == \
        backend.heuristic_blocks("short_conv", 64, 32, True)


def test_shipped_cpu_interpret_table_is_wellformed():
    """The committed table parses, targets this repo's kernels, and every
    entry carries valid block sizes."""
    path = os.path.join(backend.PRETUNED_DIR, "cpu_interpret.json")
    with open(path) as f:
        data = json.load(f)
    entries = data["entries"]
    assert entries, "shipped pretuned table is empty"
    known = set(backend._DEFAULT_TARGETS)
    for key, val in entries.items():
        kernel = key.split("|")[0]
        assert kernel in known, key
        assert "|cpu|interpret" in key, key
        assert int(val["bn"]) >= 8 and int(val["bd"]) >= 8, (key, val)
