"""Large-rank SKI (ISSUE 3): backend rank-dispatch boundaries, band-budget
edges, and windowed / FFT-Gram kernel parity against the jnp oracle —
forward and ``jax.grad`` — in interpret mode.

Tolerance policy: at the established grad-parity sizes (n ≤ a few hundred)
the kernel path matches the reference to the 1e-5 fp32 gate of
tests/test_ski_grad.py. At the acceptance sizes (n up to 8192, r up to
8192) BOTH variants agree with each other to ~1e-6 but drift from the
single-einsum reference at the 1e-4 level — pure fp32 accumulation-order
noise of the shared tiled pass-1 (the dense kernel shows the same drift
at these sizes), so those cases gate at 1e-4.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ski
from repro.kernels import backend, ops, ref, ski_vjp
from repro.kernels.ski_fused import (ski_expand_pass2_pallas,
                                     ski_windowed_pass2_pallas)
from repro.nn.params import unbox

TOL_SMALL = 1e-5      # the CI grad-parity gate (fp32)
TOL_LARGE = 1e-4      # fp32 accumulation-order drift at n, r ≥ 2048


def rel_err(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return float(np.abs(got - want).max() / (np.abs(want).max() + 1e-12))


# ------------------------------------------------ rank dispatch boundaries
def test_rank_dispatch_boundaries():
    """r = 511/512/513 straddle the dense ceiling; the windowed ceiling
    straddles 4096/4097."""
    assert backend.ski_rank_variant(64) == "dense"
    assert backend.ski_rank_variant(511) == "dense"
    assert backend.ski_rank_variant(512) == "dense"
    assert backend.ski_rank_variant(513) == "windowed"
    assert backend.ski_rank_variant(2048) == "windowed"
    assert backend.ski_rank_variant(4096) == "windowed"
    assert backend.ski_rank_variant(4097) == "fft"
    assert backend.ski_rank_variant(8192) == "fft"


def test_rank_dispatch_gram_byte_guard():
    """r ≤ 512 but an oversized (d, r, r) still refuses the dense kernel:
    d·r²·4 must stay under the 64 MB Gram budget."""
    r = 512
    d_ok = backend.SKI_GRAM_BYTES_MAX // (r * r * 4)
    assert backend.ski_rank_variant(r, d_ok) == "dense"
    assert backend.ski_rank_variant(r, d_ok + 1) == "windowed"


def test_rank_dispatch_env_overrides(monkeypatch):
    monkeypatch.setenv("REPRO_SKI_DENSE_RMAX", "100")
    monkeypatch.setenv("REPRO_SKI_WINDOWED_RMAX", "200")
    assert backend.ski_rank_variant(100) == "dense"
    assert backend.ski_rank_variant(101) == "windowed"
    assert backend.ski_rank_variant(201) == "fft"


def test_describe_mentions_variant_thresholds():
    s = backend.describe()
    assert "ski_variant=" in s
    assert f"dense<={backend.ski_dense_rank_max()}" in s
    assert f"windowed<={backend.ski_windowed_rank_max()}" in s
    assert f"band<={backend.band_budget()}" in s


def test_plan_variant_matches_policy():
    """The variant the plan records is exactly the backend policy's pick
    (what backend.describe() advertises), per rank regime."""
    cfg = ski.SKIConfig(d=4, rank=8, filter_size=4)
    params, _ = unbox(ski.ski_init(jax.random.PRNGKey(0), cfg))
    for n, r_expect in [(8, 8), (6, 6)]:
        plan = ski.ski_plan(params, cfg, n)
        assert plan["variant"] == backend.ski_rank_variant(r_expect, cfg.d)
    # unfused config records "unfused" and never builds the dense Gram
    cfg_u = ski.SKIConfig(d=4, rank=8, filter_size=4, fused=False)
    plan = ski.ski_plan(params, cfg_u, 8)
    assert plan["variant"] == "unfused" and "a_dense" not in plan


# --------------------------------------------------- band sizing / budget
@pytest.mark.parametrize("n,r,bn", [
    (2048, 513, 256), (4096, 2048, 256), (1024, 1024, 64), (300, 290, 104),
])
def test_band_width_covers_every_tile(n, r, bn):
    """Every hat tap of every length-bn tile lands inside the static
    [w0, w0+bw) window the kernel slices."""
    bw = backend.band_width(bn, n, r)
    idx_lo = np.asarray(ski.make_inducing(n, r)[0])
    for s in range(0, n, bn):
        e = min(s + bn, n) - 1
        w0 = min(idx_lo[s], max(0, r - bw))
        assert idx_lo[s] >= w0
        assert idx_lo[e] + 1 <= w0 + bw - 1, (s, idx_lo[s], idx_lo[e], w0, bw)


def test_band_fit_respects_budget(monkeypatch):
    bn, bw = backend.band_fit(256, 4096, 2048)
    assert bw <= backend.band_budget()
    monkeypatch.setenv("REPRO_SKI_BAND_MAX", "16")
    bn2, bw2 = backend.band_fit(256, 4096, 2048)
    assert bw2 <= 16 or bn2 == 8       # shrunk the tile to fit the band
    assert bn2 <= bn


def test_windowed_kernel_correct_under_tiny_band_budget(monkeypatch):
    """A 16-wide band forces many (bw, bw) chunks per tile — the streaming
    loop, not the degenerate single-chunk case — and must stay exact."""
    monkeypatch.setenv("REPRO_SKI_BAND_MAX", "16")
    b, n, d, r, m = 1, 256, 8, 96, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (b, n, d))
    z = jax.random.normal(jax.random.PRNGKey(1), (b, r, d))
    coef = jax.random.normal(jax.random.PRNGKey(2), (d, 2 * r - 1))
    filt = jax.random.normal(jax.random.PRNGKey(3), (d, m)) * 0.1
    got = ski_windowed_pass2_pallas(x, z, coef, filt, False, interpret=True)
    z2 = ref.toeplitz_gram_matvec_ref(coef, z)
    want = ref.ski_expand_pass2_ref(x, z2, filt, False)
    # 16-wide chunks change the fp32 summation order vs the single-FFT
    # reference — forward values gate at the repo-standard 1e-4
    assert rel_err(got, want) <= TOL_LARGE


# ------------------------------- three variants vs oracle (interpret mode)
@pytest.mark.parametrize("variant", ["dense", "windowed", "fft"])
@pytest.mark.parametrize("causal", [False, True])
def test_three_variant_parity_small(variant, causal):
    """All three Gram strategies compute the same operator: forced-variant
    plans under forced-Pallas dispatch match the dense jnp oracle."""
    cfg = ski.SKIConfig(d=8, rank=24, filter_size=8, use_pallas=True)
    params, _ = unbox(ski.ski_init(jax.random.PRNGKey(0), cfg))
    n = 96
    x = jax.random.normal(jax.random.PRNGKey(1), (2, n, cfg.d))
    plan = ski.ski_plan(params, cfg, n, causal=causal, variant=variant)
    assert plan["variant"] == variant
    got = ski.ski_tno_apply(params, cfg, x, causal=causal, plan=plan)
    cfg_ref = ski.SKIConfig(d=8, rank=24, filter_size=8, use_pallas=False)
    plan_ref = ski.ski_plan(params, cfg_ref, n, causal=causal,
                            variant="dense")
    want = ski.ski_tno_apply(params, cfg_ref, x, causal=causal,
                             plan=plan_ref)
    assert rel_err(got, want) <= 1e-4   # fwd values, repo-standard fp32 tol


@pytest.mark.parametrize("variant", ["windowed", "fft"])
def test_coef_op_grad_parity_small(variant):
    """jax.grad of the coef op (kernel path) == reference autodiff at the
    CI grad-parity gate, for every cotangent (x, a_coef, filt)."""
    n, d, r, m = 75, 16, 11, 4          # ragged on both axes
    x = jax.random.normal(jax.random.PRNGKey(0), (2, n, d))
    coef = jax.random.normal(jax.random.PRNGKey(1), (d, 2 * r - 1))
    filt = jax.random.normal(jax.random.PRNGKey(2), (d, m)) * 0.1
    idx_lo, w_lo, _ = ski.make_inducing(n, r)

    def loss(x, a, f, use_pallas):
        y = ops.ski_fused_tno_coef(x, a, f, idx_lo, w_lo, r, False, variant,
                                   use_pallas=use_pallas, interpret=True)
        return jnp.sum(jnp.sin(y.astype(jnp.float32)))

    gp = jax.grad(lambda *a: loss(*a, True), argnums=(0, 1, 2))(x, coef, filt)
    gr = jax.grad(lambda *a: loss(*a, False), argnums=(0, 1, 2))(x, coef, filt)
    for name, p, q in zip(("x", "a_coef", "filt"), gp, gr):
        assert rel_err(p, q) <= TOL_SMALL, (name, rel_err(p, q))


def test_gram_coef_grad_fft_matches_oracle():
    gz = jax.random.normal(jax.random.PRNGKey(0), (3, 13, 6))
    z = jax.random.normal(jax.random.PRNGKey(1), (3, 13, 6))
    from repro.kernels.ski_grad import gram_coef_grad_fft
    got = gram_coef_grad_fft(gz, z)
    want = ref.gram_coef_grad_ref(gz, z)
    assert got.shape == want.shape == (6, 25)
    assert rel_err(got, want) <= TOL_SMALL


# ------------------------- acceptance sizes: r ∈ {512, 2048, 8192}
@pytest.mark.parametrize("variant", ["windowed", "fft"])
@pytest.mark.parametrize("n,r", [(2048, 512), (4096, 2048), (8192, 8192)])
def test_coef_op_parity_acceptance_sizes(n, r, variant):
    """Forward AND jax.grad parity vs the jnp reference at the ISSUE-3
    acceptance ranks, interpret mode (see module docstring for the 1e-4
    large-size gate)."""
    d, m = 8, 6
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n, d))
    coef = jax.random.normal(jax.random.PRNGKey(1), (d, 2 * r - 1)) * 0.05
    filt = jax.random.normal(jax.random.PRNGKey(2), (d, m)) * 0.1
    idx_lo, w_lo, _ = ski.make_inducing(n, r)

    yp = ops.ski_fused_tno_coef(x, coef, filt, idx_lo, w_lo, r, False,
                                variant, use_pallas=True, interpret=True)
    yr = ops.ski_fused_tno_coef(x, coef, filt, idx_lo, w_lo, r, False,
                                variant, use_pallas=False)
    assert rel_err(yp, yr) <= TOL_LARGE

    def loss(x, a, f, use_pallas):
        y = ops.ski_fused_tno_coef(x, a, f, idx_lo, w_lo, r, False, variant,
                                   use_pallas=use_pallas, interpret=True)
        return jnp.sum(jnp.sin(y.astype(jnp.float32)))

    gp = jax.grad(lambda *a: loss(*a, True), argnums=(0, 1, 2))(x, coef, filt)
    gr = jax.grad(lambda *a: loss(*a, False), argnums=(0, 1, 2))(x, coef, filt)
    for name, p, q in zip(("x", "a_coef", "filt"), gp, gr):
        assert rel_err(p, q) <= TOL_LARGE, (name, rel_err(p, q))


def test_coef_op_bf16_parity():
    n, d, r, m = 1024, 8, 600, 6        # windowed regime by default policy
    assert backend.ski_rank_variant(r, d) == "windowed"
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n, d), jnp.bfloat16)
    coef = jax.random.normal(jax.random.PRNGKey(1), (d, 2 * r - 1)) * 0.05
    filt = jax.random.normal(jax.random.PRNGKey(2), (d, m)) * 0.1
    idx_lo, w_lo, _ = ski.make_inducing(n, r)
    yp = ops.ski_fused_tno_coef(x, coef, filt, idx_lo, w_lo, r, False,
                                "windowed", use_pallas=True, interpret=True)
    assert yp.dtype == jnp.bfloat16
    yr = ops.ski_fused_tno_coef(x.astype(jnp.float32), coef, filt, idx_lo,
                                w_lo, r, False, "windowed", use_pallas=False)
    assert rel_err(yp, yr) <= 2e-2      # bf16 gate, fp32 accumulation


# ------------------------------------- expand kernel (FFT variant pass 2)
@pytest.mark.parametrize("b,n,d,r,m", [
    (1, 128, 16, 24, 8),
    (2, 100, 20, 33, 6),                # ragged n and d
])
def test_expand_pass2_kernel_matches_ref(b, n, d, r, m):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, n, d))
    z2 = jax.random.normal(jax.random.PRNGKey(1), (b, r, d))
    filt = jax.random.normal(jax.random.PRNGKey(2), (d, m)) * 0.1
    for causal in (False, True):
        got = ski_expand_pass2_pallas(x, z2, filt, causal, interpret=True)
        want = ref.ski_expand_pass2_ref(x, z2, filt, causal)
        assert rel_err(got, want) <= TOL_SMALL


# --------------------------------------- dispatch: no silent ref fallback
def test_large_rank_training_takes_kernel_path():
    """jax.grad through ski_tno_apply at a windowed-regime rank under
    forced-Pallas dispatch runs the coef custom VJP (counters), matches
    the reference-path gradients, and a stale-plan check still fires."""
    d, n = 8, 640
    cfg_p = ski.SKIConfig(d=d, rank=700, filter_size=8, use_pallas=True)
    cfg_r = ski.SKIConfig(d=d, rank=700, filter_size=8, use_pallas=False)
    params, _ = unbox(ski.ski_init(jax.random.PRNGKey(0), cfg_p))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, n, d))
    assert backend.ski_rank_variant(min(700, n), d) == "windowed"
    ski_vjp.reset_counters()
    gp = jax.grad(lambda p: ski.ski_tno_apply(p, cfg_p, x).sum())(params)
    assert ski_vjp.counters["fwd"] >= 1
    assert ski_vjp.counters["bwd_kernel"] >= 1
    assert ski_vjp.counters["bwd_ref"] == 0, "silent reference fallback"
    gr = jax.grad(lambda p: ski.ski_tno_apply(p, cfg_r, x).sum())(params)
    for p, q in zip(jax.tree.leaves(gp), jax.tree.leaves(gr)):
        assert rel_err(p, q) <= 1e-4


def test_large_rank_grad_override_env(monkeypatch):
    """REPRO_PALLAS_GRAD=0 keeps the Pallas forward of the coef op but
    swaps its backward to the reference formulas (counters + parity)."""
    n, d, r, m = 256, 8, 96, 4
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n, d))
    coef = jax.random.normal(jax.random.PRNGKey(1), (d, 2 * r - 1)) * 0.1
    filt = jax.random.normal(jax.random.PRNGKey(2), (d, m)) * 0.1
    idx_lo, w_lo, _ = ski.make_inducing(n, r)

    def loss(x):
        return ops.ski_fused_tno_coef(x, coef, filt, idx_lo, w_lo, r, False,
                                      "windowed", use_pallas=True,
                                      interpret=True).sum()

    monkeypatch.setenv("REPRO_PALLAS_GRAD", "0")
    ski_vjp.reset_counters()
    g_ref = jax.grad(loss)(x)
    assert ski_vjp.counters["bwd_ref"] == 1
    monkeypatch.setenv("REPRO_PALLAS_GRAD", "auto")
    ski_vjp.reset_counters()
    g_kernel = jax.grad(loss)(x)
    assert ski_vjp.counters["bwd_kernel"] == 1
    assert rel_err(g_kernel, g_ref) <= TOL_SMALL
