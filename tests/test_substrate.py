"""Substrate tests: optimizer, data pipeline determinism/elasticity,
checkpoint atomicity + elastic restore, trainer fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import manifest as ckpt
from repro.data.pipeline import DataConfig, batch_at
from repro.optim import adamw
from repro.runtime.trainer import StragglerMonitor, Trainer, TrainerConfig


# ---------------------------------------------------------------- optim
def test_adamw_converges_quadratic():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0, clip_norm=100.0)
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = adamw.init(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        state, params, _ = adamw.step(cfg, state, grads, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_schedule_warmup_cosine():
    cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_frac=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.int32(0)))
    lr_w = float(adamw.schedule(cfg, jnp.int32(10)))
    lr_end = float(adamw.schedule(cfg, jnp.int32(110)))
    assert lr0 < 0.05 and abs(lr_w - 1.0) < 1e-5
    assert abs(lr_end - 0.1) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    assert abs(total - 1.0) < 1e-4
    assert float(norm) > 1.0


def test_int8_error_feedback_unbiased_over_time():
    """Error feedback: the *cumulative* compressed signal tracks the
    cumulative true signal (bias does not accumulate)."""
    rng = np.random.default_rng(0)
    err = jnp.zeros(64)
    true_sum = np.zeros(64)
    sent_sum = np.zeros(64)
    for _ in range(50):
        g = jnp.asarray(rng.normal(size=64).astype(np.float32))
        deq, err = adamw.compress_with_feedback(g, err)
        true_sum += np.asarray(g)
        sent_sum += np.asarray(deq)
    resid = np.abs(true_sum - sent_sum).max()
    scale = np.abs(true_sum).max()
    assert resid < 0.05 * scale, (resid, scale)


def test_bf16_moments_still_converge():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=1, total_steps=300,
                          weight_decay=0.0, moments_dtype="bfloat16")
    target = jnp.array([0.5, -1.5])
    params = {"w": jnp.zeros(2)}
    state = adamw.init(cfg, params)
    assert state.mu["w"].dtype == jnp.bfloat16
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        state, params, _ = adamw.step(cfg, state, grads, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=5e-2)


# ----------------------------------------------------------------- data
def test_data_deterministic_and_elastic():
    base = dict(vocab=64, seq_len=32, global_batch=8, seed=3)
    whole = batch_at(DataConfig(**base), step=7)
    again = batch_at(DataConfig(**base), step=7)
    np.testing.assert_array_equal(whole["tokens"], again["tokens"])

    # 2-host split reproduces the identical global batch (elastic invariant)
    h0 = batch_at(DataConfig(**base, host_id=0, num_hosts=2), step=7)
    h1 = batch_at(DataConfig(**base, host_id=1, num_hosts=2), step=7)
    glued = np.concatenate([h0["tokens"], h1["tokens"]], axis=0)
    np.testing.assert_array_equal(whole["tokens"], glued)


def test_data_steps_differ():
    cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=0)
    a = batch_at(cfg, 0)["tokens"]
    b = batch_at(cfg, 1)["tokens"]
    assert not np.array_equal(a, b)


def test_lra_match_task_is_learnable_signal():
    cfg = DataConfig(vocab=32, seq_len=64, global_batch=64, seed=0,
                     kind="lra_match")
    batch = batch_at(cfg, 0)
    toks, labels = batch["tokens"], batch["labels"]
    match = toks[:, 1] == toks[:, 62]
    np.testing.assert_array_equal(match.astype(np.int32), labels[:, 0])
    assert 0.2 < labels[:, 0].mean() < 0.8        # both classes present


def test_bytes_source(tmp_path):
    p = tmp_path / "corpus.bin"
    p.write_bytes(bytes(range(256)) * 64)
    cfg = DataConfig(vocab=256, seq_len=16, global_batch=2, seed=0,
                     kind="bytes", path=str(p))
    b = batch_at(cfg, 0)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ----------------------------------------------------------- checkpoint
def _tree():
    return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    os.makedirs(d)
    ckpt.save(d, 3, _tree(), extra={"data_step": 3})
    out, extra = ckpt.restore(d, jax.tree.map(jnp.zeros_like, _tree()))
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(_tree()["w"]))
    assert out["nested"]["b"].dtype == jnp.bfloat16
    assert extra["data_step"] == 3


def test_checkpoint_atomicity_crash_window(tmp_path):
    """A half-written step dir without COMMITTED must be ignored."""
    d = str(tmp_path / "ck")
    os.makedirs(d)
    ckpt.save(d, 1, _tree())
    # simulate crash: step dir exists, no COMMITTED marker, stale LATEST
    os.makedirs(os.path.join(d, "step_000000002/data"))
    with open(os.path.join(d, "LATEST"), "w") as f:
        f.write("2")
    assert ckpt.latest_step(d) == 1
    out, _ = ckpt.restore(d, jax.tree.map(jnp.zeros_like, _tree()))
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(_tree()["w"]))


def test_async_checkpointer_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    acp = ckpt.AsyncCheckpointer(d, keep=2)
    for s in (1, 2, 3, 4):
        acp.save_async(s, _tree(), extra={"data_step": s})
    acp.wait()
    assert ckpt.latest_step(d) == 4
    committed = [p for p in os.listdir(d) if p.endswith(".COMMITTED")]
    assert len(committed) == 2                    # gc kept last 2


def test_elastic_restore_reshards(tmp_path):
    """Save from a 1-device layout, restore with explicit NamedShardings
    on a different (1x1) mesh — the elastic path."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    os.makedirs(d)
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ckpt.save(d, 1, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P(None))}
    out, _ = ckpt.restore(d, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8))


# -------------------------------------------------------------- trainer
def _toy_train_setup(tmp_path, total_steps=8, fail_at=None, **tkw):
    calls = {"n": 0}

    def train_step(state, batch):
        loss = jnp.float32(1.0 / (1 + state["step"]))
        return ({"step": state["step"] + 1},
                {"loss": loss, "tok0": jnp.float32(batch["tokens"][0, 0])})

    def failure_hook(step, attempt):
        calls["n"] += 1
        if fail_at is not None and step == fail_at and attempt == 0:
            raise RuntimeError("injected fault")

    dcfg = DataConfig(vocab=16, seq_len=8, global_batch=2, seed=0)
    tcfg = TrainerConfig(total_steps=total_steps,
                         ckpt_dir=str(tmp_path / "ck"),
                         ckpt_every=4, log_every=0, **tkw)
    return Trainer(tcfg, train_step, dcfg, failure_hook=failure_hook), calls


def test_trainer_runs_and_checkpoints(tmp_path):
    trainer, _ = _toy_train_setup(tmp_path)
    state, end = trainer.run({"step": jnp.int32(0)})
    assert end == 8 and int(state["step"]) == 8
    assert ckpt.latest_step(str(tmp_path / "ck")) == 8


def test_trainer_step_retry_on_injected_fault(tmp_path):
    trainer, calls = _toy_train_setup(tmp_path, fail_at=3)
    state, end = trainer.run({"step": jnp.int32(0)})
    assert end == 8                                # survived the fault
    assert calls["n"] == 9                         # one retry


def test_trainer_fails_after_max_retries(tmp_path):
    def always_fail(step, attempt):
        raise RuntimeError("dead node")
    dcfg = DataConfig(vocab=16, seq_len=8, global_batch=2, seed=0)
    tcfg = TrainerConfig(total_steps=4, max_retries=1, log_every=0)
    tr = Trainer(tcfg, lambda s, b: (s, {"loss": jnp.float32(1)}), dcfg,
                 failure_hook=always_fail)
    with pytest.raises(RuntimeError, match="failed after"):
        tr.run({"step": jnp.int32(0)})


def test_trainer_restart_resumes_from_checkpoint(tmp_path):
    trainer, _ = _toy_train_setup(tmp_path, total_steps=4)
    state, end = trainer.run({"step": jnp.int32(0)})
    assert end == 4
    # a "new job" restores and continues to 8
    trainer2, _ = _toy_train_setup(tmp_path, total_steps=8)
    state0 = {"step": jnp.int32(0)}
    state, start = trainer2.try_restore(state0)
    assert start == 4 and int(state["step"]) == 4
    state, end = trainer2.run(state, start)
    assert end == 8 and int(state["step"]) == 8


def test_trainer_nan_guard_retries_then_raises(tmp_path):
    def nan_step(state, batch):
        return state, {"loss": jnp.float32(np.nan)}
    dcfg = DataConfig(vocab=16, seq_len=8, global_batch=2, seed=0)
    tcfg = TrainerConfig(total_steps=2, max_retries=1, log_every=0)
    tr = Trainer(tcfg, nan_step, dcfg)
    with pytest.raises(RuntimeError, match="failed after"):
        tr.run({"s": jnp.int32(0)})


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(factor=3.0, alpha=0.5)
    for i in range(5):
        assert not mon.observe(i, 1.0)
    assert mon.observe(5, 10.0)                    # 10x the EMA
    assert mon.flagged and mon.flagged[0][0] == 5
    assert not mon.observe(6, 1.0)                 # EMA not poisoned


# ---------------------------------------------- restore validation (PR 6)
def test_manifest_restore_leaf_count_mismatch_raises(tmp_path):
    """Real exceptions, not asserts: a mismatched tree must fail loudly
    even under `python -O`."""
    d = str(tmp_path / "ck")
    os.makedirs(d)
    ckpt.save(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError, match="leaves"):
        ckpt.restore(d, {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_manifest_restore_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    os.makedirs(d)
    ckpt.save(d, 1, {"a": jnp.zeros((3,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(d, {"a": jnp.zeros((4,))})


# ------------------------------------------ donated-buffer retry (PR 6)
def test_trainer_retry_survives_donated_buffer_invalidation(tmp_path):
    """train_step is jit'd with donated state: a step that fails *after*
    consuming its buffers leaves them invalidated, so a naive retry
    replays on dead arrays. The trainer must rebuild from the undonated
    host-side copy taken before the attempt."""
    calls = {"n": 0}

    def donating_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 1:
            for leaf in jax.tree.leaves(state):
                leaf.delete()   # what a donated, failed jit call leaves
            raise RuntimeError("step failed after consuming donated buffers")
        return ({"w": state["w"] + 1}, {"loss": jnp.float32(1.0)})

    dcfg = DataConfig(vocab=16, seq_len=8, global_batch=2, seed=0)
    tcfg = TrainerConfig(total_steps=2, max_retries=2, log_every=0)
    tr = Trainer(tcfg, donating_step, dcfg)
    state, end = tr.run({"w": jnp.arange(4, dtype=jnp.float32)})
    assert end == 2 and calls["n"] == 3            # one retry, then clean
    np.testing.assert_array_equal(np.asarray(state["w"]), np.arange(4) + 2)


def test_trainer_retry_unsafe_without_undonated_copy(tmp_path):
    """The hazard the copy exists for: with undonated_retry_copy=False
    the retry replays on deleted buffers and every attempt fails."""
    def donating_step(state, batch):
        for leaf in jax.tree.leaves(state):
            if not leaf.is_deleted():
                leaf.delete()
                raise RuntimeError("consumed donated buffers")
        return ({"w": state["w"] + 1}, {"loss": jnp.float32(1.0)})

    dcfg = DataConfig(vocab=16, seq_len=8, global_batch=2, seed=0)
    tcfg = TrainerConfig(total_steps=2, max_retries=2, log_every=0,
                         undonated_retry_copy=False)
    tr = Trainer(tcfg, donating_step, dcfg)
    with pytest.raises(RuntimeError, match="failed after"):
        tr.run({"w": jnp.arange(4, dtype=jnp.float32)})
