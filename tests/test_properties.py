"""Hypothesis property tests on the system's core invariants.

Collects cleanly (skips, does not error) when hypothesis is not installed
— see requirements-dev.txt for the pinned test deps.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import hilbert, toeplitz
from repro.core.ski import make_inducing
from repro.kernels import ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


@st.composite
def toeplitz_case(draw):
    n = draw(st.integers(2, 96))
    d = draw(st.integers(1, 4))
    seed = draw(st.integers(0, 2 ** 16))
    return n, d, seed


@given(toeplitz_case())
def test_toeplitz_matvec_linearity_and_oracle(case):
    n, d, seed = case
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    t = jax.random.normal(k1, (d, 2 * n - 1))
    x = jax.random.normal(k2, (d, n))
    y = jax.random.normal(k3, (d, n))
    # oracle equivalence
    dense = toeplitz.dense_toeplitz(t, n)
    np.testing.assert_allclose(
        np.asarray(toeplitz.toeplitz_matvec(t, x)),
        np.asarray(jnp.einsum("dnm,dm->dn", dense, x)),
        rtol=2e-3, atol=2e-3)
    # linearity
    lhs = toeplitz.toeplitz_matvec(t, 2.0 * x + y)
    rhs = 2.0 * toeplitz.toeplitz_matvec(t, x) + toeplitz.toeplitz_matvec(t, y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=2e-3, atol=2e-3)


@given(st.integers(2, 128), st.integers(0, 2 ** 16))
def test_causal_spectrum_always_causal(n, seed):
    khat = jax.random.normal(jax.random.PRNGKey(seed), (2, n + 1))
    spec = hilbert.causal_spectrum(khat)
    k_time = np.asarray(jnp.fft.irfft(spec, n=2 * n, axis=-1))
    assert np.abs(k_time[:, n + 1:]).max() < 1e-4 * max(
        np.abs(k_time).max(), 1.0)


@given(st.integers(2, 64), st.integers(0, 2 ** 16))
def test_hilbert_annihilates_constants(n, seed):
    """H{const} == 0 (DC is in the kernel of the Hilbert transform)."""
    c = float(jax.random.normal(jax.random.PRNGKey(seed), ()))
    u = jnp.full((2 * n,), c)
    h = np.asarray(hilbert.discrete_hilbert(u))
    assert np.abs(h).max() < 1e-4 * (abs(c) + 1.0)


@given(st.integers(3, 65), st.integers(2, 512))
def test_inducing_points_cover_and_interpolate(r, n):
    hypothesis.assume(r <= n)
    idx_lo, w_lo, h = make_inducing(n, r)
    idx, w = np.asarray(idx_lo), np.asarray(w_lo)
    assert idx.min() >= 0 and idx.max() <= r - 2
    assert np.all(w >= -1e-6) and np.all(w <= 1 + 1e-6)
    # W reproduces linear functions on the grid (degree-1 precision, up
    # to fp32 rounding of the irrational spacing h — values scale with n)
    wmat = np.asarray(ref.dense_interp_matrix(idx_lo, w_lo, r))
    grid = np.arange(r) * h
    lin = 3.0 * grid - 1.0
    np.testing.assert_allclose(wmat @ lin, 3.0 * np.arange(n) - 1.0,
                               rtol=1e-3, atol=1e-3 * n)


@given(st.integers(0, 2 ** 16), st.sampled_from([16, 33, 64]),
       st.sampled_from([4, 8]))
def test_short_conv_shift_equivariance(seed, n, m):
    """Causal depthwise conv commutes with time shift (Toeplitz property)."""
    d = 4
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, n, d))
    filt = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, m))
    y = ref.short_conv_ref(x, filt, causal=True)
    xs = jnp.pad(x, ((0, 0), (3, 0), (0, 0)))[:, :n]     # shift right 3
    ys = ref.short_conv_ref(xs, filt, causal=True)
    np.testing.assert_allclose(np.asarray(ys[:, 3:]), np.asarray(y[:, :-3]),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 2 ** 16))
def test_optimizer_step_is_deterministic(seed):
    from repro.optim import adamw
    cfg = adamw.OptConfig()
    params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (8,))}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(seed + 1), (8,))}
    s1, p1, _ = adamw.step(cfg, adamw.init(cfg, params), grads, params)
    s2, p2, _ = adamw.step(cfg, adamw.init(cfg, params), grads, params)
    np.testing.assert_array_equal(np.asarray(p1["w"]), np.asarray(p2["w"]))


@given(st.integers(0, 1000), st.integers(1, 4), st.integers(1, 4))
def test_data_rows_independent_of_host_layout(step, h1, h2):
    """The same global row produces identical tokens under any host split
    whose host_batch divides it — the elastic-restore data invariant."""
    from repro.data.pipeline import DataConfig, batch_at
    gb = 8
    hypothesis.assume(gb % h1 == 0 and gb % h2 == 0)
    base = dict(vocab=32, seq_len=16, global_batch=gb, seed=1)
    a = np.concatenate([
        batch_at(DataConfig(**base, host_id=i, num_hosts=h1), step)["tokens"]
        for i in range(h1)])
    b = np.concatenate([
        batch_at(DataConfig(**base, host_id=i, num_hosts=h2), step)["tokens"]
        for i in range(h2)])
    np.testing.assert_array_equal(a, b)


@given(st.integers(0, 2 ** 16), st.sampled_from(["tno", "fd"]))
def test_causal_mixers_never_leak_future(seed, variant):
    from repro.core import tno
    from repro.nn.params import unbox
    cfg = tno.TNOConfig(d=4, variant=variant, causal=True, rank=8,
                        filter_size=4)
    params, _ = unbox(tno.tno_init(jax.random.PRNGKey(seed), cfg))
    n = 24
    cut = n // 2
    x1 = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, n, 4))
    x2 = x1.at[:, cut:].add(
        jax.random.normal(jax.random.PRNGKey(seed + 2), (1, n - cut, 4)))
    y1 = tno.tno_apply(params, cfg, x1)
    y2 = tno.tno_apply(params, cfg, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :cut]),
                               np.asarray(y2[:, :cut]),
                               rtol=5e-3, atol=5e-3)


# --------------------------------------------------------- PR 2: gradients
@st.composite
def grad_parity_case(draw):
    """Shapes for the custom-VJP parity sweep: ragged n/d, r ≤ n, m ≥ 2."""
    n = draw(st.integers(16, 80))
    d = draw(st.integers(2, 12))
    r = draw(st.integers(3, min(16, n)))
    m = draw(st.sampled_from([2, 4, 6]))
    causal = draw(st.booleans())
    seed = draw(st.integers(0, 2 ** 16))
    return n, d, r, m, causal, seed


@settings(max_examples=10)
@given(grad_parity_case())
def test_fused_custom_vjp_matches_reference_grad(case):
    """Property: for any shape/causality, jax.grad through the Pallas
    custom-VJP fused op equals jax.grad through the reference path."""
    from repro.core.ski import make_inducing
    from repro.kernels import ops
    n, d, r, m, causal, seed = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (1, n, d))
    a = jax.random.normal(ks[1], (d, r, r))
    filt = jax.random.normal(ks[2], (d, m)) * 0.1
    idx_lo, w_lo, _ = make_inducing(n, r)

    def loss(x, a, f, up):
        y = ops.ski_fused_tno(x, a, f, idx_lo, w_lo, r, causal, use_pallas=up)
        return jnp.sum(jnp.sin(y))

    gp = jax.grad(lambda *t: loss(*t, True), argnums=(0, 1, 2))(x, a, filt)
    gr = jax.grad(lambda *t: loss(*t, False), argnums=(0, 1, 2))(x, a, filt)
    for p, q in zip(gp, gr):
        p, q = np.asarray(p, np.float32), np.asarray(q, np.float32)
        assert np.abs(p - q).max() <= 1e-5 * max(np.abs(q).max(), 1.0)


@settings(max_examples=6)
@given(st.integers(0, 2 ** 16), st.booleans())
def test_fused_custom_vjp_bf16_grad_within_tolerance(seed, causal):
    """Property: bf16 signal with fp32 params — kernel-path grads stay
    within the bf16 acceptance tolerance (2e-2 relative) of the ref path."""
    from repro.core.ski import make_inducing
    from repro.kernels import ops
    n, d, r, m = 48, 8, 7, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (1, n, d)).astype(jnp.bfloat16)
    a = jax.random.normal(ks[1], (d, r, r))
    filt = jax.random.normal(ks[2], (d, m)) * 0.1
    idx_lo, w_lo, _ = make_inducing(n, r)

    def loss(a, f, up):
        y = ops.ski_fused_tno(x, a, f, idx_lo, w_lo, r, causal, use_pallas=up)
        return jnp.sum(y.astype(jnp.float32))

    gp = jax.grad(lambda *t: loss(*t, True), argnums=(0, 1))(a, filt)
    gr = jax.grad(lambda *t: loss(*t, False), argnums=(0, 1))(a, filt)
    for p, q in zip(gp, gr):
        p, q = np.asarray(p, np.float32), np.asarray(q, np.float32)
        assert np.abs(p - q).max() <= 2e-2 * max(np.abs(q).max(), 1.0)


@settings(max_examples=10)
@given(st.integers(2, 40), st.integers(1, 6), st.sampled_from([2, 3, 5]),
       st.integers(0, 2 ** 16))
def test_conv_grad_kernels_linear_in_cotangent(n, d, m, seed):
    """Property: the tap-grad reduction is bilinear — scaling either input
    scales the output (exactness of the per-tile accumulation)."""
    left = m // 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    g = jax.random.normal(ks[0], (1, n, d))
    x = jax.random.normal(ks[1], (1, n, d))
    df = ref.conv_tap_grad_ref(g, x, m, left)
    df2 = ref.conv_tap_grad_ref(2.0 * g, x, m, left)
    np.testing.assert_allclose(np.asarray(df2), 2.0 * np.asarray(df),
                               rtol=1e-5, atol=1e-5)
    assert df.shape == (d, m)
