"""Oracle/property layer for the discrete Hilbert transform (paper §3.3.1).

Pins the production FFT form ``discrete_hilbert`` against the paper's
Definition-1 convolution oracle ``discrete_hilbert_conv`` (the periodised
2/(πl) kernel), and asserts the causal-spectrum construction is *exactly*
causal — ``irfft(causal_spectrum(u))`` vanishes on lags n+1..2n-1 — across
dtypes and odd/even n. Deterministic sweeps always run; the hypothesis
property versions (random draws over sizes/seeds) run whenever hypothesis
is installed (requirements-dev.txt — CI always has it).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hilbert

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    # per-test settings, NOT a global load_profile: mutating the active
    # profile at import time would leak deadline=None/max_examples into
    # every other module's hypothesis tests for the whole pytest session
    _settings = hypothesis.settings(
        deadline=None, max_examples=25,
        suppress_health_check=[hypothesis.HealthCheck.too_slow])
    HAVE_HYPOTHESIS = True
except ImportError:                                      # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _StubStrategies:
        """Keeps @given(...) decorators importable when hypothesis is
        absent; the tests themselves are skipped via needs_hypothesis."""
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StubStrategies()

    def given(*a, **k):
        return lambda f: f

    def _settings(f):
        return f

needs_hypothesis = pytest.mark.skipif(not HAVE_HYPOTHESIS,
                                      reason="hypothesis not installed")

TOL = {jnp.float32: 1e-4, jnp.bfloat16: 3e-2}


def _rel_max(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return float(np.abs(got - want).max() / (np.abs(want).max() + 1e-12))


# --------------------------------------- FFT form vs Definition-1 oracle
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m", [4, 6, 16, 34, 64, 130])   # even (oracle domain)
def test_hilbert_fft_matches_conv_oracle(m, dtype):
    """The O(n log n) FFT Hilbert == the paper's periodised-convolution
    Definition 1, on its even-period domain."""
    u = jax.random.normal(jax.random.PRNGKey(m), (3, m)).astype(dtype)
    got = hilbert.discrete_hilbert(u)
    assert got.dtype == dtype
    want = hilbert.discrete_hilbert_conv(u.astype(jnp.float32))
    assert _rel_max(got, want) <= TOL[dtype]


def test_hilbert_annihilates_dc_and_nyquist():
    """DC and the Nyquist line are in the kernel of H (sign(freq) is zero
    at 0 and, for the fft layout, ±π is its own negative)."""
    m = 32
    dc = jnp.ones((m,))
    nyq = jnp.asarray((-1.0) ** np.arange(m), jnp.float32)
    assert float(jnp.abs(hilbert.discrete_hilbert(dc)).max()) < 1e-6
    assert float(jnp.abs(hilbert.discrete_hilbert(nyq)).max()) < 1e-5


def test_hilbert_involution_up_to_dc_nyquist():
    """H(H(u)) = -u on the subspace orthogonal to DC and Nyquist."""
    m = 64
    u = jax.random.normal(jax.random.PRNGKey(0), (2, m))
    # project out DC and Nyquist components
    nyq = jnp.asarray((-1.0) ** np.arange(m), jnp.float32)
    u = u - u.mean(axis=-1, keepdims=True)
    u = u - (u @ nyq)[:, None] * nyq / m
    hh = hilbert.discrete_hilbert(hilbert.discrete_hilbert(u))
    assert _rel_max(hh, -u) <= 1e-4


# ------------------------------------------------- exact causality layer
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [2, 5, 8, 33, 64, 127])    # odd and even n
def test_causal_spectrum_exactly_causal(n, dtype):
    """irfft(causal_spectrum(u)) must vanish on lags n+1..2n-1 (the
    analytic-signal window zeroes negative lags exactly, not to FFT
    leakage level)."""
    khat = jax.random.normal(jax.random.PRNGKey(n), (2, n + 1)).astype(dtype)
    spec = hilbert.causal_spectrum(khat)
    k_time = np.asarray(jnp.fft.irfft(spec, n=2 * n, axis=-1))
    scale = max(float(np.abs(k_time).max()), 1.0)
    assert np.abs(k_time[:, n + 1:]).max() <= 1e-5 * scale


@pytest.mark.parametrize("n", [5, 8, 64])
def test_causal_spectrum_matches_literal_hilbert_form(n):
    """The windowed two-FFT construction == the paper-literal
    khat - i·H{khat} over the even-symmetric extension."""
    khat = jax.random.normal(jax.random.PRNGKey(n), (3, n + 1))
    a = np.asarray(hilbert.causal_spectrum(khat))
    b = np.asarray(hilbert.causal_spectrum_via_hilbert(khat))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


# ------------------------------------------------ hypothesis property layer
@needs_hypothesis
@_settings
@given(st.integers(2, 96), st.integers(0, 2 ** 16))
def test_prop_hilbert_fft_matches_conv_oracle(half_m, seed):
    m = 2 * half_m                                       # even period
    u = jax.random.normal(jax.random.PRNGKey(seed), (2, m))
    got = hilbert.discrete_hilbert(u)
    want = hilbert.discrete_hilbert_conv(u)
    assert _rel_max(got, want) <= 1e-4


@needs_hypothesis
@_settings
@given(st.integers(2, 128), st.integers(0, 2 ** 16),
       st.sampled_from(["float32", "bfloat16"]))
def test_prop_causal_spectrum_always_causal(n, seed, dtype):
    khat = jax.random.normal(jax.random.PRNGKey(seed), (2, n + 1)).astype(
        jnp.dtype(dtype))
    spec = hilbert.causal_spectrum(khat)
    k_time = np.asarray(jnp.fft.irfft(spec, n=2 * n, axis=-1))
    scale = max(float(np.abs(k_time).max()), 1.0)
    assert np.abs(k_time[:, n + 1:]).max() <= 1e-5 * scale


@needs_hypothesis
@_settings
@given(st.integers(2, 64), st.integers(0, 2 ** 16))
def test_prop_hilbert_is_linear(half_m, seed):
    m = 2 * half_m
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    u = jax.random.normal(k1, (m,))
    v = jax.random.normal(k2, (m,))
    lhs = hilbert.discrete_hilbert(3.0 * u - 2.0 * v)
    rhs = 3.0 * hilbert.discrete_hilbert(u) - 2.0 * hilbert.discrete_hilbert(v)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=1e-4, atol=1e-4)
