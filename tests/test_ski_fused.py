"""Fused SKI-TNO pipeline: parity vs the dense oracle, ragged shapes,
bf16 inputs, small-n fallbacks, and the backend autotune cache."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import ski, toeplitz
from repro.kernels import backend, ops, ref
from repro.nn.params import unbox
from tests.conftest import assert_allclose


def _setup(d=8, rank=16, m=8, seed=0, **kw):
    cfg = ski.SKIConfig(d=d, rank=rank, filter_size=m, **kw)
    params, _ = unbox(ski.ski_init(jax.random.PRNGKey(seed), cfg))
    return cfg, params


def _dense_T(params, cfg, n, causal):
    """Dense (d, n, n) oracle incl. the causal variant (masked Gram +
    causal band) — generalises ski.ski_dense_oracle."""
    r = min(cfg.rank, n)
    idx_lo, w_lo, h = ski.make_inducing(n, r)
    w = ref.dense_interp_matrix(idx_lo, w_lo, r)
    a_coef = ski.inducing_gram_coeffs(params, cfg, r, h)
    if causal:
        a_coef = toeplitz.causal_mask_coeffs(a_coef, r)
    a = toeplitz.dense_toeplitz(a_coef, r)
    t = jnp.einsum("nr,drs,ms->dnm", w, a, w)
    m = cfg.filter_size
    left = 0 if causal else m // 2
    i = jnp.arange(n)
    k_idx = (i[:, None] - i[None, :]) + left
    valid = (k_idx >= 0) & (k_idx < m)
    return t + jnp.where(valid[None], params["filt"][:, jnp.clip(k_idx, 0, m - 1)], 0.0)


# ------------------------------------------------- parity vs dense oracle
@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("n", [64, 100])          # 100: n % tile != 0
def test_fused_matches_dense_oracle(n, causal):
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, n, cfg.d))
    got = ski.ski_tno_apply(params, cfg, x, causal=causal)
    want = jnp.einsum("dnm,bmd->bnd", _dense_T(params, cfg, n, causal), x)
    assert float(jnp.abs(got - want).max()) <= 1e-4


def test_bidirectional_matches_ski_dense_oracle_exact_api():
    cfg, params = _setup()
    n = 96
    x = jax.random.normal(jax.random.PRNGKey(2), (1, n, cfg.d))
    got = ski.ski_tno_apply(params, cfg, x)
    want = jnp.einsum("dnm,bmd->bnd", ski.ski_dense_oracle(params, cfg, n), x)
    assert float(jnp.abs(got - want).max()) <= 1e-4


@pytest.mark.parametrize("causal", [False, True])
def test_fused_matches_unfused_pipeline(causal):
    """Fused two-pass (direct Gram matmul, hat W) == unfused 4-kernel
    pipeline (FFT Gram, scatter W) — two independent computation routes."""
    cfg, params = _setup(d=6, rank=9, m=4)
    cfg_u = ski.SKIConfig(d=6, rank=9, filter_size=4, fused=False)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 75, 6))  # odd n
    assert_allclose(ski.ski_tno_apply(params, cfg, x, causal=causal),
                    ski.ski_tno_apply(params, cfg_u, x, causal=causal),
                    rtol=1e-4, atol=1e-4)


def test_fused_bf16_input_fp32_accumulation():
    cfg, params = _setup()
    n = 128
    x32 = jax.random.normal(jax.random.PRNGKey(4), (1, n, cfg.d))
    x16 = x32.astype(jnp.bfloat16)
    got = ski.ski_tno_apply(params, cfg, x16)
    assert got.dtype == jnp.bfloat16
    want = ski.ski_tno_apply(params, cfg, x32)
    assert_allclose(got, want, rtol=3e-2, atol=3e-2)


def test_plan_reuse_is_equivalent():
    cfg, params = _setup()
    n = 80
    x = jax.random.normal(jax.random.PRNGKey(5), (1, n, cfg.d))
    plan = ski.ski_plan(params, cfg, n, causal=False)
    assert "a_dense" in plan                       # fused-eligible
    assert_allclose(ski.ski_tno_apply(params, cfg, x, plan=plan),
                    ski.ski_tno_apply(params, cfg, x))


def test_stale_plan_is_rejected():
    """A plan built with the wrong causal flag or n computes a different
    operator — must raise, not silently return wrong numbers."""
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 80, cfg.d))
    plan = ski.ski_plan(params, cfg, 80, causal=False)
    with pytest.raises(ValueError, match="plan mismatch"):
        ski.ski_tno_apply(params, cfg, x, causal=True, plan=plan)
    with pytest.raises(ValueError, match="plan mismatch"):
        ski.ski_tno_apply(params, cfg, x[:, :64], plan=plan)


# ------------------------------------------------------ Pallas fused path
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,n,d,r,m", [
    (1, 128, 128, 16, 8),
    (1, 100, 136, 17, 8),     # ragged n and d (pad + slice path)
])
def test_fused_pass2_pallas_matches_ref(b, n, d, r, m, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, n, d)).astype(dtype)
    z = jax.random.normal(jax.random.PRNGKey(1), (b, r, d)).astype(dtype)
    a = jax.random.normal(jax.random.PRNGKey(2), (d, r, r))
    filt = jax.random.normal(jax.random.PRNGKey(3), (d, m)).astype(dtype)
    got = ops.ski_fused_pass2(x, z, a, filt, False, use_pallas=True)
    want = ref.ski_fused_pass2_ref(x, z, a, filt, False)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-4
    assert_allclose(got, want, rtol=tol, atol=tol)


def test_short_conv_pallas_ragged_and_small_n():
    # ragged n, d -> pad/slice path (old code asserted n % bn == 0)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 300, 136))
    filt = jax.random.normal(jax.random.PRNGKey(1), (136, 8))
    for causal in (True, False):
        assert_allclose(ops.short_conv(x, filt, causal, use_pallas=True),
                        ref.short_conv_ref(x, filt, causal),
                        rtol=5e-4, atol=5e-4)
    # n < m (bn=8 < m=16): falls back to the reference path, no crash
    xs = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 16))
    fs = jax.random.normal(jax.random.PRNGKey(3), (16, 16))
    assert_allclose(ops.short_conv(xs, fs, True, use_pallas=True),
                    ref.short_conv_ref(xs, fs, True))
    # same fallback in the fused pass-2 kernel
    zs = jax.random.normal(jax.random.PRNGKey(4), (1, 3, 16))
    a = jax.random.normal(jax.random.PRNGKey(5), (16, 3, 3))
    assert_allclose(ops.ski_fused_pass2(xs, zs, a, fs, True, use_pallas=True),
                    ref.ski_fused_pass2_ref(xs, zs, a, fs, True),
                    rtol=5e-4, atol=5e-4)


def test_interp_pallas_ragged_shapes():
    n, d, r = 300, 136, 17
    x = jax.random.normal(jax.random.PRNGKey(0), (1, n, d))
    idx_lo, w_lo, h = ski.make_inducing(n, r)
    assert_allclose(ops.interp_reduce(x, idx_lo, w_lo, r, use_pallas=True),
                    ref.interp_reduce_ref(x, idx_lo, w_lo, r),
                    rtol=1e-3, atol=1e-3)
    z = jax.random.normal(jax.random.PRNGKey(1), (1, r, d))
    assert_allclose(ops.interp_expand(z, idx_lo, w_lo, use_pallas=True),
                    ref.interp_expand_ref(z, idx_lo, w_lo),
                    rtol=1e-3, atol=1e-3)


# ------------------------------------------------------- backend subsystem
def test_backend_fit_block_bounds_padding():
    for size in (7, 100, 300, 2048, 5000):
        blk = backend.fit_block(size, 256)
        assert blk % 8 == 0 and blk <= max(256, backend.round_up(size, 8))
        assert backend.round_up(size, blk) - size < blk  # waste < one tile


def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "tune.json"))
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    backend.clear_cache(memory_only=True)
    calls = []
    tune = lambda bn, bd: calls.append((bn, bd)) or jnp.zeros(())
    blocks = backend.get_blocks("short_conv", 96, 16, jnp.float32, True,
                                tune_call=tune)
    n_swept = len(calls)
    assert n_swept > 1                         # swept several candidates
    assert (tmp_path / "tune.json").exists()   # persisted
    backend.clear_cache(memory_only=True)      # force re-read from disk
    again = backend.get_blocks("short_conv", 96, 16, jnp.float32, True,
                               tune_call=tune)
    assert again == blocks and len(calls) == n_swept  # cache hit: no sweep
    monkeypatch.delenv("REPRO_AUTOTUNE")
    backend.clear_cache(memory_only=True)


def test_dispatch_policy_env(monkeypatch):
    monkeypatch.setenv("REPRO_USE_PALLAS", "auto")
    assert backend.use_pallas_default() == (backend.platform() == "tpu")
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    assert backend.use_pallas_default() is True
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    assert backend.use_pallas_default() is False
    assert backend.resolve_use_pallas(True) is True
    ops.set_default_backend(True)
    try:
        monkeypatch.setenv("REPRO_USE_PALLAS", "0")
        assert backend.use_pallas_default() is True   # programmatic wins
    finally:
        ops.set_default_backend(None)
