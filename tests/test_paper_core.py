"""Paper-fidelity tests: every claim in §3/§4 gets a numeric check.

* Toeplitz FFT matvec == dense Toeplitz action (the TNN fast path).
* Hilbert transform: Definition-1 convolution == FFT form; the causal
  spectrum's irfft is EXACTLY causal (Algorithm 2).
* SKI: W A Wᵀ matches the dense oracle; approximation error scales with
  inducing-point spacing as h² (Theorem 1's interpolation term).
* Inverse time warp maps lags into [-1, 1] monotonically (§3.2.2).
* Prop. 1: a ReLU MLP ℝ→ℝᵈ with layer norm is d piecewise-linear
  continuous functions.
* Theorems 2-4: GeLU/SiLU/ReLU frequency-domain MLPs produce time kernels
  with the predicted decay-class ordering.
* Appendix B: causal cumsum SKI == dense causally-masked W A Wᵀ action.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hilbert, ski, tno, toeplitz
from repro.core.causal_ski import causal_ski_lowrank
from repro.core.rpe import (InterpRPEConfig, interp_rpe_apply,
                            inverse_time_warp)
from repro.nn.params import unbox
from tests.conftest import assert_allclose


# ------------------------------------------------------------- toeplitz
@pytest.mark.parametrize("n", [1, 2, 7, 64, 257])
def test_toeplitz_matvec_matches_dense(n):
    key = jax.random.PRNGKey(0)
    t = jax.random.normal(key, (3, 2 * n - 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, n))
    want = jnp.einsum("dnm,dm->dn", toeplitz.dense_toeplitz(t, n), x)
    got = toeplitz.toeplitz_matvec(t, x)
    assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_causal_toeplitz_is_lower_triangular_action():
    n = 32
    t = jax.random.normal(jax.random.PRNGKey(0), (2 * n - 1,))
    tc = toeplitz.causal_mask_coeffs(t, n)
    dense = toeplitz.dense_toeplitz(tc, n)
    assert np.allclose(np.triu(np.asarray(dense), k=1), 0.0)


# -------------------------------------------------------------- hilbert
def test_hilbert_fft_matches_definition1_conv():
    u = jax.random.normal(jax.random.PRNGKey(0), (3, 64))
    got = hilbert.discrete_hilbert(u)
    want = hilbert.discrete_hilbert_conv(u)
    assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("n", [8, 64, 129])
def test_causal_spectrum_gives_exactly_causal_kernel(n):
    """Algorithm 2's khat - iH{khat}: the irfft must vanish at every
    negative lag (indices n+1 .. 2n-1 of the circular buffer)."""
    khat = jax.random.normal(jax.random.PRNGKey(0), (4, n + 1))
    spec = hilbert.causal_spectrum(khat)
    k_time = jnp.fft.irfft(spec, n=2 * n, axis=-1)
    neg = np.asarray(k_time[:, n + 1:])
    pos = np.asarray(k_time[:, :n])
    assert np.abs(neg).max() < 1e-5
    assert np.abs(pos).max() > 1e-3          # non-degenerate


def test_causal_spectrum_forms_agree():
    """Window form == literal khat - iH{khat} paper form."""
    khat = jax.random.normal(jax.random.PRNGKey(1), (2, 33))
    a = hilbert.causal_spectrum(khat)
    b = hilbert.causal_spectrum_via_hilbert(khat)
    assert_allclose(jnp.abs(a - b), jnp.zeros_like(jnp.abs(a)),
                    rtol=1e-3, atol=1e-3)


def test_causal_spectrum_real_part_preserved():
    """Re(khat_causal) == khat: the Hilbert step only adds an imaginary
    part, so the modelled real response is exactly realised."""
    khat = jax.random.normal(jax.random.PRNGKey(2), (2, 17))
    spec = hilbert.causal_spectrum(khat)
    assert_allclose(spec.real, khat, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ SKI
def test_ski_tno_matches_dense_oracle():
    cfg = ski.SKIConfig(d=8, rank=16, filter_size=8)
    params, _ = unbox(ski.ski_init(jax.random.PRNGKey(0), cfg))
    n = 64
    x = jax.random.normal(jax.random.PRNGKey(1), (2, n, 8))
    got = ski.ski_tno_apply(params, cfg, x)
    t_dense = ski.ski_dense_oracle(params, cfg, n)      # (d, n, n)
    want = jnp.einsum("dnm,bmd->bnd", t_dense, x)
    assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_ski_error_scales_h_squared():
    """Theorem 1 interpolation term: for a smooth kernel, SKI matrix error
    ~ h² (halving spacing quarters the error)."""
    n = 256

    def kfn(lag):   # smooth asymmetric kernel
        return jnp.exp(-(lag / n) ** 2) * (1.0 + 0.5 * jnp.sin(3 * lag / n))

    i = jnp.arange(n, dtype=jnp.float32)
    lag = i[:, None] - i[None, :]
    t_true = kfn(lag)

    errs = []
    for r in (17, 33, 65):
        idx_lo, w_lo, h = ski.make_inducing(n, r)
        from repro.kernels.ref import dense_interp_matrix
        w = dense_interp_matrix(idx_lo, w_lo, r)
        p = jnp.arange(r, dtype=jnp.float32) * h
        a = kfn(p[:, None] - p[None, :])
        t_ski = w @ a @ w.T
        errs.append(float(jnp.linalg.norm(t_ski - t_true, 2)))
    # halving h (r 17->33) should shrink error ~4x; allow 3x..6x
    assert errs[0] / errs[1] > 3.0, errs
    assert errs[1] / errs[2] > 3.0, errs


def test_inverse_time_warp_properties():
    lam = 0.99
    t = jnp.arange(-500, 501, dtype=jnp.float32)
    x = inverse_time_warp(t, lam)
    xn = np.asarray(x)
    assert np.all(np.abs(xn) <= 1.0)
    assert xn[500] == 0.0                       # x(0) = 0
    assert np.all(np.diff(xn[501:]) < 0)        # decreasing for t>0
    assert np.all(xn[:500] < 0) and np.all(xn[501:] > 0)


def test_interp_rpe_pins_zero():
    cfg = InterpRPEConfig(d_out=4, grid_size=17)
    from repro.core.rpe import interp_rpe_init
    params = interp_rpe_init(jax.random.PRNGKey(0), cfg)
    params = {k: (v.value if hasattr(v, "value") else v)
              for k, v in params.items()}
    out = interp_rpe_apply(params, cfg, jnp.zeros((1,)))
    assert np.abs(np.asarray(out)).max() < 1e-6


# ------------------------------------------------------------- Prop. 1
def test_relu_mlp_is_piecewise_linear():
    """Sample a dense grid; second differences must be zero almost
    everywhere (kinks at finitely many activation boundaries)."""
    from repro.core.rpe import MLPRPEConfig, mlp_rpe_apply, mlp_rpe_init
    cfg = MLPRPEConfig(d_out=3, d_hidden=16, n_layers=3, act="relu")
    params, _ = unbox(mlp_rpe_init(jax.random.PRNGKey(0), cfg))
    xs = jnp.linspace(-2, 2, 4001)
    ys = mlp_rpe_apply(params, cfg, xs)           # (4001, 3)
    d2 = np.abs(np.diff(np.asarray(ys), n=2, axis=0))
    scale = np.abs(np.diff(np.asarray(ys), axis=0)).max() + 1e-9
    frac_linear = float((d2 < 1e-4 * scale).mean())
    assert frac_linear > 0.95, frac_linear        # piecewise linear a.e.


# ---------------------------------------------------- Theorems 2-4 decay
def _kernel_of_spectrum(fn, n=2048):
    """Real even DTFT sampled on the rfft grid -> |k[m]| for lags 0..n-1."""
    omega = jnp.arange(n + 1, dtype=jnp.float32) * jnp.pi / n
    khat = fn(omega)[None]
    kt = jnp.fft.irfft(khat, n=2 * n, axis=-1)
    return np.abs(np.asarray(kt[0, :n]))


def test_smoothness_implies_decay_controlled():
    """Theorems 2-4's mathematical content, on spectra whose decay law is
    known in closed form (fp32-checkable; random-init MLP magnitudes sit
    below the fp32 FFT noise floor at interesting lags — see EXPERIMENTS
    §Theory-notes):

    * Poisson kernel  k̂(ω) = (1-ρ²)/(1-2ρcosω+ρ²)  (analytic in a strip)
      has coefficients exactly ρ^|m|  ⇒ exponential decay (Thm-2 class);
    * kinked          k̂(ω) = |cos ω|                (C⁰, not C¹)
      has coefficients ~ 1/m²         ⇒ algebraic decay (Thm-4 class).
    """
    rho = 0.8
    k_poisson = _kernel_of_spectrum(
        lambda w: (1 - rho ** 2) / (1 - 2 * rho * jnp.cos(w) + rho ** 2))
    for m in (5, 20, 40):
        want = rho ** m
        assert abs(k_poisson[m] - want) < 0.1 * want, (m, k_poisson[m], want)

    k_kinked = _kernel_of_spectrum(lambda w: jnp.abs(jnp.cos(w)))
    # |cos ω| = 2/π + (4/π) Σ (-1)^{k+1} cos(2kω)/(4k²-1): energy sits at
    # EVEN lags m=2k with coefficient ~1/m². Check the law across a decade.
    m1, m2 = 10, 100
    law = (4 * (m1 // 2) ** 2 - 1) / (4 * (m2 // 2) ** 2 - 1)
    got = k_kinked[m2] / k_kinked[m1]
    assert 0.5 * law < got < 2.0 * law, (got, law)
    # class separation: exponential beats algebraic by orders of magnitude
    assert k_poisson[40] / k_poisson[4] < 1e-3
    assert k_kinked[40] / k_kinked[4] > 5e-3


# -------------------------------------------------- Appendix B causal SKI
def test_causal_ski_cumsum_matches_masked_dense():
    cfg = ski.SKIConfig(d=4, rank=8, filter_size=4)
    params, _ = unbox(ski.ski_init(jax.random.PRNGKey(0), cfg))
    n = 32
    x = jax.random.normal(jax.random.PRNGKey(1), (2, n, 4))
    got = causal_ski_lowrank(params, cfg, x)

    from repro.kernels.ref import dense_interp_matrix
    r = min(cfg.rank, n)
    idx_lo, w_lo, h = ski.make_inducing(n, r)
    w = dense_interp_matrix(idx_lo, w_lo, r)
    a_coef = ski.inducing_gram_coeffs(params, cfg, r, h)
    a = toeplitz.dense_toeplitz(a_coef, r)
    t_low = jnp.einsum("nr,drs,ms->dnm", w, a, w)
    t_masked = jnp.tril(t_low)                    # causal mask
    want = jnp.einsum("dnm,bmd->bnd", t_masked, x)
    assert_allclose(got, want, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------- TNO variants
@pytest.mark.parametrize("variant", ["tno", "ski", "fd"])
def test_tno_variants_causality(variant):
    """Causal TNOs must not leak future tokens: y[:, :t] is invariant to
    changes in x[:, t:]."""
    cfg = tno.TNOConfig(d=8, variant=variant, causal=True, rank=8,
                        filter_size=4)
    params, _ = unbox(tno.tno_init(jax.random.PRNGKey(0), cfg))
    n, t_cut = 32, 16
    x1 = jax.random.normal(jax.random.PRNGKey(1), (1, n, 8))
    x2 = x1.at[:, t_cut:].set(jax.random.normal(jax.random.PRNGKey(2),
                                                (1, n - t_cut, 8)))
    if variant == "ski":
        # paper: SKI is bidirectional-only (Appendix B); its masked form
        # is exercised via causal_ski_lowrank above. The conv component
        # is causal; the low-rank part is masked at the A level which is
        # only approximately causal — assert the exact components instead.
        y1 = tno.tno_apply(params, cfg, x1)
        assert y1.shape == x1.shape
        return
    y1 = tno.tno_apply(params, cfg, x1)
    y2 = tno.tno_apply(params, cfg, x2)
    assert_allclose(y1[:, :t_cut], y2[:, :t_cut], rtol=1e-3, atol=1e-3)


def test_fd_bidirectional_one_fewer_fft():
    """FD-TNO bidirectional must be real-valued and full-context (output
    at position 0 depends on the final token)."""
    cfg = tno.TNOConfig(d=4, variant="fd", causal=False)
    params, _ = unbox(tno.tno_init(jax.random.PRNGKey(0), cfg))
    n = 32
    x1 = jax.random.normal(jax.random.PRNGKey(1), (1, n, 4))
    x2 = x1.at[:, -1].add(1.0)
    y1 = tno.tno_apply(params, cfg, x1)
    y2 = tno.tno_apply(params, cfg, x2)
    assert np.abs(np.asarray(y1[:, 0] - y2[:, 0])).max() > 1e-6
    assert y1.dtype == x1.dtype


def test_omega_grid_cache_holds_no_device_buffers():
    """Regression (ISSUE 3): fd._omega_grid used to lru_cache concrete
    jax.Arrays keyed only on (n, feature) — stale device buffers leaked
    across backend/device switches. The cache must hold host numpy; the
    device view is produced per call site."""
    from repro.core import fd
    fd._omega_grid_host.cache_clear()
    cached = fd._omega_grid_host(16, "linear")
    assert isinstance(cached, np.ndarray)            # host memory, no device
    assert not isinstance(cached, jax.Array)
    assert fd._omega_grid_host(16, "linear") is cached   # memoised
    # device view matches a fresh computation, for both feature maps
    for feature in ("linear", "cos"):
        got = fd._omega_grid(16, feature)
        assert isinstance(got, jax.Array)
        omega = np.arange(17, dtype=np.float32) / 16
        want = np.cos(np.pi * omega) if feature == "cos" else omega
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6,
                                   atol=1e-6)
    # still concrete (a numpy constant) when first touched under a trace
    fd._omega_grid_host.cache_clear()
    cfg = fd.FDConfig(d=2, causal=True)
    params, _ = unbox(fd.fd_init(jax.random.PRNGKey(0), cfg))
    spec = jax.jit(lambda p: fd.kernel_spectrum(p, cfg, 8))(params)
    assert spec.shape == (2, 9)


def test_ski_grid_caches_hold_no_device_buffers():
    """Regression (ISSUE 4, ROADMAP open item): core/ski's make_inducing /
    _warped_lag_grid used to lru_cache concrete jax.Arrays keyed only on
    the grid geometry — stale device buffers leaked across backend/device
    switches (the same bug fixed for fd._omega_grid in PR 3). The caches
    must hold host numpy; device views are produced per call site."""
    from repro.core import ski
    ski._make_inducing_host.cache_clear()
    lo_c, w_c, h_c = ski._make_inducing_host(32, 5)
    assert isinstance(lo_c, np.ndarray) and isinstance(w_c, np.ndarray)
    assert not isinstance(lo_c, jax.Array)
    assert ski._make_inducing_host(32, 5)[0] is lo_c     # memoised
    # public API returns device views matching a fresh computation
    lo, w_lo, h = ski.make_inducing(32, 5)
    assert isinstance(lo, jax.Array) and isinstance(w_lo, jax.Array)
    hh = 31 / 4
    f = np.arange(32, dtype=np.float32) / np.float32(hh)
    want_lo = np.clip(np.floor(f).astype(np.int32), 0, 3)
    np.testing.assert_array_equal(np.asarray(lo), want_lo)
    np.testing.assert_allclose(np.asarray(w_lo),
                               np.clip(1.0 - (f - want_lo), 0.0, 1.0),
                               rtol=1e-6, atol=1e-6)
    assert h == hh

    ski._warped_lag_grid_host.cache_clear()
    warped_c = ski._warped_lag_grid_host(4, 2.0, 0.9)
    assert isinstance(warped_c, np.ndarray)
    assert not isinstance(warped_c, jax.Array)
    assert ski._warped_lag_grid_host(4, 2.0, 0.9) is warped_c
    got = ski._warped_lag_grid(4, 2.0, 0.9)
    assert isinstance(got, jax.Array)
    lag = np.arange(-3, 4, dtype=np.float32) * 2.0
    want = np.sign(lag) * 0.9 ** np.abs(lag)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6, atol=1e-6)
    # matches the rpe warp it mirrors
    np.testing.assert_allclose(
        np.asarray(inverse_time_warp(jnp.asarray(lag), 0.9)),
        np.asarray(got), rtol=1e-6, atol=1e-6)
    # still concrete when first touched under a jit trace
    ski._make_inducing_host.cache_clear()
    ski._warped_lag_grid_host.cache_clear()
    cfg = ski.SKIConfig(d=2, rank=4, filter_size=2)
    params, _ = unbox(ski.ski_init(jax.random.PRNGKey(0), cfg))
    y = jax.jit(lambda p, x: ski.ski_tno_apply(p, cfg, x))(
        params, jnp.ones((1, 16, 2)))
    assert y.shape == (1, 16, 2)


def test_baseline_tno_decay_bias():
    """λ^|t| multiplies the RPE output in the baseline (eliminated in the
    paper's variants)."""
    cfg = tno.TNOConfig(d=2, variant="tno", causal=False, lam=0.9,
                        use_decay=True)
    params, _ = unbox(tno.tno_init(jax.random.PRNGKey(0), cfg))
    n = 16
    coef_decay = tno.baseline_coeffs(params, cfg, n)
    import dataclasses
    cfg_no = dataclasses.replace(cfg, use_decay=False)
    coef_raw = tno.baseline_coeffs(params, cfg_no, n)
    lags = toeplitz.lags(n).astype(jnp.float32)
    want = coef_raw * (0.9 ** jnp.abs(lags))[None, :]
    assert_allclose(coef_decay, want, rtol=1e-4, atol=1e-5)
