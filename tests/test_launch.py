"""Distribution-layer tests on a small fake-device mesh (8 CPU devices via
subprocess-free reuse: these tests run in the main process only when the
device count allows; otherwise they validate the pure-python parts)."""
import numpy as np

import jax

from repro.configs import get_config
from repro.launch.steps import SHAPES, StepBuilder, cell_is_applicable
from repro.parallel.sharding import ShardingRules, spec_for


def test_cell_applicability_matrix():
    """33 applicable cells: 10 archs × 4 shapes − 7 long_500k skips."""
    archs = ["jamba-1.5-large-398b", "grok-1-314b", "granite-moe-3b-a800m",
             "phi3-medium-14b", "qwen2-72b", "gemma3-4b", "stablelm-3b",
             "paligemma-3b", "whisper-medium", "mamba2-2.7b"]
    cells = [(a, s) for a in archs for s in SHAPES
             if cell_is_applicable(a, s)]
    assert len(cells) == 33
    assert ("qwen2-72b", "long_500k") not in cells
    assert ("mamba2-2.7b", "long_500k") in cells
    assert ("gemma3-4b", "long_500k") in cells
    assert ("jamba-1.5-large-398b", "long_500k") in cells


def test_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_spec_for_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(data_axes=("data",))
    # 'heads' -> model; extent 1 divides everything
    s = spec_for(mesh, rules, ("embed", "heads"), (64, 64))
    assert len(s) == 2


def test_spec_for_no_axis_reuse():
    """An axis already consumed by one dim must not shard a second dim."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = ShardingRules(data_axes=("data",))
    s = spec_for(mesh, rules, ("heads", "ffn"), (16, 16))  # both -> model
    used = [x for x in s if x is not None]
    assert len(used) <= 1


def test_input_specs_shapes_per_kind():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen2-72b")
    sb = StepBuilder(cfg, mesh)
    tr = sb.input_specs(SHAPES["train_4k"])
    assert tr["tokens"].shape == (256, 4096)
    assert tr["labels"].shape == (256, 4096)
    pf = sb.input_specs(SHAPES["prefill_32k"])
    assert pf["tokens"].shape == (32, 32768) and "labels" not in pf
    dc = sb.input_specs(SHAPES["decode_32k"])
    assert dc["batch"]["tokens"].shape == (128, 1)
    kv = jax.tree.leaves(dc["cache"])
    assert any(x.shape[-3] == 32768 for x in kv if hasattr(x, "shape"))


def test_abstract_params_match_param_count_scale():
    """eval_shape param total ≈ analytic param_count (no allocation)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("qwen2-72b", "mamba2-2.7b"):
        cfg = get_config(arch)
        sb = StepBuilder(cfg, mesh)
        vals, axes = sb.abstract_params()
        total = sum(int(np.prod(v.shape)) for v in jax.tree.leaves(vals))
        analytic = cfg.param_count()["total"]
        assert abs(total - analytic) / analytic < 0.05, (arch, total, analytic)


def test_qwen_total_params_near_72b():
    cfg = get_config("qwen2-72b")
    t = cfg.param_count()["total"]
    assert 6.5e10 < t < 8.5e10, t


def test_jamba_active_vs_total():
    cfg = get_config("jamba-1.5-large-398b")
    pc = cfg.param_count()
    assert 3.4e11 < pc["total"] < 4.6e11, pc     # ~398B class
    assert pc["active"] < 0.4 * pc["total"]      # 16e top-2 sparsity


def test_serve_ctx_folds_data_axes_for_batch1():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("mamba2-2.7b")
    sb = StepBuilder(cfg, mesh)
    ctx = sb.serve_ctx(SHAPES["long_500k"])
    # with 1-extent axes everything divides; logic check via big mesh is
    # covered by the dry-run. Here: decode ctx must disable seq-SP.
    assert ctx.decode and not ctx.seq_shard_resid
