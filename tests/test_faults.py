"""Serving fault tolerance (repro.serving_engine, ISSUE 6).

Contracts under test:
* isolation — a prefill fault, a raising ``on_token`` callback, or a
  NaN-poisoned slot fails only that request (explicit error outcome,
  slot recycled); every other request's token stream is bit-exact vs the
  fault-free baseline, and a full second wave serves after the faults
  (no slot leaks);
* retries — transient (RuntimeError-family) prefill/decode faults are
  retried with backoff and leave token streams exact;
* persistent decode failure — in-flight requests get error outcomes,
  the queue survives, and a fresh ``run()`` serves the remainder
  (re-entrancy: nothing half-consumed);
* deadlines/backpressure — the watchdog evicts expired slots and drops
  expired queued requests; a bounded queue rejects (QueueFull) or
  blocks until drained;
* snapshot/restore — a preempted run resumes token-exact; a failing
  snapshot write never takes serving down; geometry mismatches raise;
* determinism — the seeded FaultInjector reproduces its schedule.
"""
import os
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models.transformer import init_model
from repro.nn.params import unbox
from repro.serving_engine import (Engine, EngineStepError, FaultInjector,
                                  FaultSpec, InjectedFault, QueueFull,
                                  Request, Scheduler)

PLENS = [3, 6, 5, 2]
GENS = [8, 9, 10, 8]
MAX_LEN = 32


@pytest.fixture(scope="module")
def env():
    """Shared smoke config/params/engine (stream block C=4 so boundary
    refreshes happen inside every test) + the fault-free baseline."""
    old = os.environ.get("REPRO_FD_STREAM_C")
    os.environ["REPRO_FD_STREAM_C"] = "4"
    try:
        cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"),
                               dtype="float32", param_dtype="float32")
        params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
        eng = Engine(cfg, params, slots=2, max_len=MAX_LEN)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
                   for p in PLENS]
        sched = Scheduler(eng)
        for r in _fleet(prompts):
            sched.submit(r)
        baseline, _ = sched.run()
        assert all(o.status == "ok" for o in sched.outcomes.values())
        yield {"cfg": cfg, "params": params, "engine": eng,
               "prompts": prompts,
               "baseline": {u: list(t) for u, t in baseline.items()}}
    finally:
        if old is None:
            os.environ.pop("REPRO_FD_STREAM_C", None)
        else:
            os.environ["REPRO_FD_STREAM_C"] = old


def _fleet(prompts, uid_prefix="r", gens=GENS, **kw):
    return [Request(uid=f"{uid_prefix}{i}", prompt=pr, max_new=g, **kw)
            for i, (pr, g) in enumerate(zip(prompts, gens))]


def _run(env, injector=None, reqs=None, **sched_kw):
    sched = Scheduler(env["engine"], injector=injector, backoff_base=0.0,
                      **sched_kw)
    for r in (reqs if reqs is not None else _fleet(env["prompts"])):
        sched.submit(r)
    results, state = sched.run()
    return sched, results, state


# ------------------------------------------------------------- injector
def test_injector_scripted_transient_and_persistent():
    inj = FaultInjector(specs=[
        FaultSpec(site="prefill", uid="a", at=0, count=1),   # transient
        FaultSpec(site="decode", at=1, count=99),            # persistent
    ])
    with pytest.raises(InjectedFault):
        inj.prefill("a")
    inj.prefill("a")                       # second visit passes (count=1)
    inj.prefill("b")                       # other uid never matches
    assert inj.decode(0) is None
    for step in (1, 2, 3):
        with pytest.raises(InjectedFault):
            inj.decode(step)
    assert inj.fired == 4 and len(inj.log) == 4


def test_injector_seeded_is_deterministic():
    def schedule():
        inj = FaultInjector(seed=123, rates={"decode": 0.5})
        fired = []
        for step in range(40):
            try:
                inj.decode(step)
                fired.append(False)
            except InjectedFault:
                fired.append(True)
        return fired
    a, b = schedule(), schedule()
    assert a == b and any(a) and not all(a)
    with pytest.raises(ValueError, match="seed"):
        FaultInjector(rates={"decode": 0.5})
    with pytest.raises(ValueError, match="site"):
        FaultSpec(site="nope")


def test_injector_poison_requires_decode_site():
    with pytest.raises(ValueError, match="poison_slot"):
        FaultSpec(site="prefill", poison_slot=0)


# ------------------------------------------------- request-level isolation
def test_prefill_fault_fails_only_that_request(env):
    inj = FaultInjector(specs=[FaultSpec(site="prefill", uid="r1",
                                         count=99)])
    sched, results, _ = _run(env, injector=inj)
    assert sched.outcomes["r1"].status == "error"
    assert "prefill failed" in sched.outcomes["r1"].error
    assert results["r1"] == []
    for u in ("r0", "r2", "r3"):
        assert sched.outcomes[u].status == "ok"
        assert results[u] == env["baseline"][u], u


def test_transient_prefill_fault_is_retried(env):
    inj = FaultInjector(specs=[FaultSpec(site="prefill", uid="r0",
                                         count=1)])
    sched, results, _ = _run(env, injector=inj, max_retries=2)
    assert sched.retries >= 1
    for u in ("r0", "r1", "r2", "r3"):
        assert sched.outcomes[u].status == "ok"
        assert results[u] == env["baseline"][u], u


def test_raising_callback_is_detached_not_fatal(env):
    calls = {"n": 0}

    def bad_cb(uid, tok):
        calls["n"] += 1
        raise ZeroDivisionError("callback bug")

    reqs = _fleet(env["prompts"])
    reqs[1].on_token = bad_cb
    sched, results, _ = _run(env, reqs=reqs)
    assert calls["n"] == 1                      # detached after first raise
    out = sched.outcomes["r1"]
    assert out.status == "ok" and "ZeroDivisionError" in out.callback_error
    for u in ("r0", "r1", "r2", "r3"):
        assert results[u] == env["baseline"][u], u


def test_nonfinite_guard_quarantines_slot_and_recycles(env):
    # poison slot 0 on the 3rd decode step: r0 (gen 8, admitted to slot 0)
    # is still mid-generation there
    inj = FaultInjector(specs=[FaultSpec(site="decode", at=3,
                                         poison_slot=0)])
    sched, results, state = _run(env, injector=inj)
    out = sched.outcomes["r0"]
    assert out.status == "error" and "non-finite" in out.error
    got = results["r0"]
    base = env["baseline"]["r0"]
    # tokens up to the injection are exact; garbage is never streamed
    assert 0 < len(got) < len(base) and got == base[:len(got)]
    for u in ("r1", "r2", "r3"):
        assert sched.outcomes[u].status == "ok"
        assert results[u] == env["baseline"][u], u
    # second wave over the same state: the NaN'd slot row must have been
    # fully overwritten by the recycling insert — no leak
    for r in _fleet(env["prompts"], uid_prefix="w"):
        sched.submit(r)
    sched.injector = None
    results2, _ = sched.run(state)
    for i in range(4):
        assert results2[f"w{i}"] == env["baseline"][f"r{i}"], i


def test_transient_decode_fault_is_retried_exactly(env):
    inj = FaultInjector(specs=[FaultSpec(site="decode", at=2, count=1)])
    sched, results, _ = _run(env, injector=inj, max_retries=1)
    assert sched.retries >= 1
    for u in ("r0", "r1", "r2", "r3"):
        assert sched.outcomes[u].status == "ok"
        assert results[u] == env["baseline"][u], u


def test_persistent_decode_failure_is_reentrant(env):
    """Retry exhaustion on the batched step fails the in-flight requests
    with explicit outcomes but leaves the queue intact: a fresh run()
    serves the remainder exactly (nothing half-consumed)."""
    inj = FaultInjector(specs=[FaultSpec(site="decode", at=1, count=99)])
    sched = Scheduler(env["engine"], injector=inj, max_retries=1,
                      backoff_base=0.0)
    for r in _fleet(env["prompts"]):
        sched.submit(r)
    with pytest.raises(EngineStepError):
        sched.run()
    # slots=2: r0/r1 were in flight and failed; r2/r3 still queued
    for u in ("r0", "r1"):
        assert sched.outcomes[u].status == "error"
        assert "engine step failed" in sched.outcomes[u].error
    assert [r.uid for r in sched.queue] == ["r2", "r3"]
    assert sched.outcomes["r2"].status == "pending"
    sched.injector = None
    results, _ = sched.run()                    # fresh state, same queue
    for u in ("r2", "r3"):
        assert sched.outcomes[u].status == "ok"
        assert results[u] == env["baseline"][u], u


def test_duplicate_uid_after_completed_run_rejected(env):
    sched, results, state = _run(env)
    assert sched.outcomes["r0"].status == "ok"
    with pytest.raises(ValueError, match="already submitted"):
        sched.submit(Request(uid="r0", prompt=env["prompts"][0],
                             max_new=4))


# --------------------------------------------------- deadlines/backpressure
def test_deadline_watchdog_evicts_expired_slot(env):
    clk = {"t": 0.0}

    def tick(uid, tok):
        clk["t"] += 2.0                         # each streamed token: +2s

    reqs = _fleet(env["prompts"][:2], gens=[10, 10], on_token=tick)
    reqs[0].deadline = 5.0                      # expires after ~3 tokens
    sched = Scheduler(env["engine"], clock=lambda: clk["t"],
                      backoff_base=0.0)
    for r in reqs:
        sched.submit(r)
    results, _ = sched.run()
    out = sched.outcomes["r0"]
    assert out.status == "expired" and "deadline" in out.error
    assert 0 < len(results["r0"]) < 10          # partial stream, then evicted
    assert sched.evictions >= 1
    assert sched.outcomes["r1"].status == "ok" and len(results["r1"]) == 10


def test_deadline_drops_expired_queued_request(env):
    clk = {"t": 0.0}

    def tick(uid, tok):
        clk["t"] += 1.0

    # slots=2: r2 waits in the queue while r0/r1 decode 12 tokens each;
    # its 4s TTL expires before a slot frees
    reqs = _fleet(env["prompts"][:3], gens=[12, 12, 4], on_token=tick)
    reqs[2].deadline = 4.0
    sched = Scheduler(env["engine"], clock=lambda: clk["t"],
                      backoff_base=0.0)
    for r in reqs:
        sched.submit(r)
    results, _ = sched.run()
    assert sched.outcomes["r2"].status == "expired"
    assert "queued" in sched.outcomes["r2"].error
    assert results["r2"] == []
    assert sched.outcomes["r0"].status == "ok"
    assert sched.outcomes["r1"].status == "ok"


def test_bounded_queue_reject(env):
    sched = Scheduler(env["engine"], queue_cap=2)
    for r in _fleet(env["prompts"][:2]):
        sched.submit(r)
    with pytest.raises(QueueFull, match="capacity"):
        sched.submit(Request(uid="over", prompt=env["prompts"][2],
                             max_new=4))
    # the rejected request left no bookkeeping behind
    assert "over" not in sched.results and "over" not in sched.outcomes
    results, _ = sched.run()
    assert all(sched.outcomes[f"r{i}"].status == "ok" for i in range(2))


def test_bounded_queue_block_unblocks_as_run_drains(env):
    sched = Scheduler(env["engine"], queue_cap=1, admission="block")
    reqs = _fleet(env["prompts"][:2], gens=[12, 8])
    sched.submit(reqs[0])                       # queue now at cap
    t = threading.Thread(target=sched.run)
    t.start()
    # blocks until run() pops r0, then queues r1
    sched.submit(reqs[1], timeout=30.0)
    t.join(timeout=60.0)
    assert not t.is_alive()
    assert len(sched.results["r0"]) == 12 and len(sched.results["r1"]) == 8
    for u in ("r0", "r1"):
        assert sched.outcomes[u].status == "ok"
        base, got = env["baseline"][u], sched.results[u]
        n = min(len(base), len(got))
        assert got[:n] == base[:n], u       # greedy streams agree up to min


def test_block_admission_timeout_raises(env):
    sched = Scheduler(env["engine"], queue_cap=1, admission="block")
    sched.submit(_fleet(env["prompts"][:1])[0])
    with pytest.raises(QueueFull, match="still full"):
        sched.submit(Request(uid="late", prompt=env["prompts"][1],
                             max_new=4), timeout=0.05)


# ------------------------------------------------------- snapshot/restore
def test_preempt_snapshot_resume_token_exact(env, tmp_path):
    emitted = {"n": 0}

    def preempt_after(uid, tok):
        emitted["n"] += 1
        if emitted["n"] == 7:
            sched.preempt()

    snap_dir = str(tmp_path / "snap")
    sched = Scheduler(env["engine"], snapshot_dir=snap_dir)
    for r in _fleet(env["prompts"], on_token=preempt_after):
        sched.submit(r)
    partial, _ = sched.run()
    assert sched.preempted
    n_partial = sum(len(v) for v in partial.values())
    n_total = sum(len(v) for v in env["baseline"].values())
    assert 0 < n_partial < n_total

    streamed = {}
    sched2 = Scheduler(env["engine"], snapshot_dir=snap_dir)
    assert sched2.try_restore(callbacks={
        "r0": lambda u, t: streamed.setdefault(u, []).append(t)})
    resumed, _ = sched2.run()
    for u, want in env["baseline"].items():
        assert sched2.outcomes[u].status == "ok", sched2.outcomes[u]
        assert resumed[u] == want, (
            f"{u}: resume drift {resumed[u]} vs {want}")
    # the re-attached callback streamed exactly the post-resume tokens
    if "r0" in streamed:
        assert resumed["r0"][-len(streamed["r0"]):] == streamed["r0"]


def test_try_restore_without_snapshot_is_noop(env, tmp_path):
    sched = Scheduler(env["engine"], snapshot_dir=str(tmp_path / "empty"))
    os.makedirs(str(tmp_path / "empty"), exist_ok=True)
    assert not sched.try_restore()
    assert Scheduler(env["engine"]).try_restore() is False  # no dir at all


def test_snapshot_geometry_mismatch_raises(env, tmp_path):
    snap_dir = str(tmp_path / "snap")
    sched = Scheduler(env["engine"], snapshot_dir=snap_dir,
                      snapshot_every=2)
    for r in _fleet(env["prompts"][:2]):
        sched.submit(r)
    sched.run()
    other = Engine(env["cfg"], env["params"], slots=3, max_len=MAX_LEN)
    with pytest.raises(ValueError, match="geometry"):
        Scheduler(other, snapshot_dir=snap_dir).try_restore()


def test_snapshot_write_fault_never_fatal(env, tmp_path):
    inj = FaultInjector(specs=[FaultSpec(site="snapshot", count=99)])
    sched, results, _ = _run(env, injector=inj,
                             snapshot_dir=str(tmp_path / "snap"),
                             snapshot_every=2)
    assert sched.snapshot_errors >= 1           # every write failed...
    for u in ("r0", "r1", "r2", "r3"):          # ...and serving never blinked
        assert sched.outcomes[u].status == "ok"
        assert results[u] == env["baseline"][u], u


# ------------------------------------------------------- guard plumbing
def test_generate_returns_all_ok_without_faults(env):
    eng = env["engine"]
    state = eng.init_state()
    prefix, first, plen = eng.prefill(env["prompts"][0])
    state = eng.insert(state, prefix, plen, int(first), 0)
    state, toks, ok = eng.generate(state)
    ok_h = np.asarray(ok)
    assert ok_h.shape == (eng.slots,) and bool(ok_h.all())


def test_poison_then_generate_flags_only_that_slot(env):
    eng = env["engine"]
    state = eng.init_state()
    for slot in (0, 1):
        prefix, first, plen = eng.prefill(env["prompts"][slot])
        state = eng.insert(state, prefix, plen, int(first), slot)
    state = eng.poison_slot(state, 0)
    state, toks, ok = eng.generate(state)
    ok_h = np.asarray(ok)
    assert not bool(ok_h[0]) and bool(ok_h[1])
    active = np.asarray(state.active)
    assert not bool(active[0]) and bool(active[1])   # quarantined on device
