"""Streaming overlap-save FD decode (kernels/fd_stream.py): exactness of
the block scheme against the direct causal-convolution oracle, the
push-block ≡ C-steps equivalence (chunked prefill), and the serving-level
stream-vs-hist-replay parity across multiple C-blocks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fd_stream


def _direct_causal(k, u):
    """y[t] = Σ_{τ<=t} k[τ] u[t-τ] — O(n²) float64 oracle."""
    ko = np.asarray(k, np.float64)
    uo = np.asarray(u, np.float64)
    b, n, d = uo.shape
    y = np.zeros((b, n, d))
    for t in range(n):
        for tau in range(t + 1):
            y[:, t] += ko[:, tau] * uo[:, t - tau]
    return y


@pytest.mark.parametrize("c,n", [(4, 16), (8, 40), (8, 37), (16, 16),
                                 (32, 20)])
def test_stream_step_matches_direct_conv(c, n):
    """Token-by-token streaming == the exact causal Toeplitz action, across
    block boundaries, partial final blocks, and C > n."""
    b, d = 2, 5
    k = jax.random.normal(jax.random.PRNGKey(c * n), (d, n))
    u = jax.random.normal(jax.random.PRNGKey(c + n), (b, n, d))
    want = _direct_causal(k, u)
    cache = fd_stream.fd_stream_cache(k, b, n, c)
    step = jax.jit(fd_stream.stream_step)
    got = []
    for t in range(n):
        y, cache = step(cache, u[:, t], jnp.int32(t))
        got.append(y)
    got = np.asarray(jnp.stack(got, 1))
    rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-12)
    assert rel <= 1e-5


@pytest.mark.parametrize("c", [4, 8])
def test_push_block_equals_steps(c):
    """Chunked prefill: one stream_push_block == C stream_step calls, in
    outputs AND in every cache leaf (the machinery is shared)."""
    b, d, n = 2, 3, 4 * c
    k = jax.random.normal(jax.random.PRNGKey(0), (d, n))
    u = jax.random.normal(jax.random.PRNGKey(1), (b, n, d))
    c_step = fd_stream.fd_stream_cache(k, b, n, c)
    c_push = fd_stream.fd_stream_cache(k, b, n, c)
    ys, yp = [], []
    for j in range(n // c):
        for t in range(j * c, (j + 1) * c):
            y, c_step = fd_stream.stream_step(c_step, u[:, t], jnp.int32(t))
            ys.append(y)
        yb, c_push = fd_stream.stream_push_block(c_push, u[:, j * c:(j + 1) * c],
                                                 jnp.int32(j * c))
        yp.append(yb)
    ys = np.asarray(jnp.stack(ys, 1))
    yp = np.asarray(jnp.concatenate(yp, 1))
    np.testing.assert_allclose(yp, ys, rtol=1e-5, atol=1e-5)
    for key in ("ring", "tail", "uspec_re", "uspec_im"):
        np.testing.assert_allclose(np.asarray(c_push[key]),
                                   np.asarray(c_step[key]),
                                   rtol=1e-5, atol=1e-5, err_msg=key)


def test_cache_shapes_and_block_size():
    k = jax.random.normal(jax.random.PRNGKey(0), (3, 24))
    cache = fd_stream.fd_stream_cache(k, 2, 24, 8)
    assert fd_stream.is_stream_cache(cache)
    assert fd_stream.stream_block_size(cache) == 8
    assert cache["uspec_re"].shape == (2, 3, 9, 3)       # (b, NB, C+1, d)
    assert cache["kseg_re"].shape == (3, 9, 3)
    assert not fd_stream.is_stream_cache({"hist": k})


def test_cache_rejects_short_kernel():
    k = jax.random.normal(jax.random.PRNGKey(0), (3, 8))
    with pytest.raises(ValueError):
        fd_stream.fd_stream_cache(k, 1, 16, 4)


# ----------------------------------------------------- serving-level parity
def test_serving_stream_matches_hist_replay(monkeypatch):
    """Full-model decode: the streaming FD cache reproduces the hist-replay
    decode token-for-token (logits and greedy tokens) over a generation
    spanning multiple C-blocks."""
    monkeypatch.setenv("REPRO_FD_STREAM_C", "4")
    from repro.configs import get_config, reduce_for_smoke
    from repro.models.context import Ctx
    from repro.models import serving
    from repro.models.transformer import init_model
    from repro.nn.params import unbox

    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"), dtype="float32",
                           param_dtype="float32")
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    b, p, gen = 1, 3, 14                                  # spans 4 C-blocks
    max_len = p + gen
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, p), 0, cfg.vocab)

    def decode(cache):
        toks = [prompt[:, i] for i in range(p)]
        logits_all = []
        for t in range(max_len - 1):
            lg, cache = serving.decode_step(
                params, cfg, Ctx(decode=True),
                {"tokens": toks[t][:, None]}, cache, jnp.int32(t))
            logits_all.append(lg[:, 0])
            if t + 1 >= p:
                toks.append(jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32))
        return jnp.stack(toks, 1), jnp.stack(logits_all, 1)

    hist_cache = serving.init_cache(cfg, b, max_len)
    stream_cache = serving.init_cache(cfg, b, max_len, params=params)
    assert serving.stream_block_of(stream_cache) == 4
    toks_h, logits_h = decode(hist_cache)
    toks_s, logits_s = decode(stream_cache)
    assert np.array_equal(np.asarray(toks_h), np.asarray(toks_s))
    np.testing.assert_allclose(np.asarray(logits_s), np.asarray(logits_h),
                               rtol=1e-4, atol=1e-4)


def test_serving_stream_disabled_by_env(monkeypatch):
    """REPRO_FD_STREAM=0 pins the legacy hist cache even when params are
    available at init."""
    monkeypatch.setenv("REPRO_FD_STREAM", "0")
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import serving
    from repro.models.transformer import init_model
    from repro.nn.params import unbox

    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"))
    params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
    cache = serving.init_cache(cfg, 1, 8, params=params)
    assert serving.stream_block_of(cache) is None
    assert not serving.supports_chunked_prefill(cfg, cache)


def test_generate_chunked_prefill_matches_plain(monkeypatch):
    """launch/serve.generate with chunked prefill (block machinery) emits
    the same tokens as token-by-token prefill, streaming and hist."""
    monkeypatch.setenv("REPRO_FD_STREAM_C", "4")
    from repro.configs import get_config, reduce_for_smoke
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import generate
    from repro.launch.steps import StepBuilder
    from repro.models.transformer import init_model
    from repro.nn.params import unbox

    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"), dtype="float32",
                           param_dtype="float32")
    mesh = make_host_mesh()
    sb = StepBuilder(cfg, mesh)
    with mesh:
        params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0,
                                    cfg.vocab)
        toks_chunked = generate(sb, params, prompt, 10)
        toks_plain = generate(sb, params, prompt, 10, chunked_prefill=False)
        monkeypatch.setenv("REPRO_FD_STREAM", "0")
        toks_hist = generate(sb, params, prompt, 10)
    assert np.array_equal(np.asarray(toks_chunked), np.asarray(toks_plain))
    assert np.array_equal(np.asarray(toks_chunked), np.asarray(toks_hist))


def test_generate_edge_cases(monkeypatch):
    """gen_len=0 returns the prompt unchanged (no phantom token), and an
    explicit chunked_prefill=True on an unsupported cache raises instead
    of running the wrong machinery."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import generate
    from repro.launch.steps import StepBuilder
    from repro.models.transformer import init_model
    from repro.nn.params import unbox

    cfg = reduce_for_smoke(get_config("fd-tnn-lm-wt103"))
    mesh = make_host_mesh()
    sb = StepBuilder(cfg, mesh)
    with mesh:
        params, _ = unbox(init_model(jax.random.PRNGKey(0), cfg))
        prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 5), 0,
                                    cfg.vocab)
        toks = generate(sb, params, prompt, 0)
        assert np.array_equal(np.asarray(toks), np.asarray(prompt))
        one = generate(sb, params, prompt[:, :1], 0)   # p=1, logits never set
        assert np.array_equal(np.asarray(one), np.asarray(prompt[:, :1]))
        monkeypatch.setenv("REPRO_FD_STREAM", "0")     # hist cache
        with pytest.raises(ValueError):
            generate(sb, params, prompt, 4, chunked_prefill=True)


def test_fd_stream_env_rejects_typos(monkeypatch):
    from repro.kernels import backend
    monkeypatch.setenv("REPRO_FD_STREAM", "off")
    assert not backend.fd_stream_enabled()
    monkeypatch.setenv("REPRO_FD_STREAM", "on")
    assert backend.fd_stream_enabled()
    monkeypatch.setenv("REPRO_FD_STREAM", "offf")
    with pytest.raises(ValueError):
        backend.fd_stream_enabled()
