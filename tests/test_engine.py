"""Continuous-batching serving engine (repro.serving_engine, ISSUE 5).

Contracts under test:
* ragged parity — S slots at staggered lengths emit token-for-token what
  independent solo ``generate`` calls emit (same length bucket), across
  {fd, tno, attention, mamba} × {fp32, bf16};
* jit stability — the generate/insert steps trace exactly once across
  steps, inserts, and evictions at fixed S;
* eviction/recycle — more requests than slots all complete through
  recycled slots;
* capacity — over-capacity prompts/requests raise instead of silently
  clamping cache writes (the ring-corruption fix);
* ragged fd_stream — the per-slot-position stream step is exactly the
  lockstep step applied row-wise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.kernels import fd_stream
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import generate
from repro.launch.steps import StepBuilder
from repro.models import serving
from repro.models.transformer import init_model
from repro.nn.params import unbox
from repro.serving_engine import Engine, Request, Scheduler

MIXER_ARCHS = {
    "tno": "tnn-lm-wt103",
    "fd": "fd-tnn-lm-wt103",
    "attention": "stablelm-3b",
    "mamba": "mamba2-2.7b",
}


def _setup(arch, dtype, seed=0):
    cfg = reduce_for_smoke(get_config(arch), dtype=dtype, param_dtype=dtype)
    params, _ = unbox(init_model(jax.random.PRNGKey(seed), cfg))
    return cfg, params


def _solo_tokens(cfg, params, prompts, gens, max_len):
    mesh = make_host_mesh()
    sb = StepBuilder(cfg, mesh)
    outs = []
    with mesh:
        for pr, g in zip(prompts, gens):
            toks = generate(sb, params, jnp.asarray(pr)[None], g,
                            max_len=max_len)
            outs.append(np.asarray(toks)[0, len(pr):])
    return outs


# ------------------------------------------------------- ragged parity
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("mixer", sorted(MIXER_ARCHS))
def test_engine_ragged_parity(mixer, dtype, monkeypatch):
    """4 staggered-length requests through S=4 slots == 4 independent
    solo decodes, token for token."""
    monkeypatch.setenv("REPRO_FD_STREAM_C", "4")
    cfg, params = _setup(MIXER_ARCHS[mixer], dtype)
    rng = np.random.default_rng(1)
    plens, gens = [3, 6, 5, 2], [8, 5, 6, 9]
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
               for p in plens]
    max_len = 24
    solo = _solo_tokens(cfg, params, prompts, gens, max_len)

    eng = Engine(cfg, params, slots=4, max_len=max_len)
    sched = Scheduler(eng)
    for i, (pr, g) in enumerate(zip(prompts, gens)):
        sched.submit(Request(uid=f"r{i}", prompt=pr, max_new=g))
    res, _ = sched.run()
    for i in range(len(prompts)):
        got = np.asarray(res[f"r{i}"])
        assert np.array_equal(got, solo[i]), (
            f"{mixer}/{dtype} r{i}: engine {got} != solo {solo[i]}")


def test_engine_eviction_recycle_more_requests_than_slots(monkeypatch):
    """6 requests over 2 slots: every slot is recycled, all requests
    complete, tokens stay exact, and streaming callbacks saw every
    token in order."""
    monkeypatch.setenv("REPRO_FD_STREAM_C", "4")
    cfg, params = _setup("fd-tnn-lm-wt103", "float32")
    rng = np.random.default_rng(2)
    plens = [3, 7, 5, 9, 4, 6]
    gens = [10, 6, 12, 8, 5, 7]
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
               for p in plens]
    max_len = 32
    solo = _solo_tokens(cfg, params, prompts, gens, max_len)

    eng = Engine(cfg, params, slots=2, max_len=max_len)
    sched = Scheduler(eng)
    streamed = {}
    for i, (pr, g) in enumerate(zip(prompts, gens)):
        sched.submit(Request(
            uid=f"r{i}", prompt=pr, max_new=g,
            on_token=lambda uid, t: streamed.setdefault(uid, []).append(t)))
    res, _ = sched.run()
    assert sched.prefills == 6
    for i in range(6):
        assert np.array_equal(np.asarray(res[f"r{i}"]), solo[i]), i
        assert res[f"r{i}"] == streamed[f"r{i}"], i


def test_engine_eos_eviction(monkeypatch):
    """A request stops at its EOS token and frees the slot early; the
    queued request recycles it and still decodes exactly."""
    monkeypatch.setenv("REPRO_FD_STREAM_C", "4")
    cfg, params = _setup("fd-tnn-lm-wt103", "float32")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (4,)).astype(np.int32)
               for _ in range(3)]
    max_len = 32
    solo = _solo_tokens(cfg, params, prompts, [12] * 3, max_len)
    # pick an EOS that actually occurs mid-stream for request 0
    eos = int(solo[0][3])
    want0 = list(solo[0][:list(solo[0]).index(eos) + 1])

    eng = Engine(cfg, params, slots=1, max_len=max_len)
    sched = Scheduler(eng)
    sched.submit(Request(uid="r0", prompt=prompts[0], max_new=12,
                         eos_id=eos))
    sched.submit(Request(uid="r1", prompt=prompts[1], max_new=12))
    res, _ = sched.run()
    assert res["r0"] == want0                     # truncated at EOS
    assert np.array_equal(np.asarray(res["r1"]), solo[1])


# --------------------------------------------------------- jit stability
def test_engine_jit_stable_across_steps_inserts_evictions(monkeypatch):
    """At fixed S the jitted functions trace exactly once each, across
    staggered inserts, boundary refreshes, evictions and recycles."""
    monkeypatch.setenv("REPRO_FD_STREAM_C", "4")
    cfg, params = _setup("fd-tnn-lm-wt103", "float32")
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, (p,)).astype(np.int32)
               for p in [3, 7, 5, 9, 4]]
    eng = Engine(cfg, params, slots=2, max_len=32)
    sched = Scheduler(eng)
    for i, pr in enumerate(prompts):
        sched.submit(Request(uid=f"r{i}", prompt=pr, max_new=6 + i))
    sched.run()
    assert sched.prefills == 5 and sched.steps > 10
    assert eng.trace_counts["generate"] == 1, eng.trace_counts
    assert eng.trace_counts["insert"] <= 1, eng.trace_counts
    assert eng.trace_counts["decode1"] <= 1, eng.trace_counts
    assert eng.trace_counts["chunk1"] <= 1, eng.trace_counts
    # packed admission traces are bounded by SHAPES, never request count:
    # the first wave packs both free slots (one insert_from trace per
    # distinct packed batch size), recycled slots free up one at a time
    # (the sequential insert trace), and the bucketed prefill compiles at
    # most one executable per (batch, bucket, n_tok) triple
    assert sched.packed_prefills >= 1
    assert 1 <= eng.trace_counts["insert_from"] <= 2, eng.trace_counts
    assert eng.trace_counts["prefill_bucket"] <= 2 * len(eng.buckets), (
        eng.trace_counts)


def test_engine_slots_env(monkeypatch):
    from repro.serving_engine import default_slots
    monkeypatch.delenv("REPRO_ENGINE_SLOTS", raising=False)
    assert default_slots() == 8
    monkeypatch.setenv("REPRO_ENGINE_SLOTS", "3")
    assert default_slots() == 3
    monkeypatch.setenv("REPRO_ENGINE_SLOTS", "0")
    with pytest.raises(ValueError):
        default_slots()


def test_engine_rejects_zero_slots_and_duplicate_uid():
    """A 0-slot engine would make the scheduler spin forever; a reused
    uid would merge token lists and truncate the later request — both
    must raise at submission time."""
    cfg, params = _setup("fd-tnn-lm-wt103", "float32")
    with pytest.raises(ValueError, match="slots"):
        Engine(cfg, params, slots=0, max_len=16)
    eng = Engine(cfg, params, slots=1, max_len=16)
    sched = Scheduler(eng)
    pr = np.zeros((3,), np.int32)
    sched.submit(Request(uid="dup", prompt=pr, max_new=2))
    with pytest.raises(ValueError, match="already submitted"):
        sched.submit(Request(uid="dup", prompt=pr, max_new=2))


def test_insert_raises_on_unclassified_cache_leaf():
    """Every cache leaf must be declared per-slot or shared — a new leaf
    name must fail loud instead of silently leaking a recycled slot's
    previous state (treated-as-shared default)."""
    from repro.serving_engine.state import insert_prefix_cache
    dst = {"mystery": jnp.zeros((2, 4)), "hist": jnp.zeros((2, 4, 3))}
    src = {"mystery": jnp.ones((1, 4)), "hist": jnp.ones((1, 4, 3))}
    with pytest.raises(NotImplementedError, match="mystery"):
        insert_prefix_cache(dst, src, jnp.int32(0))


# ------------------------------------------------------------- capacity
def test_capacity_is_explicit_and_gates_admission():
    cfg, params = _setup("fd-tnn-lm-wt103", "float32")
    eng = Engine(cfg, params, slots=2, max_len=16)
    assert eng.capacity == 16
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError, match="exceeds slot capacity"):
        eng.prefill(rng.integers(0, cfg.vocab, (17,)).astype(np.int32))
    sched = Scheduler(eng)
    with pytest.raises(ValueError, match="exceeds slot capacity"):
        sched.submit(Request(uid="big", max_new=10,
                             prompt=rng.integers(0, cfg.vocab, (8,))
                             .astype(np.int32)))
    # boundary case fits: 8 prompt + 9 generated = 16 written positions
    sched.submit(Request(uid="fit", max_new=9,
                         prompt=rng.integers(0, cfg.vocab, (8,))
                         .astype(np.int32)))
    res, _ = sched.run()
    assert len(res["fit"]) == 9


def test_cache_capacity_by_family():
    for arch, want in [("fd-tnn-lm-wt103", 24), ("tnn-lm-wt103", 24),
                       ("stablelm-3b", 24), ("mamba2-2.7b", None)]:
        cfg, params = _setup(arch, "float32")
        cache = serving.init_cache(cfg, 2, 24, params=params)
        assert serving.cache_capacity(cache) == want, arch
    assert fd_stream.stream_capacity(
        fd_stream.fd_stream_cache(jnp.ones((3, 24)), 1, 20, 8)) == 20


# ------------------------------------------------- ragged stream kernel
def test_stream_step_ragged_matches_lockstep_rows():
    """Vector-position stream_step == each row run alone with scalar
    positions, bit-for-bit, including parked rows pinned at position 0
    (the engine's inactive-slot convention) and staggered boundaries."""
    b, d, n, c = 3, 5, 16, 4
    k = jax.random.normal(jax.random.PRNGKey(0), (d, n))
    u = jax.random.normal(jax.random.PRNGKey(1), (b, n, d))
    starts = [0, 2, 7]                         # row i enters at step starts[i]

    # reference: each row alone, scalar positions
    refs = []
    for i in range(b):
        cache = fd_stream.fd_stream_cache(k, 1, n, c)
        ys = []
        for t in range(n - starts[i]):
            y, cache = fd_stream.stream_step(cache, u[i:i + 1, t],
                                             jnp.int32(t))
            ys.append(y[0])
        refs.append(np.asarray(jnp.stack(ys)))

    cache = fd_stream.fd_stream_cache(k, b, n, c)
    got = [[] for _ in range(b)]
    for step in range(n):
        # rows not yet started idle at position 0 with zero input
        pos = np.array([max(step - s, 0) for s in starts], np.int32)
        live = np.array([step >= s for s in starts])
        inp = np.stack([np.asarray(u[i, step - starts[i]]) if live[i]
                        else np.zeros((d,), np.float32) for i in range(b)])
        y, cache = fd_stream.stream_step(cache, jnp.asarray(inp),
                                         jnp.asarray(pos))
        for i in range(b):
            if live[i]:
                got[i].append(np.asarray(y[i]))
    for i in range(b):
        np.testing.assert_array_equal(np.stack(got[i]),
                                      refs[i][:len(got[i])], err_msg=f"row{i}")


def test_insert_leaves_other_slots_untouched(monkeypatch):
    """insert() is a pure slot-row slice-in: every per-slot leaf outside
    the target row is bitwise unchanged, shared leaves fully unchanged."""
    monkeypatch.setenv("REPRO_FD_STREAM_C", "4")
    cfg, params = _setup("fd-tnn-lm-wt103", "float32")
    eng = Engine(cfg, params, slots=3, max_len=16)
    state = eng.init_state()
    rng = np.random.default_rng(6)
    prefix, first, plen = eng.prefill(
        rng.integers(0, cfg.vocab, (5,)).astype(np.int32))
    # fill slot 0 then slot 2; slot 1 must stay zero
    state = eng.insert(state, prefix, plen, first, 0)
    before = jax.tree.map(lambda x: np.asarray(x), state.cache)
    state = eng.insert(state, prefix, plen, first, 2)
    after = jax.tree.map(lambda x: np.asarray(x), state.cache)

    from repro.serving_engine.state import BATCH_AXIS_FROM_END

    def check(path, a, b):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        leaf = names[-1] if names else ""
        off = BATCH_AXIS_FROM_END.get(leaf)
        if off is None:
            np.testing.assert_array_equal(a, b, err_msg=f"shared {leaf}")
            return a
        ax = a.ndim - off
        for s in (0, 1):                      # untouched slots
            np.testing.assert_array_equal(np.take(a, s, axis=ax),
                                          np.take(b, s, axis=ax),
                                          err_msg=f"{leaf} slot {s}")
        return a
    jax.tree_util.tree_map_with_path(check, before, after)
    assert bool(state.active[0]) and bool(state.active[2])
    assert not bool(state.active[1])
    assert int(state.cur_len[2]) == plen
